"""Gradient compression with error feedback (beyond-paper optimization).

Under GSPMD the data-parallel gradient all-reduce happens in the grads'
dtype (already bf16 here — a 2x "compression" over fp32 baselines). This
module provides the explicit shard_map path for *further* compression on
slow inter-pod links: int8 quantization with per-tensor scale and error
feedback (the residual of quantization is carried to the next step, the
standard EF-SGD trick that keeps convergence).

Usage (explicit-DP training step):

    state_ef = ef_init(grads)
    comp, state_ef = ef_compress(grads, state_ef)          # int8 + scales
    comp = jax.lax.psum(comp.q, axis_name), ...            # 4x fewer bytes
    grads = ef_decompress(comp)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

try:  # jax >= 0.6 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map

Pytree = Any


@dataclasses.dataclass
class Compressed:
    q: Pytree       # int8 tensors
    scale: Pytree   # fp32 per-tensor scales


def ef_init(grads: Pytree) -> Pytree:
    """Error-feedback residual state (same structure as grads, fp32)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantize(x, bits: int = 8):
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def ef_compress(grads: Pytree, ef_state: Pytree,
                bits: int = 8) -> Tuple[Compressed, Pytree]:
    """Quantize (grads + residual); residual carries quantization error."""
    def leaf(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quantize(x, bits)
        err = x - q.astype(jnp.float32) * scale
        return (q, scale), err

    flat = jax.tree.map(leaf, grads, ef_state)
    q = jax.tree.map(lambda t: t[0][0], flat,
                     is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2
                     and isinstance(t[0], tuple))
    scale = jax.tree.map(lambda t: t[0][1], flat,
                         is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2
                         and isinstance(t[0], tuple))
    err = jax.tree.map(lambda t: t[1], flat,
                       is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2
                       and isinstance(t[0], tuple))
    return Compressed(q=q, scale=scale), err


def ef_decompress(comp: Compressed, dtype=jnp.float32) -> Pytree:
    return jax.tree.map(
        lambda q, s: (q.astype(jnp.float32) * s).astype(dtype),
        comp.q, comp.scale)


def allreduce_compressed(grads: Pytree, ef_state: Pytree, axis_name: str,
                         bits: int = 8) -> Tuple[Pytree, Pytree]:
    """psum of int8-quantized grads inside shard_map; returns mean grads.

    The int8 payloads are summed in int32 (no overflow for <=2^23 ranks),
    scales are summed alongside; the decompressed mean applies the summed
    scale / n. 4x fewer link bytes than fp32, 2x fewer than bf16.
    """
    comp, ef_state = ef_compress(grads, ef_state, bits)
    n = jax.lax.psum(1, axis_name)
    summed = jax.tree.map(
        lambda q: jax.lax.psum(q.astype(jnp.int32), axis_name), comp.q)
    scales = jax.tree.map(lambda s: jax.lax.pmean(s, axis_name), comp.scale)
    mean = jax.tree.map(
        lambda qs, s: qs.astype(jnp.float32) * s / n, summed, scales)
    return mean, ef_state
