"""ZeRO-1 optimizer-state partitioning over the data(+pod) axes.

Parameters are tensor-parallel over ``model`` only; their optimizer
moments and fp32 master copies are *additionally* sharded over the data
axes — each data shard owns a slice of the optimizer state, which is the
ZeRO-1 memory split (state bytes / (data x pod)). GSPMD materializes the
reduce-scatter/all-gather pattern implied by the sharding difference.

``zero_axes`` rewrites a logical-axes tree: for each tensor it finds the
first dim that is not already sharded and whose size divides the combined
data-axis extent, and assigns it the pseudo-logical name ``"zero"``
(ruled to ``("pod", "data")``).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import ShardingRules, _axis_sizes


def zero_rules(rules: ShardingRules) -> ShardingRules:
    return rules.replace(zero=("pod", "data"))


def zero_axes(axes_tree, shape_tree, mesh: Mesh, rules: ShardingRules):
    """Rewrite logical axes so optimizer state also shards over data axes."""
    sizes = _axis_sizes(mesh)
    dp = math.prod(sizes.get(a, 1) for a in ("pod", "data"))

    def leaf(axes: Tuple, shp):
        shape = shp.shape if hasattr(shp, "shape") else tuple(shp)
        if dp <= 1:
            return axes
        best = None
        for i, (name, dim) in enumerate(zip(axes, shape)):
            sharded = bool(name and rules.rules.get(name))
            if sharded:
                continue
            if dim % dp == 0:
                best = i
                break
        if best is None:
            return axes
        new = list(axes)
        new[best] = "zero"
        return tuple(new)

    return jax.tree.map(
        leaf, axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
