"""Logical-axis -> mesh-axis sharding resolution.

Every parameter / cache / activation tensor carries a tuple of *logical*
axis names (see ``repro.models.module``). A :class:`ShardingRules` maps
logical names to mesh axes; :func:`resolve_spec` turns (logical axes,
shape, mesh) into a concrete ``PartitionSpec``:

- mesh axes absent from the mesh (e.g. ``pod`` on the single-pod mesh) are
  dropped;
- a dim is sharded only when evenly divisible, or when GSPMD's padding
  waste ``ceil(d/n)*n/d`` stays within ``pad_tolerance`` (default 4/3 —
  admits 40 heads or 24 heads on a 16-way model axis at <=33% attention-
  only padding, rejects pathological cases like 2 kv-heads on 16 shards,
  which fall back to replication);
- a mesh axis is consumed at most once per tensor, first (leftmost
  logical dim) wins — e.g. MoE kernels (experts, embed, ..., mlp) take
  expert parallelism over ``model`` and leave ``mlp`` replicated.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "embed": (),
    "mlp": ("model",),
    "heads": ("model",),        # even head counts (SSM heads, 32/64-head attn)
    "heads_flat": ("model",),   # flattened H*hd projections (always divisible)
    "kv_flat": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "vocab": ("model",),
    "experts": ("model",),
    "seq": (),
    # Megatron-style sequence parallelism: the residual stream between
    # blocks is sharded over `model` on the sequence dim, cutting the
    # saved scan-carry activations 16x; GSPMD turns the surrounding
    # all-reduces into the matching all-gather/reduce-scatter pairs.
    "act_seq": ("model",),
    "cache_seq": ("model",),
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    # pad_tolerance 1.0 (strict) for params/caches/inputs: jit in_shardings
    # require even division. make_sharder relaxes it for activation
    # constraints, where GSPMD pads transparently.
    rules: Dict[str, Tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES))
    pad_tolerance: float = 1.0

    def replace(self, **updates) -> "ShardingRules":
        merged = dict(self.rules)
        merged.update(updates)
        return dataclasses.replace(self, rules=merged)


def _axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_spec(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
                 mesh: Mesh, rules: ShardingRules) -> P:
    sizes = _axis_sizes(mesh)
    used = set()
    out = []
    for name, dim in zip(axes, shape):
        entry = rules.rules.get(name, ()) if name else ()
        mesh_axes = tuple(a for a in entry if a in sizes and a not in used)
        if not mesh_axes:
            out.append(None)
            continue
        n = math.prod(sizes[a] for a in mesh_axes)
        if n <= 1:
            out.append(None)
            continue
        waste = (-(-dim // n) * n) / max(dim, 1)
        if dim % n != 0 and waste > rules.pad_tolerance:
            out.append(None)
            continue
        used.update(mesh_axes)
        out.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_shardings(mesh: Mesh, axes_tree, shape_tree, rules: ShardingRules):
    """Parallel trees of logical axes + shapes -> NamedSharding tree."""
    def leaf(axes, shp):
        shape = shp.shape if hasattr(shp, "shape") else tuple(shp)
        return NamedSharding(mesh, resolve_spec(tuple(axes), shape, mesh, rules))
    return jax.tree.map(leaf, axes_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


# Activation constraint points used inside models (name -> logical axes).
_ACT_AXES = {
    "acts": ("batch", "act_seq", "embed"),
    "acts_qkv": ("batch", "seq", "heads", "head_dim"),
    "acts_kv": ("batch", "seq", "kv_heads", "head_dim"),
    "acts_kv_repl": ("batch", "seq", None, "head_dim"),  # batch-only
    "moe_disp": ("batch", None, "experts", None),   # (G, gs, E, C)
    "moe_xe": ("batch", "experts", None, None),     # (G, E, C, D)
    "decode_scores": ("batch", None, None, "cache_seq"),  # (B, H, 1, S)
    "decode_scores5": ("batch", None, None, None, "cache_seq"),  # grouped
    "logits": ("batch", "seq", "vocab"),
}


# Activation-sharding schemes (see EXPERIMENTS.md §Perf):
#   "sp"       — residual stream sharded over model on the seq dim
#                (Megatron sequence parallelism); attention internals left
#                to GSPMD propagation (no q/k/v constraints).
#   "sp_heads" — sp + forced head sharding of q/k/v (induces reshards
#                between seq- and head-sharded layouts).
#   "tp"       — replicated-seq residual + head-sharded attention
#                (classic tensor parallelism; high activation memory).
#   "dp"       — no tensor parallelism: the model axis joins the batch
#                axis (256-way DP) and parameters are FSDP-sharded over
#                `model` (GSPMD all-gathers them per scan step). The right
#                choice for models whose per-layer weights are smaller
#                than the per-layer activation traffic TP would move.
SCHEMES = ("sp", "sp_heads", "tp", "dp")


def scheme_rules(scheme: str, rules: Optional[ShardingRules] = None) -> ShardingRules:
    rules = rules or ShardingRules()
    if scheme == "tp":
        return rules.replace(act_seq=())
    if scheme == "dp":
        # vocab stays model-sharded: a 200k-vocab fp32 logits tensor must
        # never materialize unsharded (phi4: 25 GiB of softmax temps)
        return rules.replace(
            batch=("pod", "data", "model"), act_seq=(),
            mlp=(), heads=(), heads_flat=(), kv_flat=(),
            experts=(), fsdp=("model",))
    return rules


def fsdp_axes(axes_tree, shape_tree, mesh: Mesh):
    """Rewrite param logical axes for the "dp" scheme: shard the first
    model-axis-divisible dim of every tensor as "fsdp" (ZeRO-3 over the
    model axis; GSPMD inserts the per-layer all-gathers)."""
    sizes = _axis_sizes(mesh)
    n = sizes.get("model", 1)

    def leaf(axes, shp):
        shape = shp.shape if hasattr(shp, "shape") else tuple(shp)
        if n <= 1:
            return axes
        for i, (name, dim) in enumerate(zip(axes, shape)):
            if dim % n == 0 and dim >= n:
                new = list(axes)
                new[i] = "fsdp"
                return tuple(new)
        return axes

    return jax.tree.map(
        leaf, axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def make_sharder(mesh: Optional[Mesh], rules: Optional[ShardingRules] = None,
                 scheme: str = "sp"):
    """Returns the ``sharder(name, shape)`` hook consumed by StackModel."""
    if mesh is None:
        return None
    assert scheme in SCHEMES, scheme
    rules = scheme_rules(scheme, rules)
    rules = dataclasses.replace(rules, pad_tolerance=4.0 / 3.0)
    names = ({"acts", "acts_kv_repl", "moe_disp", "moe_xe", "decode_scores",
              "decode_scores5"}
             if scheme in ("sp", "dp") else set(_ACT_AXES))

    def sharder(name: str, shape: Tuple[int, ...]):
        axes = _ACT_AXES.get(name)
        if name not in names or axes is None or len(axes) != len(shape):
            return None
        return NamedSharding(mesh, resolve_spec(axes, shape, mesh, rules))

    return sharder


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
