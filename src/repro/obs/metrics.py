"""Metrics registry: counters, gauges, and fixed-bucket latency histograms.

Dependency-free (stdlib only) telemetry primitives for the serving
stack. Design constraints, in order:

1. **Warm-path overhead < 3%** (gated by ``benchmarks/bench_obs.py``).
   Counters and gauges are deliberately *unlocked*: every counter in the
   serving stack was previously a plain ``int`` field mutated under its
   owner's lock (``AbacusServer._cond``, ``ClusterFrontend._route_lock``,
   ``PredictionService._lock``), and that synchronization contract is
   unchanged — the metric object just gives the same int a stable name
   and an exposition path. Histograms *are* internally locked (they are
   fed from batch contexts via :meth:`Histogram.observe_many`, one lock
   round per tick, not per query), and defer the per-value bucket fold
   to the reader (``snapshot``/``percentile``) so the serving tick only
   pays one buffered list append.

2. **Order-independent merging.** A fleet snapshot is the merge of every
   replica's snapshot, arriving in whatever order the wire delivers
   them. Counters merge by sum, gauges by max, histograms by element-wise
   bucket addition — all commutative and associative, so
   :func:`merge_snapshots` is order-independent (property-tested in
   ``tests/test_obs.py``).

3. **Exact local quantiles.** Each histogram keeps a bounded window of
   raw samples alongside its buckets: ``percentile()`` on a live
   histogram is exact over the most recent ``window`` observations
   (nearest-rank). Merged snapshots no longer have raw samples, so their
   quantiles come from bucket interpolation (:func:`quantile_from_buckets`).

The registry can be constructed with ``enabled=False``: counters and
gauges keep working (server logic depends on tick numbering etc.), but
callers are expected to skip histogram observes and span recording when
``registry.enabled`` is false — that is the "registry-disabled" baseline
the < 3% overhead gate compares against.
"""
from __future__ import annotations

import bisect
import math
import threading
from collections import deque
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "DEFAULT_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "CounterDict",
    "merge_snapshots",
    "quantile_from_buckets",
    "render_prometheus",
]

# Log-spaced latency bounds (seconds): 10 us .. 60 s, ~1-2.5-5 ladder.
# Chosen once, shared fleet-wide, so bucket merges are always aligned.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-5, 2.5e-5, 5e-5,
    1e-4, 2.5e-4, 5e-4,
    1e-3, 2.5e-3, 5e-3,
    1e-2, 2.5e-2, 5e-2,
    1e-1, 2.5e-1, 5e-1,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)

_QUANTILES = (("p50", 0.50), ("p95", 0.95), ("p99", 0.99))


class Counter:
    """Monotonic-by-convention counter. Unlocked: callers synchronize
    exactly as they did when this was a bare int field (see module
    docstring). Supports ``+=`` through the owning stats object."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def set(self, value) -> None:
        self.value = value

    def snapshot(self) -> Dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Point-in-time value (high-water marks, queue depths). Unlocked,
    same contract as :class:`Counter`. Merges by max."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self.value = 0

    def set(self, value) -> None:
        self.value = value

    def snapshot(self) -> Dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket latency histogram with an exact-quantile window.

    Thread-safe. ``observe_many`` amortizes the lock to one round per
    micro-batch tick AND defers the per-value bucket fold: observed
    values land in a pending buffer (one list append), and the
    bisect-per-value work happens when a *reader* asks — ``snapshot()``
    / ``percentile()`` — or every ``FLUSH_AT`` buffered values,
    whichever comes first. Warm serving ticks pay list-append cost; the
    metrics scraper pays the fold, off the hot path. Bucket bounds are
    upper-inclusive (`v <= le[i]`), with an implicit +Inf overflow
    bucket at ``counts[-1]``.
    """

    __slots__ = ("name", "help", "le", "counts", "count", "sum",
                 "min", "max", "_window", "_lock", "_pending",
                 "_pending_n")

    FLUSH_AT = 4096  # bounds pending-buffer memory between scrapes

    def __init__(self, name: str, buckets: Sequence[float] = DEFAULT_BUCKETS,
                 help: str = "", window: int = 2048) -> None:
        self.name = name
        self.help = help
        self.le = tuple(float(b) for b in buckets)
        if list(self.le) != sorted(set(self.le)):
            raise ValueError("histogram bounds must be strictly increasing")
        self.counts = [0] * (len(self.le) + 1)
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._window: deque = deque(maxlen=int(window))
        self._pending: List[List[float]] = []
        self._pending_n = 0
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        self.observe_many((value,))

    def observe_many(self, values: Iterable[float]) -> None:
        vals = [float(v) for v in values]
        if not vals:
            return
        with self._lock:
            self._pending.append(vals)
            self._pending_n += len(vals)
            if self._pending_n >= self.FLUSH_AT:
                self._flush_locked()

    def _flush_locked(self) -> None:
        """Fold buffered observations into buckets; lock held."""
        if not self._pending_n:
            return
        le, counts, bl = self.le, self.counts, bisect.bisect_left
        for vals in self._pending:
            for v in vals:
                counts[bl(le, v)] += 1
            self.sum += sum(vals)
            self.count += len(vals)
            lo, hi = min(vals), max(vals)
            if self.min is None or lo < self.min:
                self.min = lo
            if self.max is None or hi > self.max:
                self.max = hi
            self._window.extend(vals)
        self._pending = []
        self._pending_n = 0

    def percentile(self, q: float) -> Optional[float]:
        """Exact nearest-rank quantile over the raw-sample window (the
        most recent ``window`` observations). None when empty."""
        with self._lock:
            self._flush_locked()
            samples = sorted(self._window)
        if not samples:
            return None
        rank = max(1, math.ceil(q * len(samples)))
        return samples[min(rank, len(samples)) - 1]

    def snapshot(self) -> Dict:
        with self._lock:
            self._flush_locked()
            snap = {
                "type": "histogram",
                "le": list(self.le),
                "counts": list(self.counts),
                "count": self.count,
                "sum": self.sum,
                "min": self.min,
                "max": self.max,
            }
            samples = sorted(self._window)
        for key, q in _QUANTILES:
            if samples:
                rank = max(1, math.ceil(q * len(samples)))
                snap[key] = samples[min(rank, len(samples)) - 1]
            else:
                snap[key] = None
        return snap


def quantile_from_buckets(le: Sequence[float], counts: Sequence[int],
                          q: float, hi: Optional[float] = None) -> Optional[float]:
    """Prometheus-style linear interpolation inside the target bucket.

    Used for *merged* snapshots, where raw samples are gone and buckets
    are all that survives the wire. ``hi`` optionally clamps the
    overflow bucket's upper edge (e.g. the merged max)."""
    total = sum(counts)
    if total <= 0:
        return None
    target = q * total
    cum = 0
    for i, c in enumerate(counts):
        if c == 0:
            continue
        lo = le[i - 1] if i > 0 else 0.0
        up = le[i] if i < len(le) else (hi if hi is not None else le[-1])
        if cum + c >= target:
            frac = (target - cum) / c
            return lo + (up - lo) * min(1.0, max(0.0, frac))
        cum += c
    return le[-1] if hi is None else hi


class MetricsRegistry:
    """Named metric store. ``counter``/``gauge``/``histogram`` are
    idempotent by name: asking twice returns the same object, which is
    how ``ServerStats`` and the exposition plane share one underlying
    int. Callback sources contribute computed gauges (cache sizes,
    queue depth) at snapshot time only — zero hot-path cost."""

    def __init__(self, enabled: bool = True, namespace: str = "abacus") -> None:
        self.enabled = bool(enabled)
        self.namespace = namespace
        self._metrics: Dict[str, object] = {}
        self._callbacks: List[Callable[[], Dict[str, float]]] = []
        self._lock = threading.Lock()

    def _get(self, name: str, cls, **kwargs):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, **kwargs)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, buckets: Sequence[float] = DEFAULT_BUCKETS,
                  help: str = "", window: int = 2048) -> Histogram:
        return self._get(name, Histogram, buckets=buckets, help=help,
                         window=window)

    def register_callback(self, fn: Callable[[], Dict[str, float]]) -> None:
        with self._lock:
            self._callbacks.append(fn)

    def snapshot(self) -> Dict[str, Dict]:
        """JSON-safe snapshot of every metric; callback gauges included.
        Sorted by name so renderings are deterministic."""
        with self._lock:
            metrics = dict(self._metrics)
            callbacks = list(self._callbacks)
        out = {name: m.snapshot() for name, m in sorted(metrics.items())}
        for fn in callbacks:
            try:
                computed = fn()
            except Exception:
                continue
            for name, value in computed.items():
                out[name] = {"type": "gauge", "value": value}
        return out


def merge_snapshots(snaps: Sequence[Dict[str, Dict]]) -> Dict[str, Dict]:
    """Merge registry snapshots: counters sum, gauges max, histogram
    buckets add element-wise. Commutative + associative, so the result
    is independent of replica order. Merged histogram quantiles are
    recomputed from the merged buckets (interpolated, not exact)."""
    merged: Dict[str, Dict] = {}
    for snap in snaps:
        for name, m in snap.items():
            cur = merged.get(name)
            if cur is None:
                merged[name] = dict(m)
                continue
            kind = m.get("type")
            if kind != cur.get("type"):
                continue  # type clash across replicas: first one wins
            if kind == "counter":
                cur["value"] = cur["value"] + m["value"]
            elif kind == "gauge":
                cur["value"] = max(cur["value"], m["value"])
            elif kind == "histogram":
                if list(m["le"]) != list(cur["le"]):
                    continue  # misaligned bounds cannot be added
                cur["counts"] = [a + b for a, b in
                                 zip(cur["counts"], m["counts"])]
                cur["count"] = cur["count"] + m["count"]
                cur["sum"] = cur["sum"] + m["sum"]
                mins = [v for v in (cur["min"], m["min"]) if v is not None]
                maxs = [v for v in (cur["max"], m["max"]) if v is not None]
                cur["min"] = min(mins) if mins else None
                cur["max"] = max(maxs) if maxs else None
    for m in merged.values():
        if m.get("type") == "histogram":
            for key, q in _QUANTILES:
                m[key] = quantile_from_buckets(m["le"], m["counts"], q,
                                               hi=m.get("max"))
    return merged


def _prom_num(v) -> str:
    if v is None:
        return "NaN"
    if isinstance(v, float):
        if math.isinf(v):
            return "+Inf" if v > 0 else "-Inf"
        return repr(v)
    return str(v)


def render_prometheus(snapshot: Dict[str, Dict],
                      namespace: str = "abacus") -> str:
    """Render a snapshot (live or merged) as Prometheus text exposition.
    Histogram buckets are emitted cumulatively with ``le`` labels, plus
    ``_sum``/``_count`` series, per the exposition format."""
    lines: List[str] = []
    prefix = f"{namespace}_" if namespace else ""
    for name in sorted(snapshot):
        m = snapshot[name]
        full = prefix + name
        kind = m.get("type", "untyped")
        lines.append(f"# TYPE {full} {kind}")
        if kind == "histogram":
            cum = 0
            for le, c in zip(m["le"], m["counts"]):
                cum += c
                lines.append(f'{full}_bucket{{le="{_prom_num(float(le))}"}} {cum}')
            cum += m["counts"][-1]
            lines.append(f'{full}_bucket{{le="+Inf"}} {cum}')
            lines.append(f"{full}_sum {_prom_num(m['sum'])}")
            lines.append(f"{full}_count {m['count']}")
        else:
            lines.append(f"{full} {_prom_num(m.get('value'))}")
    return "\n".join(lines) + "\n"


class CounterDict:
    """Registry-backed mapping with the exact mutation surface of the
    plain dict it replaces (``d[k] += 1``, ``dict(d)``, ``d.keys()``),
    so ``ClusterFrontend.reshard_stats`` keeps its wire shape while the
    counters gain metric names and show up in snapshots."""

    def __init__(self, registry: MetricsRegistry, prefix: str,
                 names: Sequence[str]) -> None:
        self._names = tuple(names)
        self._counters = {n: registry.counter(f"{prefix}{n}_total")
                          for n in self._names}

    def __getitem__(self, key: str) -> int:
        return self._counters[key].value

    def __setitem__(self, key: str, value: int) -> None:
        self._counters[key].set(value)

    def __contains__(self, key: str) -> bool:
        return key in self._counters

    def __iter__(self):
        return iter(self._names)

    def __len__(self) -> int:
        return len(self._names)

    def keys(self):
        return list(self._names)

    def items(self):
        return [(n, self._counters[n].value) for n in self._names]

    def values(self):
        return [self._counters[n].value for n in self._names]

    def get(self, key: str, default=None):
        c = self._counters.get(key)
        return default if c is None else c.value

    def as_dict(self) -> Dict[str, int]:
        return {n: self._counters[n].value for n in self._names}

    def __repr__(self) -> str:
        return f"CounterDict({self.as_dict()!r})"
