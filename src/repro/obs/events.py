"""Structured JSONL event log for fleet lifecycle events.

Replaces ad-hoc ``print`` statements with a single, thread-safe emitter.
Every event is one JSON object per line::

    {"event": "exclusion", "ts": 1723105000.123, "pid": 4242, "replica": "r2", ...}

Canonical event kinds emitted by the serving stack:

==================  ======================================================
``ready``           replica child process finished binding (rpc handshake)
``gen_swap``        a server adopted a new ModelGeneration
``reshard``         frontend completed a resize/exclusion reshard
``exclusion``       a dead replica was excluded from the ring
``replica_dead``    heartbeat/EOF death verdict for a remote replica
``refit``           OnlineRefitter published a new generation
``refit_failed``    a refit cycle raised
``scenario_start``  ScenarioRunner began replaying a schedule
``scenario_fault``  a scheduled fault event fired (publish/kill/resize)
``scenario_end``    replay finished (ground-truth counter summary)
==================  ======================================================

Events always land in an in-memory ring buffer (``tail()``); optionally
they are appended to a JSONL file (``configure(path=...)``) or written
to a stream. File writes happen line-at-a-time in append mode, so
multiple processes sharing one path interleave whole lines.

A module-level default log backs the convenience functions
:func:`emit` / :func:`configure` / :func:`tail`; components call
``events.emit(...)`` without threading a logger through every
constructor. **Do not** point a replica child's event stream at its
stdout pipe beyond the ready handshake: the parent stops draining stdout
after the ready line, and a filled pipe would wedge the child.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Dict, List, Optional

__all__ = ["EventLog", "emit", "configure", "tail", "clear"]


class EventLog:
    def __init__(self, path: Optional[str] = None, stream=None,
                 maxlen: int = 2048) -> None:
        self._lock = threading.Lock()
        self._buf: deque = deque(maxlen=int(maxlen))
        self._stream = stream
        self._path: Optional[str] = None
        self._fh = None
        if path:
            self.configure(path=path)

    def configure(self, path: Optional[str] = None, stream=None) -> None:
        """Point the log at a JSONL file and/or a stream. ``path=None``
        detaches the file; the ring buffer is always on."""
        with self._lock:
            if self._fh is not None:
                try:
                    self._fh.close()
                except Exception:
                    pass
                self._fh = None
            self._path = path
            if path:
                self._fh = open(path, "a", encoding="utf-8")
            if stream is not None:
                self._stream = stream

    @property
    def path(self) -> Optional[str]:
        return self._path

    def emit(self, event: str, **fields) -> Dict:
        rec = {"event": str(event), "ts": time.time(), "pid": os.getpid()}
        rec.update(fields)
        line = json.dumps(rec, separators=(",", ":"), default=str)
        with self._lock:
            self._buf.append(rec)
            if self._fh is not None:
                try:
                    self._fh.write(line + "\n")
                    self._fh.flush()
                except Exception:
                    pass
            if self._stream is not None:
                try:
                    self._stream.write(line + "\n")
                    self._stream.flush()
                except Exception:
                    pass
        return rec

    def tail(self, n: Optional[int] = None) -> List[Dict]:
        with self._lock:
            recs = list(self._buf)
        return recs if n is None else recs[-n:]

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                try:
                    self._fh.close()
                except Exception:
                    pass
                self._fh = None
            self._stream = None


DEFAULT = EventLog()


def emit(event: str, **fields) -> Dict:
    return DEFAULT.emit(event, **fields)


def configure(path: Optional[str] = None, stream=None) -> None:
    DEFAULT.configure(path=path, stream=stream)


def tail(n: Optional[int] = None) -> List[Dict]:
    return DEFAULT.tail(n)


def clear() -> None:
    DEFAULT.clear()
