"""repro.obs — fleet telemetry plane (metrics, tracing, events).

Dependency-free observability for the serving stack:

- :mod:`repro.obs.metrics` — registry of counters/gauges/histograms with
  order-independent snapshot merging and Prometheus text rendering.
- :mod:`repro.obs.tracing` — per-query spans with cross-process trace
  context (carried in RPC submit frames).
- :mod:`repro.obs.events` — structured JSONL event log for lifecycle
  events (gen swaps, reshards, exclusions, refits, heartbeat deaths).
"""
from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    Counter,
    CounterDict,
    Gauge,
    Histogram,
    MetricsRegistry,
    merge_snapshots,
    quantile_from_buckets,
    render_prometheus,
)
from repro.obs.tracing import SpanSink, make_span, new_context, new_id
from repro.obs.events import EventLog
from repro.obs import events

__all__ = [
    "DEFAULT_BUCKETS",
    "Counter",
    "CounterDict",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "merge_snapshots",
    "quantile_from_buckets",
    "render_prometheus",
    "SpanSink",
    "make_span",
    "new_context",
    "new_id",
    "EventLog",
    "events",
]
