"""Per-query tracing: spans for every lifecycle stage of a query.

A *trace context* is a tiny dict ``{"trace": <id>, "span": <root id>}``
attached to a :class:`~repro.serve.prediction_service.Query` and carried
verbatim inside the RPC submit frame, so the stages a query passes
through — frontend routing, a remote replica's tick, a hedge re-issued
to a different process — all stamp spans with the same trace id. Server-
side spans ride back to the frontend inside the estimate dict under the
``"_trace"`` key; the frontend harvests them into its
:class:`SpanSink`, yielding one coherent cross-process trace.

Span taxonomy (``name`` field):

==============  =============================================
``submit``      root span; frontend accepted the query
``route``       ring lookup chose a replica (attrs: replica)
``queue_wait``  time between enqueue and its tick starting
``tick_batch``  the micro-batch tick that served the query
``cold_trace``  record resolution ran cold jaxpr traces
``ensemble``    the tick's single ensemble pass
``reply``       estimate resolution back onto the future
``hedge``       duplicate issued to the next ring owner
``retry``       re-submit after a replica failure
``replay``      re-submit after parking across a cutover
==============  =============================================

Spans are plain dicts (JSON-safe by construction): ``trace``, ``span``,
``parent``, ``name``, ``ts`` (wall epoch seconds), ``dur_s``, ``pid``,
plus optional ``attrs``. No clock sync is attempted across processes;
``ts`` values are per-host wall clocks and ``dur_s`` comes from
``perf_counter`` deltas.
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque
from typing import Dict, List, Optional

__all__ = ["new_id", "new_context", "make_span", "SpanSink"]


def new_id() -> str:
    """64-bit random hex id (trace or span)."""
    return os.urandom(8).hex()


def new_context() -> Dict[str, str]:
    """Fresh trace context: the root span id doubles as the parent for
    every stage span recorded downstream."""
    return {"trace": new_id(), "span": new_id()}


def make_span(trace: str, name: str, dur_s: float, *,
              parent: Optional[str] = None, ts: Optional[float] = None,
              span_id: Optional[str] = None, **attrs) -> Dict:
    span = {
        "trace": trace,
        "span": span_id if span_id is not None else new_id(),
        "parent": parent,
        "name": name,
        "ts": time.time() if ts is None else ts,
        "dur_s": float(dur_s),
        "pid": os.getpid(),
    }
    if attrs:
        span["attrs"] = attrs
    return span


class SpanSink:
    """Bounded, thread-safe span buffer. One per frontend/server; holds
    the most recent ``maxlen`` spans for inspection and test assertions.
    Tracing is opt-in per query, so in practice this holds the spans of
    explicitly traced queries, not the whole stream."""

    def __init__(self, maxlen: int = 4096) -> None:
        self._spans: deque = deque(maxlen=int(maxlen))
        self._lock = threading.Lock()

    def record(self, span: Dict) -> None:
        with self._lock:
            self._spans.append(span)

    def extend(self, spans) -> None:
        with self._lock:
            self._spans.extend(spans)

    def for_trace(self, trace_id: str) -> List[Dict]:
        """All spans of one trace, ordered by start timestamp."""
        with self._lock:
            spans = [s for s in self._spans if s.get("trace") == trace_id]
        return sorted(spans, key=lambda s: (s.get("ts", 0.0), s.get("name", "")))

    def snapshot(self) -> List[Dict]:
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)
