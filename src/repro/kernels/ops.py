"""Jitted wrappers: model-facing entry points for the Pallas kernels.

These adapt model-layout tensors to kernel layouts, pick block sizes, and
fall back to the reference for shapes the kernels do not tile (tiny or
ragged extents during smoke tests).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import ref as ref_lib
from repro.kernels import rmsnorm as rn
from repro.kernels import ssd_scan as ssd


def _pick_block(s: int, target: int) -> int:
    b = min(target, s)
    while s % b:
        b //= 2
    return max(b, 1)


@partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q, k, v, causal: bool = True, interpret: bool = False):
    """Model layout: q (B,S,H,hd), k/v (B,S,H,hd) (pre-expanded GQA)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * h, sk, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * h, sk, hd)
    bq = _pick_block(sq, 128)
    bk = _pick_block(sk, 128)
    o = fa.flash_attention_bhsd(qf, kf, vf, causal=causal, block_q=bq,
                                block_k=bk, interpret=interpret)
    return jnp.moveaxis(o.reshape(b, h, sq, hd), 1, 2)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xb, dt, a_neg, bmat, cmat, chunk: int, interpret: bool = False):
    """Model layout: xb (B,L,H,P), dt (B,L,H), bmat/cmat (B,L,N).

    Returns (y (B,L,H,P), final_state (B,H,N,P)) matching
    ``repro.models.ssm.ssd_chunked_ref``. The final state (needed only by
    prefill) is reconstructed with one extra lightweight pass.
    """
    xh = jnp.moveaxis(xb, 1, 2)   # (B,H,L,P)
    dth = jnp.moveaxis(dt, 1, 2)  # (B,H,L)
    y = ssd.ssd_scan_bhlp(xh, dth, a_neg, bmat, cmat, chunk,
                          interpret=interpret)
    y = jnp.moveaxis(y, 1, 2)
    # final state: cheap closed form over the full sequence (O(L·N·P))
    loga = dth.astype(jnp.float32) * a_neg[None, :, None]  # (B,H,L)
    cum = jnp.cumsum(loga, axis=-1)
    w_end = jnp.exp(cum[..., -1:] - cum)  # (B,H,L)
    s = jnp.einsum("bhl,bln,blhp->bhnp", w_end, bmat.astype(jnp.float32),
                   jnp.moveaxis(xh, 1, 2).astype(jnp.float32))
    return y, s


@partial(jax.jit, static_argnames=("interpret",))
def rmsnorm(x, gain, interpret: bool = False):
    """x (..., D) -> normalized; flattens leading dims for the row kernel."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    r = x2.shape[0]
    br = _pick_block(r, 256)
    y = rn.rmsnorm_2d(x2, gain, block_rows=br, interpret=interpret)
    return y.reshape(shape)
