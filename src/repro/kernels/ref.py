"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """q (BH,Sq,d), k/v (BH,Sk,d) -> (BH,Sq,d); fp32 softmax."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(d))
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(xb, dt, a_neg, bmat, cmat, chunk: int):
    """Same contract as kernels.ssd_scan.ssd_scan_bhlp: xb (B,H,L,P)."""
    from repro.models.ssm import ssd_chunked_ref
    y, _ = ssd_chunked_ref(jnp.moveaxis(xb, 1, 2), jnp.moveaxis(dt, 1, 2),
                           a_neg, bmat, cmat, chunk)
    return jnp.moveaxis(y, 2, 1)


def rmsnorm_ref(x, gain, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)
            * gain.astype(jnp.float32)).astype(x.dtype)
