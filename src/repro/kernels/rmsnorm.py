"""Fused RMSNorm row kernel (Pallas TPU).

One pass per row block: mean-of-squares reduction and the scaled
normalization fused in VMEM — on TPU this saves a full HBM round trip of
the activation tensor versus the unfused (reduce, then multiply) pair.
Rows are tiled (block_rows x D) with D resident; fp32 statistics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_2d(x, gain, eps: float = 1e-6, block_rows: int = 256,
               interpret: bool = False):
    """x (R, D), gain (D,) -> (R, D)."""
    r, d = x.shape
    block_rows = min(block_rows, r)
    assert r % block_rows == 0, (r, block_rows)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(r // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, gain)
