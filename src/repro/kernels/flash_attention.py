"""Flash attention (blockwise online softmax) as a Pallas TPU kernel.

TPU-native tiling: the grid is (batch*heads, q_blocks, k_blocks) with the
k-block axis innermost (sequential on a TPU core), so the running softmax
statistics and the output accumulator live in VMEM scratch across the
k-sweep. Block shapes are MXU-aligned (block_q x head_dim and
block_k x head_dim tiles, multiples of 128 on the sequence axes). The
S x S score matrix never exists: peak VMEM is
O(block_q*(head_dim + block_k)) per core.

For causal masking, k-blocks strictly above the diagonal skip their
compute entirely (``pl.when``); the diagonal block masks with 2D iota.

Validated against ``repro.kernels.ref.attention_ref`` in interpret mode
(CPU) across shape/dtype sweeps; on real TPU hardware this is the
``--attention=pallas`` path of the models.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                 *, scale: float, causal: bool, block_q: int, block_k: int,
                 num_k_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    run = True
    if causal:
        # skip blocks strictly above the diagonal
        run = k_start <= q_start + block_q - 1

    @pl.when(run if causal else True)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale     # (bq, d)
        k = k_ref[0].astype(jnp.float32)             # (bk, d)
        v = v_ref[0].astype(jnp.float32)             # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False):
    """q (BH, Sq, d), k/v (BH, Sk, d) -> (BH, Sq, d)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    nq, nk = sq // block_q, sk // block_k
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, num_k_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
