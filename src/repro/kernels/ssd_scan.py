"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

Grid (batch, heads, chunks) with the chunk axis innermost: the carried
SSD state (N x P, fp32) lives in VMEM scratch across the sequential chunk
sweep, exactly the recurrence structure of the SSD algorithm. Each chunk
step does three MXU matmuls — C·Bᵀ (Q x Q), the masked-decay intra-chunk
product (Q x P), and the state update (N x P) — on VMEM-resident tiles,
so HBM traffic per chunk is the operand tiles only.

Inputs are pre-scaled x̄ = x·dt and pre-activated B/C (the layer applies
conv+SiLU before the scan). Decay math is fp32 in-kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref,
                *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q,)
    a_neg = a_ref[0]                              # () per-head A (negative)
    bm = b_ref[0, 0].astype(jnp.float32)          # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)          # (Q, N)

    loga = dt * a_neg                             # (Q,) <= 0
    cum = jnp.cumsum(loga)                        # (Q,)

    # intra-chunk: (C B^T * decay) x
    seg = cum[:, None] - cum[None, :]             # (Q, Q)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(rows >= cols, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_intra = jax.lax.dot_general(cb * decay, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: C_t . (exp(cum_t) * S_prev)
    s_prev = state_ref[...]                       # (N, P)
    cs = jax.lax.dot_general(cm, s_prev, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_inter = jnp.exp(cum)[:, None] * cs

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S = exp(cum_end) * S_prev + sum_s w_s B_s x_s^T
    w_end = jnp.exp(cum[-1] - cum)                # (Q,)
    s_new = (jnp.exp(cum[-1]) * s_prev
             + jax.lax.dot_general(bm * w_end[:, None], x,
                                   (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    state_ref[...] = s_new


def ssd_scan_bhlp(xb, dt, a_neg, bmat, cmat, chunk: int,
                  interpret: bool = False):
    """xb (B,H,L,P); dt (B,H,L); a_neg (H,); bmat/cmat (B,L,N).

    Returns y (B,H,L,P). (The final state is recomputed by callers that
    need it via the reference path; the train path only needs y.)
    """
    b, h, l, p = xb.shape
    n = bmat.shape[-1]
    chunk = min(chunk, l)
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, 0, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, 0, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p),
                               lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, l, p), xb.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xb, dt, a_neg, bmat[:, None], cmat[:, None])
