"""Fault tolerance: retrying step runner, straggler detection, watchdog.

Straggler detection uses DNNAbacus as its reference: if the cost
predictor has been fit for this platform, a step whose wall time exceeds
``straggler_factor x`` the *predicted* step time is flagged (the paper's
scheduling use-case, applied online). Without a predictor the detector
falls back to a running median.

On a multi-host deployment, ``on_straggler``/``on_failure`` hooks feed
the cluster controller (re-slice the data axis and restart from the last
atomic checkpoint — see repro.ckpt). Everything here is host-local and
unit-testable.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


class StepFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FTConfig:
    max_retries: int = 2
    straggler_factor: float = 3.0
    watchdog_timeout_s: Optional[float] = None
    min_history: int = 5


class StepRunner:
    """Wraps a step callable with retries + timing + straggler flags."""

    def __init__(self, step_fn: Callable, cfg: FTConfig = FTConfig(),
                 predicted_step_s: Optional[float] = None,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.step_fn = step_fn
        self.cfg = cfg
        self.predicted = predicted_step_s
        self.on_straggler = on_straggler
        self.history: List[float] = []
        self.retries = 0
        self.stragglers = 0

    def _reference_time(self) -> Optional[float]:
        if self.predicted is not None:
            return self.predicted
        if len(self.history) >= self.cfg.min_history:
            s = sorted(self.history[-50:])
            return s[len(s) // 2]
        return None

    def __call__(self, *args):
        last_err = None
        for attempt in range(self.cfg.max_retries + 1):
            t0 = time.perf_counter()
            try:
                out = self.step_fn(*args)
                out = jax_block(out)
                dt = time.perf_counter() - t0
                ref = self._reference_time()
                if (ref is not None and dt > self.cfg.straggler_factor * ref):
                    self.stragglers += 1
                    if self.on_straggler:
                        self.on_straggler(len(self.history), dt)
                if (self.cfg.watchdog_timeout_s
                        and dt > self.cfg.watchdog_timeout_s):
                    raise StepFailure(
                        f"watchdog: step took {dt:.1f}s "
                        f"> {self.cfg.watchdog_timeout_s}s")
                self.history.append(dt)
                return out
            except StepFailure:
                raise
            except Exception as e:  # transient device/runtime errors
                last_err = e
                self.retries += 1
        raise StepFailure(
            f"step failed after {self.cfg.max_retries + 1} attempts") from last_err


def jax_block(out):
    import jax
    return jax.block_until_ready(out)


@dataclasses.dataclass
class FailureInjector:
    """Deterministic fault injection for tests: raises on listed calls."""

    fail_on_calls: tuple = ()
    exception: type = RuntimeError
    calls: int = 0

    def wrap(self, fn):
        def inner(*args):
            self.calls += 1
            if self.calls in self.fail_on_calls:
                raise self.exception(f"injected failure at call {self.calls}")
            return fn(*args)
        return inner
