"""CART regression trees (variance-reduction splits), vectorized numpy.

The building block for the RF / Extra-Trees / GBDT models in the
AutoML-lite pool (the image has no sklearn). Split search per node is
O(F' · N log N) using sorted prefix sums; ``max_features`` subsamples
features per split (random forest), ``random_splits`` draws thresholds
uniformly instead of scanning (extra-trees).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class TreeConfig:
    max_depth: int = 12
    min_samples_leaf: int = 2
    min_samples_split: int = 4
    max_features: Optional[float] = None  # fraction of features per split
    random_splits: bool = False           # extra-trees style thresholds


class _Node:
    __slots__ = ("feature", "threshold", "left", "right", "value")

    def __init__(self, value=0.0):
        self.feature = -1
        self.threshold = 0.0
        self.left = None
        self.right = None
        self.value = value


class DecisionTreeRegressor:
    def __init__(self, cfg: TreeConfig = TreeConfig(), seed: int = 0):
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)
        self.root: Optional[_Node] = None

    # -- fitting -----------------------------------------------------------
    def fit(self, x: np.ndarray, y: np.ndarray) -> "DecisionTreeRegressor":
        x = np.asarray(x, np.float64)
        y = np.asarray(y, np.float64)
        self.n_features = x.shape[1]
        self.root = self._build(x, y, depth=0)
        return self

    def _feature_subset(self) -> np.ndarray:
        f = self.n_features
        if self.cfg.max_features is None:
            return np.arange(f)
        k = max(1, int(round(self.cfg.max_features * f)))
        return self.rng.choice(f, size=k, replace=False)

    def _best_split(self, x, y):
        n = len(y)
        best = (None, None, 0.0)  # feature, threshold, gain
        base = np.var(y) * n
        if base <= 1e-18:
            return best
        msl = self.cfg.min_samples_leaf
        for j in self._feature_subset():
            col = x[:, j]
            if self.cfg.random_splits:
                lo, hi = col.min(), col.max()
                if hi <= lo:
                    continue
                thr = self.rng.uniform(lo, hi)
                mask = col <= thr
                nl = int(mask.sum())
                if nl < msl or n - nl < msl:
                    continue
                yl, yr = y[mask], y[~mask]
                gain = base - (np.var(yl) * nl + np.var(yr) * (n - nl))
                if best[2] < gain:
                    best = (j, thr, gain)
                continue
            order = np.argsort(col, kind="stable")
            cs, ys = col[order], y[order]
            csum = np.cumsum(ys)
            csum2 = np.cumsum(ys * ys)
            nl = np.arange(1, n)
            valid = (cs[1:] > cs[:-1]) & (nl >= msl) & ((n - nl) >= msl)
            if not valid.any():
                continue
            sl, sl2 = csum[:-1], csum2[:-1]
            sr, sr2 = csum[-1] - sl, csum2[-1] - sl2
            sse = (sl2 - sl * sl / nl) + (sr2 - sr * sr / (n - nl))
            sse = np.where(valid, sse, np.inf)
            i = int(np.argmin(sse))
            gain = base - sse[i]
            if np.isfinite(sse[i]) and gain > best[2]:
                best = (j, (cs[i] + cs[i + 1]) / 2.0, gain)
        return best

    def _build(self, x, y, depth):
        node = _Node(float(np.mean(y)))
        if (depth >= self.cfg.max_depth
                or len(y) < self.cfg.min_samples_split):
            return node
        j, thr, gain = self._best_split(x, y)
        if j is None or gain <= 1e-18:
            return node
        mask = x[:, j] <= thr
        node.feature = int(j)
        node.threshold = float(thr)
        node.left = self._build(x[mask], y[mask], depth + 1)
        node.right = self._build(x[~mask], y[~mask], depth + 1)
        return node

    # -- inference ----------------------------------------------------------
    def predict(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float64)
        out = np.empty(len(x), np.float64)
        # iterative per-node partition (vectorized walk)
        stack = [(self.root, np.arange(len(x)))]
        while stack:
            node, idx = stack.pop()
            if node.feature < 0 or node.left is None:
                out[idx] = node.value
                continue
            mask = x[idx, node.feature] <= node.threshold
            stack.append((node.left, idx[mask]))
            stack.append((node.right, idx[~mask]))
        return out

    # -- (de)serialization ---------------------------------------------------
    def to_dict(self) -> dict:
        def enc(n):
            if n is None:
                return None
            return {"f": n.feature, "t": n.threshold, "v": n.value,
                    "l": enc(n.left), "r": enc(n.right)}
        return {"cfg": dataclasses.asdict(self.cfg), "root": enc(self.root),
                "n_features": getattr(self, "n_features", 0)}

    @classmethod
    def from_dict(cls, d: dict) -> "DecisionTreeRegressor":
        t = cls(TreeConfig(**d["cfg"]))
        t.n_features = d.get("n_features", 0)

        def dec(e):
            if e is None:
                return None
            n = _Node(e["v"])
            n.feature = e["f"]
            n.threshold = e["t"]
            n.left = dec(e["l"])
            n.right = dec(e["r"])
            return n

        t.root = dec(d["root"])
        return t
