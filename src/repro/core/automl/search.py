"""AutoML-lite (paper §3.3): train a model pool, select/ensemble by MRE.

AutoGluon's recipe at our scale: fit every candidate (RF / Extra-Trees /
GBDT / Ridge / kNN across a small hyperparameter grid) on a train split,
score MRE on a validation split, then build a greedy weighted ensemble
(Caruana-style forward selection with replacement) over the candidates.
The single best model is kept when the ensemble does not improve MRE.

Targets are modeled in log space (times/bytes span orders of magnitude;
relative error in the original space is ~absolute error in log space).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.automl.models import (ExtraTreesRegressor,
                                      GradientBoostingRegressor,
                                      KNNRegressor, RandomForestRegressor,
                                      RidgeRegressor, model_from_dict)


def default_candidates(seed: int = 0):
    return [
        RandomForestRegressor(n_trees=60, max_depth=14, max_features=0.5,
                              seed=seed),
        RandomForestRegressor(n_trees=40, max_depth=20, max_features=0.8,
                              seed=seed + 1),
        ExtraTreesRegressor(n_trees=80, max_depth=16, seed=seed + 2),
        GradientBoostingRegressor(n_stages=200, learning_rate=0.08,
                                  max_depth=5, seed=seed + 3),
        GradientBoostingRegressor(n_stages=350, learning_rate=0.05,
                                  max_depth=3, seed=seed + 4),
        RidgeRegressor(alpha=1.0),
        KNNRegressor(k=3),
    ]


_EPS = 1e-12


def _mre_log(pred_log, true_log):
    pred = np.exp(np.minimum(pred_log, 46.0))  # clip extrapolation overflow
    true = np.exp(true_log)
    return float(np.mean(np.abs(pred - true) / np.maximum(np.abs(true), _EPS)))


@dataclasses.dataclass
class FittedEnsemble:
    models: List[object]
    weights: np.ndarray
    val_mre: float
    leaderboard: List[Tuple[str, float]]

    def predict_log(self, x) -> np.ndarray:
        preds = np.stack([m.predict(x) for m in self.models])
        return (self.weights[:, None] * preds).sum(0)

    def predict(self, x) -> np.ndarray:
        return np.exp(np.minimum(self.predict_log(x), 46.0))

    def to_dict(self):
        return {"weights": self.weights.tolist(), "val_mre": self.val_mre,
                "leaderboard": self.leaderboard,
                "models": [m.to_dict() for m in self.models]}

    @classmethod
    def from_dict(cls, d):
        return cls(models=[model_from_dict(m) for m in d["models"]],
                   weights=np.array(d["weights"]),
                   val_mre=d["val_mre"],
                   leaderboard=[tuple(e) for e in d["leaderboard"]])


def fit_automl(x: np.ndarray, y: np.ndarray, val_frac: float = 0.2,
               seed: int = 0, candidates=None,
               ensemble_rounds: int = 12) -> FittedEnsemble:
    """y in ORIGINAL units (seconds / bytes); modeling in log space
    (absolute log error ~ relative error at every scale)."""
    rng = np.random.default_rng(seed)
    n = len(y)
    idx = rng.permutation(n)
    nv = max(1, int(val_frac * n))
    vi, ti = idx[:nv], idx[nv:]
    ylog = np.log(np.maximum(np.asarray(y, np.float64), _EPS))

    cands = candidates if candidates is not None else default_candidates(seed)
    fitted, scores = [], []
    for m in cands:
        m.fit(x[ti], ylog[ti])
        s = _mre_log(m.predict(x[vi]), ylog[vi])
        fitted.append(m)
        scores.append(s)
    leaderboard = sorted(
        [(type(m).KIND, s) for m, s in zip(fitted, scores)], key=lambda e: e[1])

    # Caruana forward selection with replacement on the validation split.
    val_preds = np.stack([m.predict(x[vi]) for m in fitted])
    counts = np.zeros(len(fitted))
    counts[int(np.argmin(scores))] = 1
    best = min(scores)
    for _ in range(ensemble_rounds):
        cur = (counts[:, None] * val_preds).sum(0) / counts.sum()
        trial_scores = []
        for j in range(len(fitted)):
            mix = (cur * counts.sum() + val_preds[j]) / (counts.sum() + 1)
            trial_scores.append(_mre_log(mix, ylog[vi]))
        j = int(np.argmin(trial_scores))
        if trial_scores[j] >= best - 1e-6:
            break
        counts[j] += 1
        best = trial_scores[j]

    keep = counts > 0
    models = [m for m, k in zip(fitted, keep) if k]
    weights = counts[keep] / counts.sum()
    # refit the kept models on ALL data (standard AutoGluon finale)
    for m in models:
        m.fit(x, ylog)
    return FittedEnsemble(models=models, weights=weights, val_mre=best,
                          leaderboard=leaderboard)
