"""Shallow regressor pool: RF, Extra-Trees, GBDT, Ridge, kNN.

All models share fit(x, y) / predict(x) / to_dict() / from_dict() so the
AutoML search (``repro.core.automl.search``) can treat them uniformly.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.automl.tree import DecisionTreeRegressor, TreeConfig


class RandomForestRegressor:
    KIND = "random_forest"

    def __init__(self, n_trees: int = 60, max_depth: int = 14,
                 max_features: float = 0.5, min_samples_leaf: int = 1,
                 extra: bool = False, seed: int = 0):
        self.n_trees = n_trees
        self.extra = extra
        self.cfg = TreeConfig(max_depth=max_depth,
                              min_samples_leaf=min_samples_leaf,
                              max_features=max_features,
                              random_splits=extra)
        self.seed = seed
        self.trees: List[DecisionTreeRegressor] = []

    def fit(self, x, y):
        rng = np.random.default_rng(self.seed)
        n = len(y)
        self.trees = []
        for t in range(self.n_trees):
            idx = (np.arange(n) if self.extra
                   else rng.integers(0, n, size=n))  # ET: no bootstrap
            tree = DecisionTreeRegressor(self.cfg, seed=self.seed * 1000 + t)
            tree.fit(x[idx], y[idx])
            self.trees.append(tree)
        return self

    def predict(self, x):
        return np.mean([t.predict(x) for t in self.trees], axis=0)

    def to_dict(self):
        return {"kind": self.KIND, "n_trees": self.n_trees,
                "extra": self.extra, "seed": self.seed,
                "cfg": dataclasses.asdict(self.cfg),
                "trees": [t.to_dict() for t in self.trees]}

    @classmethod
    def from_dict(cls, d):
        m = cls(n_trees=d["n_trees"], extra=d["extra"], seed=d["seed"])
        m.cfg = TreeConfig(**d["cfg"])
        m.trees = [DecisionTreeRegressor.from_dict(t) for t in d["trees"]]
        return m


class ExtraTreesRegressor(RandomForestRegressor):
    KIND = "extra_trees"

    def __init__(self, n_trees: int = 80, max_depth: int = 16,
                 max_features: float = 0.7, min_samples_leaf: int = 1,
                 seed: int = 0):
        super().__init__(n_trees=n_trees, max_depth=max_depth,
                         max_features=max_features,
                         min_samples_leaf=min_samples_leaf,
                         extra=True, seed=seed)

    @classmethod
    def from_dict(cls, d):
        m = cls(n_trees=d["n_trees"], seed=d["seed"])
        m.cfg = TreeConfig(**d["cfg"])
        m.trees = [DecisionTreeRegressor.from_dict(t) for t in d["trees"]]
        return m


class GradientBoostingRegressor:
    KIND = "gbdt"

    def __init__(self, n_stages: int = 200, learning_rate: float = 0.08,
                 max_depth: int = 5, subsample: float = 0.9,
                 max_features: float = 0.8, seed: int = 0):
        self.n_stages = n_stages
        self.lr = learning_rate
        self.subsample = subsample
        self.cfg = TreeConfig(max_depth=max_depth, min_samples_leaf=2,
                              max_features=max_features)
        self.seed = seed
        self.base = 0.0
        self.trees: List[DecisionTreeRegressor] = []

    def fit(self, x, y):
        rng = np.random.default_rng(self.seed)
        self.base = float(np.mean(y))
        resid = y - self.base
        self.trees = []
        n = len(y)
        k = max(2, int(self.subsample * n))
        for t in range(self.n_stages):
            idx = rng.choice(n, size=k, replace=False)
            tree = DecisionTreeRegressor(self.cfg, seed=self.seed * 997 + t)
            tree.fit(x[idx], resid[idx])
            pred = tree.predict(x)
            resid = resid - self.lr * pred
            self.trees.append(tree)
        return self

    def predict(self, x):
        out = np.full(len(x), self.base, np.float64)
        for t in self.trees:
            out += self.lr * t.predict(x)
        return out

    def to_dict(self):
        return {"kind": self.KIND, "n_stages": self.n_stages, "lr": self.lr,
                "subsample": self.subsample, "seed": self.seed,
                "base": self.base, "cfg": dataclasses.asdict(self.cfg),
                "trees": [t.to_dict() for t in self.trees]}

    @classmethod
    def from_dict(cls, d):
        m = cls(n_stages=d["n_stages"], learning_rate=d["lr"],
                subsample=d["subsample"], seed=d["seed"])
        m.cfg = TreeConfig(**d["cfg"])
        m.base = d["base"]
        m.trees = [DecisionTreeRegressor.from_dict(t) for t in d["trees"]]
        return m


class RidgeRegressor:
    KIND = "ridge"

    def __init__(self, alpha: float = 1.0, seed: int = 0):
        self.alpha = alpha
        self.w: Optional[np.ndarray] = None
        self.mu = None
        self.sd = None

    def _norm(self, x):
        return (x - self.mu) / self.sd

    def fit(self, x, y):
        self.mu = x.mean(0)
        self.sd = x.std(0) + 1e-9
        xn = np.concatenate([self._norm(x), np.ones((len(x), 1))], axis=1)
        a = xn.T @ xn + self.alpha * np.eye(xn.shape[1])
        # the intercept must NOT be regularized: shrinking it biases every
        # prediction by ~exp(-mean(y)*alpha/(n+alpha)) in log-target space,
        # a large systematic error for small-n (online refit) fits on
        # big-magnitude targets like log-bytes.
        a[-1, -1] -= self.alpha
        self.w = np.linalg.solve(a, xn.T @ y)
        return self

    def predict(self, x):
        xn = np.concatenate([self._norm(x), np.ones((len(x), 1))], axis=1)
        return xn @ self.w

    def to_dict(self):
        return {"kind": self.KIND, "alpha": self.alpha,
                "w": self.w.tolist(), "mu": self.mu.tolist(),
                "sd": self.sd.tolist()}

    @classmethod
    def from_dict(cls, d):
        m = cls(alpha=d["alpha"])
        m.w = np.array(d["w"])
        m.mu = np.array(d["mu"])
        m.sd = np.array(d["sd"])
        return m


class KNNRegressor:
    KIND = "knn"

    def __init__(self, k: int = 5, seed: int = 0):
        self.k = k
        self.x = None
        self.y = None
        self.mu = None
        self.sd = None

    def fit(self, x, y):
        self.mu = x.mean(0)
        self.sd = x.std(0) + 1e-9
        self.x = (x - self.mu) / self.sd
        self.y = np.asarray(y, np.float64)
        return self

    def predict(self, x):
        xn = (x - self.mu) / self.sd
        d = ((xn[:, None, :] - self.x[None, :, :]) ** 2).sum(-1)
        idx = np.argsort(d, axis=1)[:, : self.k]
        return self.y[idx].mean(axis=1)

    def to_dict(self):
        return {"kind": self.KIND, "k": self.k, "x": self.x.tolist(),
                "y": self.y.tolist(), "mu": self.mu.tolist(),
                "sd": self.sd.tolist()}

    @classmethod
    def from_dict(cls, d):
        m = cls(k=d["k"])
        m.x = np.array(d["x"])
        m.y = np.array(d["y"])
        m.mu = np.array(d["mu"])
        m.sd = np.array(d["sd"])
        return m


MODEL_KINDS = {c.KIND: c for c in
               (RandomForestRegressor, ExtraTreesRegressor,
                GradientBoostingRegressor, RidgeRegressor, KNNRegressor)}


def model_from_dict(d):
    return MODEL_KINDS[d["kind"]].from_dict(d)


def clone_unfitted(model):
    """Fresh unfitted copy with the same hyperparameters.

    The online-refit path reuses the *architectures* the original AutoML
    search selected (a refit re-estimates parameters on drifted data; it
    does not need to re-run model selection over the whole pool).
    """
    kind = type(model).KIND
    if kind == "random_forest":
        m = RandomForestRegressor(n_trees=model.n_trees, extra=model.extra,
                                  seed=model.seed)
    elif kind == "extra_trees":
        m = ExtraTreesRegressor(n_trees=model.n_trees, seed=model.seed)
    elif kind == "gbdt":
        m = GradientBoostingRegressor(n_stages=model.n_stages,
                                      learning_rate=model.lr,
                                      subsample=model.subsample,
                                      seed=model.seed)
    elif kind == "ridge":
        return RidgeRegressor(alpha=model.alpha)
    elif kind == "knn":
        return KNNRegressor(k=model.k)
    else:
        raise ValueError(f"unknown model kind {kind!r}")
    m.cfg = dataclasses.replace(model.cfg)
    return m
