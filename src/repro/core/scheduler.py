"""Scheduling application (paper §4.3): GA job placement from predictions.

Assign N training jobs to M machines minimizing makespan, with predicted
peak memory enforced against each machine's HBM (jobs predicted to OOM on
a machine are infeasible there). Three plans, as in the paper:
optimal (exhaustive / DP), random (averaged over trials), and a genetic
algorithm over assignment strings (population 20, elitist selection,
single-point crossover) — the paper reports GA matching optimal in 20
generations at -20.9% vs random.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Job:
    name: str
    time_s: float
    mem_bytes: float


@dataclasses.dataclass(frozen=True)
class Machine:
    name: str
    hbm_bytes: float
    speed: float = 1.0  # relative throughput


def jobs_from_estimates(names: Sequence[str], times: Sequence[float],
                        mems: Sequence[float], time_scale: float = 1.0,
                        mem_pad: float = 0.0) -> List[Job]:
    """Jobs from predicted (time, memory); scale step time to job length,
    pad memory for framework overhead (as in the paper's §4.3 setup)."""
    return [Job(n, float(t) * time_scale, float(m) + mem_pad)
            for n, t, m in zip(names, times, mems)]


def _base(base, n: int) -> np.ndarray:
    return np.zeros(n) if base is None else np.asarray(base, np.float64)


def makespan(assign: Sequence[int], jobs: Sequence[Job],
             machines: Sequence[Machine], base_time=None,
             reserved_mem=None) -> float:
    """Max per-machine total time; +inf if any job violates memory.

    ``base_time`` / ``reserved_mem`` (per-machine) carry load already
    committed by earlier placements — the incremental-admission case:
    new jobs are placed on top of a running cluster, not an empty one.
    Memory stays a per-job feasibility check (jobs on one machine run
    sequentially, as in the paper); ``reserved_mem`` shrinks the HBM
    that in-flight resident jobs have already claimed.
    """
    totals = _base(base_time, len(machines)).copy()
    reserved = _base(reserved_mem, len(machines))
    for a, j in zip(assign, jobs):
        m = machines[a]
        if j.mem_bytes + reserved[a] > m.hbm_bytes:
            return float("inf")
        totals[a] += j.time_s / m.speed
    return float(totals.max())


def schedule_random(jobs, machines, trials: int = 100, seed: int = 0,
                    base_time=None, reserved_mem=None):
    rng = np.random.default_rng(seed)
    spans = []
    reserved = _base(reserved_mem, len(machines))
    feasible = [[m for m, mc in enumerate(machines)
                 if j.mem_bytes + reserved[m] <= mc.hbm_bytes] for j in jobs]
    for _ in range(trials):
        a = [int(rng.choice(f)) for f in feasible]
        spans.append(makespan(a, jobs, machines, base_time, reserved_mem))
    return float(np.mean(spans)), spans


def schedule_optimal(jobs, machines, base_time=None, reserved_mem=None):
    """Exhaustive for M^N <= ~2M; otherwise multi-start local search."""
    n, m = len(jobs), len(machines)
    if m ** n <= 2_000_000:
        best, best_a = float("inf"), None
        for a in itertools.product(range(m), repeat=n):
            s = makespan(a, jobs, machines, base_time, reserved_mem)
            if s < best:
                best, best_a = s, a
        return best, list(best_a)
    # fallback: LPT + pairwise improvement
    order = np.argsort([-j.time_s for j in jobs])
    totals = _base(base_time, m).copy()
    reserved = _base(reserved_mem, m)
    a = [0] * n
    for i in order:
        ok = [k for k in range(m)
              if jobs[i].mem_bytes + reserved[k] <= machines[k].hbm_bytes]
        k = min(ok, key=lambda k: totals[k] + jobs[i].time_s / machines[k].speed)
        a[i] = k
        totals[k] += jobs[i].time_s / machines[k].speed
    return makespan(a, jobs, machines, base_time, reserved_mem), a


def schedule_ga(jobs, machines, pop_size: int = 20, generations: int = 20,
                mutation: float = 0.05, seed: int = 0,
                return_history: bool = False,
                base_time=None, reserved_mem=None):
    """The paper's GA: assignment strings, fitness = makespan."""
    rng = np.random.default_rng(seed)
    n, m = len(jobs), len(machines)
    pop = rng.integers(0, m, size=(pop_size, n))
    history = []

    def fitness(a):
        return makespan(a, jobs, machines, base_time, reserved_mem)

    best_a, best_s = None, float("inf")
    for g in range(generations):
        scores = np.array([fitness(a) for a in pop])
        order = np.argsort(scores)
        # `or best_a is None` seeds the elite even when generation 0 is
        # entirely infeasible (all-inf fitness) — memory-tight incremental
        # waves hit this; without it `best_a.copy()` below crashes.
        if scores[order[0]] < best_s or best_a is None:
            best_s = float(scores[order[0]])
            best_a = pop[order[0]].copy()
        history.append(best_s)
        parents = pop[order[: max(2, pop_size // 2)]]
        children = [best_a.copy()]  # elitism
        while len(children) < pop_size:
            i, j = rng.integers(0, len(parents), size=2)
            # n == 1: no interior cut point exists; child = parents[i]
            cut = int(rng.integers(1, n)) if n > 1 else 1
            child = np.concatenate([parents[i][:cut], parents[j][cut:]])
            flip = rng.uniform(size=n) < mutation
            child[flip] = rng.integers(0, m, size=int(flip.sum()))
            children.append(child)
        pop = np.stack(children)
    if return_history:
        return best_s, list(best_a), history
    return best_s, list(best_a)


PLANS = {"optimal": schedule_optimal, "random": schedule_random,
         "ga": schedule_ga}


def schedule_jobs(jobs: Sequence[Job], machines: Sequence[Machine],
                  plan: str = "ga", **kw):
    """Dispatch to one of the paper's three placement plans by name."""
    if plan not in PLANS:
        raise ValueError(f"unknown plan {plan!r}; choose from {sorted(PLANS)}")
    return PLANS[plan](jobs, machines, **kw)
