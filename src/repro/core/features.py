"""Feature engineering (paper §3.2).

Structure-independent features (Table 2): batch size, input size,
channels, learning rate, epoch, optimizer, #layers, FLOPs, #params —
plus a platform tag so one model generalizes across hardware (paper §4,
two systems). Structure-dependent features: the NSM vector
(``repro.core.nsm``) or the WL graph embedding (``repro.core.graphfeat``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

OPTIMIZERS = {"sgd": 0, "momentum": 1, "adam": 2, "adamw": 3}

SI_FEATURES = ["batch_size", "input_size", "channels", "learning_rate",
               "epoch", "optimizer", "layers", "flops", "params",
               "platform", "dtype_bytes"]


@dataclasses.dataclass
class ProfileRecord:
    """One profiled training/inference configuration (a data point)."""
    model_name: str
    family: str
    batch_size: int
    input_size: int          # image H(=W) or sequence length
    channels: int            # input channels or d_model
    learning_rate: float
    epoch: int
    optimizer: str
    layers: int
    flops: float             # per-step FLOPs (analytic or HLO-derived)
    params: int
    nsm_edges: Dict          # {(src,dst): count}
    time_s: float = 0.0      # measured wall time per step
    mem_bytes: float = 0.0   # XLA peak bytes (memory_analysis)
    platform: int = 0        # platform tag (paper: System 1 / System 2)
    dtype_bytes: int = 4
    extra: Optional[Dict] = None

    def si_vector(self) -> np.ndarray:
        return np.array([
            self.batch_size,
            self.input_size,
            self.channels,
            self.learning_rate,
            self.epoch,
            OPTIMIZERS.get(self.optimizer, len(OPTIMIZERS)),
            self.layers,
            np.log1p(self.flops),
            np.log1p(self.params),
            self.platform,
            self.dtype_bytes,
        ], np.float64)


def record_to_json(r: "ProfileRecord") -> Dict:
    d = dataclasses.asdict(r)
    d["nsm_edges"] = {f"{a}->{b}": v for (a, b), v in r.nsm_edges.items()}
    return d


def record_from_json(d: Dict) -> "ProfileRecord":
    d = dict(d)
    d["nsm_edges"] = {tuple(k.split("->")): v
                      for k, v in d["nsm_edges"].items()}
    return ProfileRecord(**d)


def design_matrix(records: List[ProfileRecord], nsm_featurizer=None,
                  graph_featurizer=None) -> np.ndarray:
    """One (N, D) design matrix for N records."""
    blocks = [np.stack([r.si_vector() for r in records])]
    if nsm_featurizer is not None:
        blocks.append(nsm_featurizer.vectors([r.nsm_edges for r in records]))
    if graph_featurizer is not None:
        blocks.append(np.stack([graph_featurizer.vector(r.nsm_edges)
                                for r in records]))
    return np.concatenate(blocks, axis=1)


def targets(records: List[ProfileRecord]):
    t = np.array([r.time_s for r in records], np.float64)
    m = np.array([r.mem_bytes for r in records], np.float64)
    return t, m


def mre(pred: np.ndarray, true: np.ndarray) -> float:
    """Mean relative error — the paper's metric."""
    true = np.asarray(true, np.float64)
    pred = np.asarray(pred, np.float64)
    denom = np.maximum(np.abs(true), 1e-12)
    return float(np.mean(np.abs(pred - true) / denom))
