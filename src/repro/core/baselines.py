"""Comparison baselines from the paper's evaluation (§4.1).

- Shape inference [15]: memory = sizes of weights + inputs + outputs
  discoverable from the computation graph. The paper reports 46.8% MRE —
  it systematically underestimates because workspace/temporaries are
  invisible to shapes.
- MLP regressor [27, 29] (PerfNet-style): a small 4-layer MLP trained in
  JAX on the same features.
"""

from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import ProfileRecord, design_matrix


def shape_inference_memory(record: ProfileRecord) -> float:
    """Weights + input/output tensor bytes (fp32), per the [15] baseline."""
    params_bytes = record.params * 4.0
    if record.family == "cnn":
        io = record.batch_size * record.input_size ** 2 * record.channels * 4.0
    else:
        io = record.batch_size * record.input_size * record.channels * 4.0
    return params_bytes * 2.0 + io * 2.0  # params + grads, in + out


class MLPBaseline:
    """PerfNet-style 4-layer MLP regressor (fit in log space)."""

    def __init__(self, hidden=(64, 64, 32), lr: float = 1e-3,
                 epochs: int = 400, seed: int = 0):
        self.hidden = hidden
        self.lr = lr
        self.epochs = epochs
        self.seed = seed

    def fit(self, x: np.ndarray, y: np.ndarray):
        self.mu, self.sd = x.mean(0), x.std(0) + 1e-9
        xn = jnp.asarray((x - self.mu) / self.sd, jnp.float32)
        yl_raw = np.log(np.maximum(y, 1e-12))
        self.ymu, self.ysd = float(yl_raw.mean()), float(yl_raw.std() + 1e-9)
        yl = jnp.asarray((yl_raw - self.ymu) / self.ysd, jnp.float32)
        key = jax.random.key(self.seed)
        sizes = [x.shape[1], *self.hidden, 1]
        params = []
        for i in range(len(sizes) - 1):
            key, k = jax.random.split(key)
            params.append({
                "w": jax.random.normal(k, (sizes[i], sizes[i + 1]))
                * (1.0 / np.sqrt(sizes[i])),
                "b": jnp.zeros((sizes[i + 1],))})

        def forward(p, a):
            for i, layer in enumerate(p):
                a = a @ layer["w"] + layer["b"]
                if i < len(p) - 1:
                    a = jax.nn.relu(a)
            return a[:, 0]

        def loss(p):
            return jnp.mean((forward(p, xn) - yl) ** 2)

        @jax.jit
        def step(p, m, v, t):
            g = jax.grad(loss)(p)
            m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
            v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
            tf = t.astype(jnp.float32)
            p = jax.tree.map(
                lambda pp, mm, vv: pp - self.lr * (mm / (1 - 0.9 ** tf))
                / (jnp.sqrt(vv / (1 - 0.999 ** tf)) + 1e-8), p, m, v)
            return p, m, v, t + 1

        m = jax.tree.map(jnp.zeros_like, params)
        v = jax.tree.map(jnp.zeros_like, params)
        t = jnp.ones((), jnp.int32)
        for _ in range(self.epochs):
            params, m, v, t = step(params, m, v, t)
        self.params = params
        self._forward = forward
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        xn = jnp.asarray((x - self.mu) / self.sd, jnp.float32)
        z = np.asarray(self._forward(self.params, xn))
        return np.exp(np.minimum(z * self.ysd + self.ymu, 46.0))
