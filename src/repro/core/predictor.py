"""DNNAbacus — the end-to-end predictor (paper §3).

Pipeline: ProfileRecords -> [structure-independent features | NSM vector
(or WL graph embedding)] -> AutoML-lite ensembles for time and memory.

``save``/``load`` persist everything (featurizer vocab + serialized tree
ensembles) as JSON so the launcher's admission control can run without
refitting.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import nsm as nsm_lib
from repro.core.automl.search import FittedEnsemble, fit_automl
from repro.core.features import ProfileRecord, design_matrix, mre, targets
from repro.core.graphfeat import WLGraphEmbedder

HBM_PER_DEVICE = 16 * 2**30  # v5e target; host budget used on CPU


class DNNAbacus:
    def __init__(self, representation: str = "nsm", max_vocab: int = 28,
                 seed: int = 0):
        assert representation in ("nsm", "ge", "none")
        self.representation = representation
        self.seed = seed
        self.nsm_feat = (nsm_lib.NSMFeaturizer(max_vocab=max_vocab)
                         if representation == "nsm" else None)
        self.ge_feat = (WLGraphEmbedder() if representation == "ge" else None)
        self.time_model: Optional[FittedEnsemble] = None
        self.mem_model: Optional[FittedEnsemble] = None

    # -- featurization ------------------------------------------------------
    def _x(self, records: Sequence[ProfileRecord]) -> np.ndarray:
        return design_matrix(list(records), self.nsm_feat, self.ge_feat)

    def fit(self, records: Sequence[ProfileRecord], val_frac: float = 0.2,
            candidate_factory=None) -> "DNNAbacus":
        """``candidate_factory(seed) -> [models]`` builds a FRESH candidate
        pool per target (the time and memory ensembles must not share
        model objects)."""
        if self.nsm_feat is not None:
            self.nsm_feat.fit([r.nsm_edges for r in records])
        x = self._x(records)
        t, m = targets(list(records))
        mk = candidate_factory or (lambda seed: None)
        self.time_model = fit_automl(x, t, val_frac=val_frac, seed=self.seed,
                                     candidates=mk(self.seed))
        self.mem_model = fit_automl(x, m, val_frac=val_frac,
                                    seed=self.seed + 1,
                                    candidates=mk(self.seed + 1))
        return self

    def predict(self, records: Sequence[ProfileRecord]):
        x = self._x(records)
        return self.time_model.predict(x), self.mem_model.predict(x)

    def evaluate(self, records: Sequence[ProfileRecord]) -> Dict[str, float]:
        t_pred, m_pred = self.predict(records)
        t, m = targets(list(records))
        return {"time_mre": mre(t_pred, t), "mem_mre": mre(m_pred, m)}

    # -- launcher integration ------------------------------------------------
    def predict_config(self, cfg, batch: int, seq: int) -> Dict[str, float]:
        """Admission-control estimate for a (ModelConfig, batch, seq) job."""
        from repro.core.profiler import profile_lm  # features only, no run
        from repro.models import build_model
        import jax
        import jax.numpy as jnp
        from repro.train import optimizer as opt_lib
        from repro.train import step as step_lib

        model = build_model(cfg)
        opt_cfg = opt_lib.OptConfig(keep_master=False)
        step = step_lib.make_train_step(model, opt_cfg)
        state_sds = step_lib.state_shapes(model, opt_cfg)
        b = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
             "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
        dt = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
        if cfg.cross_every:
            b["patches"] = jax.ShapeDtypeStruct(
                (batch, cfg.vision_seq, cfg.d_model), dt)
        if cfg.is_encoder_decoder:
            b["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.audio_seq, cfg.d_model), dt)
        closed = jax.make_jaxpr(step)(state_sds, b)
        edges = nsm_lib.nsm_edges(closed)
        rec = ProfileRecord(
            model_name=cfg.name, family=cfg.family, batch_size=batch,
            input_size=seq, channels=cfg.d_model, learning_rate=1e-3,
            epoch=1, optimizer="adamw", layers=cfg.num_layers,
            flops=6.0 * model.param_count(active_only=True) * batch * seq,
            params=model.param_count(), nsm_edges=edges)
        t_pred, m_pred = self.predict([rec])
        return {"time_s": float(t_pred[0]),
                "memory_bytes": float(m_pred[0]),
                "hbm_budget": float(HBM_PER_DEVICE)}

    # -- persistence ----------------------------------------------------------
    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        d = {
            "representation": self.representation,
            "seed": self.seed,
            "vocab": self.nsm_feat.vocab if self.nsm_feat else None,
            "time_model": self.time_model.to_dict(),
            "mem_model": self.mem_model.to_dict(),
        }
        with open(path + ".json", "w") as f:
            json.dump(d, f)

    @classmethod
    def load(cls, path: str) -> "DNNAbacus":
        with open(path + ".json") as f:
            d = json.load(f)
        ab = cls(representation=d["representation"], seed=d["seed"])
        if ab.nsm_feat is not None:
            ab.nsm_feat.vocab = d["vocab"]
        ab.time_model = FittedEnsemble.from_dict(d["time_model"])
        ab.mem_model = FittedEnsemble.from_dict(d["mem_model"])
        return ab
