"""DNNAbacus — the end-to-end predictor (paper §3).

Pipeline: ProfileRecords -> [structure-independent features | NSM vector
(or WL graph embedding)] -> AutoML-lite ensembles for time and memory.

``save``/``load`` persist everything (featurizer vocab + serialized tree
ensembles) as JSON so the launcher's admission control can run without
refitting.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import nsm as nsm_lib
from repro.core.automl.search import FittedEnsemble, fit_automl
from repro.core.features import ProfileRecord, design_matrix, mre, targets
from repro.core.graphfeat import WLGraphEmbedder

HBM_PER_DEVICE = 16 * 2**30  # v5e target; host budget used on CPU


class DNNAbacus:
    def __init__(self, representation: str = "nsm", max_vocab: int = 28,
                 seed: int = 0):
        assert representation in ("nsm", "ge", "none")
        self.representation = representation
        self.seed = seed
        self.nsm_feat = (nsm_lib.NSMFeaturizer(max_vocab=max_vocab)
                         if representation == "nsm" else None)
        self.ge_feat = (WLGraphEmbedder() if representation == "ge" else None)
        self.time_model: Optional[FittedEnsemble] = None
        self.mem_model: Optional[FittedEnsemble] = None
        self._service = None  # lazy PredictionService (see ``service``)

    # -- featurization ------------------------------------------------------
    def _x(self, records: Sequence[ProfileRecord]) -> np.ndarray:
        return design_matrix(list(records), self.nsm_feat, self.ge_feat)

    def fit(self, records: Sequence[ProfileRecord], val_frac: float = 0.2,
            candidate_factory=None) -> "DNNAbacus":
        """``candidate_factory(seed) -> [models]`` builds a FRESH candidate
        pool per target (the time and memory ensembles must not share
        model objects)."""
        if self.nsm_feat is not None:
            self.nsm_feat.fit([r.nsm_edges for r in records])
        x = self._x(records)
        t, m = targets(list(records))
        mk = candidate_factory or (lambda seed: None)
        self.time_model = fit_automl(x, t, val_frac=val_frac, seed=self.seed,
                                     candidates=mk(self.seed))
        self.mem_model = fit_automl(x, m, val_frac=val_frac,
                                    seed=self.seed + 1,
                                    candidates=mk(self.seed + 1))
        return self

    def refit(self, records: Sequence[ProfileRecord], val_frac: float = 0.2,
              candidate_factory=None) -> "DNNAbacus":
        """A NEW predictor re-fit on ``records`` (self is untouched).

        The online-refit loop publishes immutable model generations, so
        refitting must never mutate the ensembles a live server is
        predicting with mid-tick — hence a fresh ``DNNAbacus``. Without
        a ``candidate_factory`` the candidate pools are unfitted clones
        of the models the original AutoML search *selected* (per
        target), so a refit re-estimates parameters on fresh data
        without re-running model selection over the whole pool.
        """
        new = DNNAbacus(representation=self.representation,
                        max_vocab=(self.nsm_feat.max_vocab
                                   if self.nsm_feat is not None else 28),
                        seed=self.seed)
        if candidate_factory is not None or self.time_model is None:
            return new.fit(records, val_frac=val_frac,
                           candidate_factory=candidate_factory)
        from repro.core.automl.models import clone_unfitted
        records = list(records)
        if new.nsm_feat is not None:
            new.nsm_feat.fit([r.nsm_edges for r in records])
        x = new._x(records)
        t, m = targets(records)
        new.time_model = fit_automl(
            x, t, val_frac=val_frac, seed=self.seed,
            candidates=[clone_unfitted(c) for c in self.time_model.models])
        new.mem_model = fit_automl(
            x, m, val_frac=val_frac, seed=self.seed + 1,
            candidates=[clone_unfitted(c) for c in self.mem_model.models])
        return new

    def predict(self, records: Sequence[ProfileRecord]):
        x = self._x(records)
        return self.time_model.predict(x), self.mem_model.predict(x)

    def evaluate(self, records: Sequence[ProfileRecord]) -> Dict[str, float]:
        t_pred, m_pred = self.predict(records)
        t, m = targets(list(records))
        return {"time_mre": mre(t_pred, t), "mem_mre": mre(m_pred, m)}

    # -- launcher integration ------------------------------------------------
    def service(self, store=None) -> "object":
        """The (lazily created) PredictionService fronting this predictor.

        All online queries go through it: repeated (config, batch, seq)
        questions hit its trace cache instead of re-building the model.
        ``store`` (a ``repro.serve.trace_store.TraceStore``) backs the
        cache with cross-process persistence; it only takes effect when
        the service is first created (or has no store yet) — an already
        attached store is never silently swapped out. For other custom
        options (budget, cache size, tracer) construct a
        ``PredictionService`` directly — recreating it here would throw
        away the warm trace cache.
        """
        if self._service is None:
            from repro.serve.prediction_service import PredictionService
            self._service = PredictionService(self, store=store)
        elif store is not None and self._service.store is None:
            self._service.store = store
        return self._service

    def predict_config(self, cfg, batch: int, seq: int) -> Dict:
        """Admission-control estimate for a (ModelConfig, batch, seq) job.

        Returns the service estimate dict: ``time_s``, ``memory_bytes``,
        ``hbm_budget`` (floats) plus ``model`` (str) / ``admitted`` (bool).
        """
        return self.service().predict_one(cfg, batch, seq)

    # -- persistence ----------------------------------------------------------
    def to_dict(self) -> Dict:
        """JSON-safe snapshot of the fitted predictor.

        The single serialization seam: ``save``/``load`` persist it to
        disk, and the RPC fleet (``repro.serve.rpc``) ships it over the
        wire to adopt model generations in remote replica processes.
        """
        return {
            "representation": self.representation,
            "seed": self.seed,
            "vocab": self.nsm_feat.vocab if self.nsm_feat else None,
            "time_model": self.time_model.to_dict(),
            "mem_model": self.mem_model.to_dict(),
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "DNNAbacus":
        ab = cls(representation=d["representation"], seed=d["seed"])
        if ab.nsm_feat is not None:
            ab.nsm_feat.vocab = d["vocab"]
        ab.time_model = FittedEnsemble.from_dict(d["time_model"])
        ab.mem_model = FittedEnsemble.from_dict(d["mem_model"])
        return ab

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path + ".json", "w") as f:
            json.dump(self.to_dict(), f)

    @classmethod
    def load(cls, path: str) -> "DNNAbacus":
        with open(path + ".json") as f:
            return cls.from_dict(json.load(f))
