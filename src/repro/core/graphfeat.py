"""Graph-embedding comparison arm (paper §3.2.2 "Graph embedding").

The paper evaluates graph2vec against NSM. graph2vec's backbone is
Weisfeiler-Lehman subtree relabeling followed by an embedding of the
bag-of-rooted-subtrees; with no gensim in the image we realize the same
object as *WL feature hashing*: h iterations of neighborhood relabeling
over the operator graph, hashing each label into a fixed-size count
vector. This preserves exactly the information graph2vec's doc2vec stage
consumes, in a deterministic, dependency-free form — and, like the paper
observes, it is more expensive to build than the one-pass NSM.
"""

from __future__ import annotations

import hashlib
from collections import defaultdict
from typing import Dict, Tuple

import numpy as np

EdgeCounts = Dict[Tuple[str, str], float]


def _h(s: str, dim: int) -> int:
    return int.from_bytes(hashlib.blake2b(s.encode(), digest_size=8).digest(),
                          "little") % dim


class WLGraphEmbedder:
    """WL-subtree feature hashing over the operator multigraph."""

    def __init__(self, dim: int = 256, iterations: int = 3):
        self.dim = dim
        self.iterations = iterations

    def vector(self, edges: EdgeCounts, log_scale: bool = True) -> np.ndarray:
        # adjacency with multiplicity; nodes = operator types
        nbrs = defaultdict(list)
        nodes = set()
        for (a, b), n in edges.items():
            if n <= 0:
                continue
            nodes.update((a, b))
            nbrs[b].append((a, n))  # in-neighbors define the subtree
        labels = {v: v for v in nodes}
        vec = np.zeros(self.dim, np.float64)
        for v in nodes:
            vec[_h(labels[v], self.dim)] += 1
        for _ in range(self.iterations):
            new_labels = {}
            for v in nodes:
                parts = sorted(f"{labels[a]}*{int(n)}" for a, n in nbrs[v])
                new_labels[v] = labels[v] + "(" + ",".join(parts) + ")"
            labels = new_labels
            for v in nodes:
                vec[_h(labels[v], self.dim)] += 1
        return np.log1p(vec) if log_scale else vec

    @property
    def dim_out(self) -> int:
        return self.dim
