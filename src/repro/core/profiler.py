"""Profiling harness (paper §2): measure (time, memory) for train steps.

For every configuration we build a *real* jitted training step (model
forward + CE loss + backward + optimizer update), then record:

  time_s     — median wall-clock of ``steps`` executed steps (compile
               excluded), on the host backend;
  mem_bytes  — XLA peak bytes from ``compiled.memory_analysis()`` (the
               AOT analogue of the paper's pynvml polling — see DESIGN.md);
  flops      — loop-aware HLO FLOPs (repro.analysis.hlo), which doubles
               as the paper's Table-2 "FLOPs" feature;
  nsm_edges  — NSM extracted from the step's jaxpr (repro.core.nsm).
"""

from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo as hlo_lib
from repro.core import nsm as nsm_lib
from repro.core.features import ProfileRecord
from repro.core.zoo import ZooModel, build_zoo_model

# ---------------------------------------------------------------------------
# Tiny optimizers for the profiling rig (the paper varies the optimizer)
# ---------------------------------------------------------------------------


def make_optimizer(kind: str, lr: float):
    if kind == "sgd":
        def init(p):
            return {}

        def update(g, s, p):
            return jax.tree.map(lambda pp, gg: pp - lr * gg, p, g), s
    elif kind == "momentum":
        def init(p):
            return {"m": jax.tree.map(jnp.zeros_like, p)}

        def update(g, s, p):
            m = jax.tree.map(lambda mm, gg: 0.9 * mm + gg, s["m"], g)
            return jax.tree.map(lambda pp, mm: pp - lr * mm, p, m), {"m": m}
    elif kind in ("adam", "adamw"):
        def init(p):
            return {"m": jax.tree.map(jnp.zeros_like, p),
                    "v": jax.tree.map(jnp.zeros_like, p),
                    "t": jnp.zeros((), jnp.int32)}

        def update(g, s, p):
            t = s["t"] + 1
            m = jax.tree.map(lambda mm, gg: 0.9 * mm + 0.1 * gg, s["m"], g)
            v = jax.tree.map(lambda vv, gg: 0.999 * vv + 0.001 * gg * gg,
                             s["v"], g)
            tf = t.astype(jnp.float32)
            def upd(pp, mm, vv):
                mh = mm / (1 - 0.9 ** tf)
                vh = vv / (1 - 0.999 ** tf)
                step = mh / (jnp.sqrt(vh) + 1e-8)
                if kind == "adamw":
                    step = step + 0.01 * pp
                return pp - lr * step
            return (jax.tree.map(upd, p, m, v), {"m": m, "v": v, "t": t})
    else:
        raise ValueError(kind)
    return init, update


def _softmax_ce(logits, labels):
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


# ---------------------------------------------------------------------------
# Generic step profiling
# ---------------------------------------------------------------------------


def profile_step(step_fn, args, steps: int = 3,
                 donate: Tuple[int, ...] = ()) -> Dict:
    """Compile & run ``step_fn(*args)``; return measurements + features."""
    closed = jax.make_jaxpr(step_fn)(*args)
    edges = nsm_lib.nsm_edges(closed)
    jf = jax.jit(step_fn, donate_argnums=donate)
    lowered = jf.lower(*args)
    compiled = lowered.compile()
    cost = hlo_lib.analyze_text(compiled.as_text())
    ma = compiled.memory_analysis()
    mem = (getattr(ma, "argument_size_in_bytes", 0)
           + getattr(ma, "output_size_in_bytes", 0)
           + getattr(ma, "temp_size_in_bytes", 0)
           - getattr(ma, "alias_size_in_bytes", 0))
    # run
    concrete = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype) if hasattr(s, "shape") else s,
        args)
    out = compiled(*concrete)
    jax.block_until_ready(out)
    times = []
    for _ in range(steps):
        concrete2 = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype) if hasattr(s, "shape") else s,
            args)
        t0 = time.perf_counter()
        out = compiled(*concrete2)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return {"time_s": float(np.median(times)), "mem_bytes": float(mem),
            "flops": cost.flops, "nsm_edges": edges}


# ---------------------------------------------------------------------------
# Zoo (CNN) profiling
# ---------------------------------------------------------------------------


def zoo_train_step(model: ZooModel, optimizer: str, lr: float):
    init_opt, update = make_optimizer(optimizer, lr)

    def loss_fn(params, x, y):
        return _softmax_ce(model.apply(params, x), y)

    def step(params, opt_state, x, y):
        g = jax.grad(loss_fn)(params, x, y)
        return update(g, opt_state, params)

    return step, init_opt


def profile_zoo(name: str, batch: int = 16, image: int = 32,
                channels: int = 3, lr: float = 0.1,
                optimizer: str = "sgd", epoch: int = 1,
                steps: int = 3, platform: int = 0) -> ProfileRecord:
    model = build_zoo_model(name, channels, image)
    params = model.init(jax.random.key(0), image)
    step, init_opt = zoo_train_step(model, optimizer, lr)
    opt_state = init_opt(params)
    x = jax.ShapeDtypeStruct((batch, image, image, channels), jnp.float32)
    y = jax.ShapeDtypeStruct((batch,), jnp.int32)
    p_sds = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                         params)
    o_sds = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                         opt_state)
    meas = profile_step(step, (p_sds, o_sds, x, y), steps=steps)
    n_params = int(sum(int(np.prod(p.shape))
                       for p in jax.tree.leaves(params)))
    return ProfileRecord(
        model_name=name, family="cnn", batch_size=batch, input_size=image,
        channels=channels, learning_rate=lr, epoch=epoch,
        optimizer=optimizer, layers=model.layer_count(), flops=meas["flops"],
        params=n_params, nsm_edges=meas["nsm_edges"],
        time_s=meas["time_s"], mem_bytes=meas["mem_bytes"],
        platform=platform)


# ---------------------------------------------------------------------------
# LM (StackModel) profiling — cross-family generality
# ---------------------------------------------------------------------------


def lm_batch_specs(cfg, batch: int, seq: int) -> Dict:
    """Abstract {tokens, labels[, patches, frames]} train-step inputs.

    Single source of truth for the modality conditionals — the profiler
    and the serving-side trace path must featurize identical graphs.
    """
    b = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
         "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    dt = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    if cfg.cross_every:
        b["patches"] = jax.ShapeDtypeStruct((batch, cfg.vision_seq,
                                             cfg.d_model), dt)
    if cfg.is_encoder_decoder:
        b["frames"] = jax.ShapeDtypeStruct((batch, cfg.audio_seq,
                                            cfg.d_model), dt)
    return b


def lm_trace(cfg, batch: int, seq: int, lr: float = 1e-3):
    """(model, step_fn, state_specs, batch_specs) for one LM train step.

    Shared by the offline profiler and the online PredictionService
    tracer so both featurize the exact same graph.
    """
    from repro.models import build_model
    from repro.train import optimizer as opt_lib
    from repro.train import step as step_lib

    model = build_model(cfg)
    opt_cfg = opt_lib.OptConfig(lr=lr, keep_master=False)
    step = step_lib.make_train_step(model, opt_cfg)
    state_sds = step_lib.state_shapes(model, opt_cfg)
    return model, step, state_sds, lm_batch_specs(cfg, batch, seq)


def lm_record(cfg, model, batch: int, seq: int, *, flops, nsm_edges,
              lr: float = 1e-3, optimizer: str = "adamw",
              time_s: float = 0.0, mem_bytes: float = 0.0,
              platform: int = 0) -> ProfileRecord:
    """The canonical ModelConfig -> ProfileRecord field mapping."""
    return ProfileRecord(
        model_name=cfg.name, family=cfg.family, batch_size=batch,
        input_size=seq, channels=cfg.d_model, learning_rate=lr, epoch=1,
        optimizer=optimizer, layers=cfg.num_layers, flops=flops,
        params=model.param_count(), nsm_edges=nsm_edges,
        time_s=time_s, mem_bytes=mem_bytes, platform=platform)


def profile_lm(cfg, batch: int = 2, seq: int = 64, lr: float = 1e-3,
               optimizer: str = "adamw", steps: int = 3,
               platform: int = 0) -> ProfileRecord:
    model, step, state_sds, b = lm_trace(cfg, batch, seq, lr)
    meas = profile_step(step, (state_sds, b), steps=steps)
    return lm_record(cfg, model, batch, seq, flops=meas["flops"],
                     nsm_edges=meas["nsm_edges"], lr=lr, optimizer=optimizer,
                     time_s=meas["time_s"], mem_bytes=meas["mem_bytes"],
                     platform=platform)
