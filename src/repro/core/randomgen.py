"""Random model generator (paper §3.1: 5,500 randomly generated networks).

Two grammars, deterministic in seed:
  - random CNNs: staged conv nets sampling kernel sizes, widths, depthwise
    vs dense convs, residual/fire/inception-lite blocks, pooling points;
  - random transformers: StackModel configs sampling width/depth/heads/
    ff-multiplier/family (dense or MoE or SSM).
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import zoo as Z


def random_cnn(seed: int) -> Z.ZooModel:
    rng = np.random.default_rng(seed)
    layers: List[Z.Layer] = []
    width = int(rng.choice([16, 24, 32, 48, 64]))
    layers.append(Z.cbr(width, int(rng.choice([3, 5]))))
    stages = rng.integers(1, 4)
    for s in range(stages):
        blocks = rng.integers(1, 4)
        for _ in range(blocks):
            kind = rng.choice(["conv", "conv1", "dw", "res", "fire"])
            if kind == "conv":
                layers.append(Z.cbr(width, int(rng.choice([3, 5]))))
            elif kind == "conv1":
                layers.append(Z.cbr(width, 1))
            elif kind == "dw":
                layers.append(Z.Seq(Z.Depthwise(3), Z.BN(), Z.Act(),
                                    Z.Conv(width, 1), Z.BN(), Z.Act()))
            elif kind == "res":
                layers.append(Z.basic_block(width))
            else:
                layers.append(Z.fire(max(8, width // 4), width // 2,
                                     width // 2))
        if s < stages - 1:
            layers.append(Z.Pool("max", 2))
            width = min(256, width * 2)
    layers += [Z.GlobalAvg(), Z.Dense(10)]
    net = Z.Seq(*layers)
    m = Z.ZooModel(f"rand_cnn_{seed}", net, 3)
    m.net.spec(3)
    return m


def random_lm_config(seed: int) -> ModelConfig:
    rng = np.random.default_rng(seed + 10_000)
    d = int(rng.choice([64, 128, 192, 256]))
    heads = int(rng.choice([2, 4, 8]))
    family = rng.choice(["dense", "dense", "moe", "ssm"])
    kw = dict(
        name=f"rand_lm_{seed}",
        family=str(family),
        num_layers=int(rng.integers(1, 7)),
        d_model=d,
        num_heads=heads,
        num_kv_heads=int(rng.choice([1, heads])),
        head_dim=int(rng.choice([16, 32])),
        d_ff=int(d * rng.choice([2, 3, 4])),
        vocab_size=int(rng.choice([256, 512, 1024])),
        dtype="float32",
        remat="none",
    )
    if family == "moe":
        kw.update(num_experts=int(rng.choice([2, 4, 8])), top_k=2,
                  moe_group_size=64)
    if family == "ssm":
        kw.update(d_ff=0, num_heads=0, num_kv_heads=0, head_dim=0,
                  ssm_state=int(rng.choice([8, 16])), ssm_head_dim=16,
                  ssm_chunk=16, sub_quadratic=True)
    return ModelConfig(**kw)
