"""Network Structural Matrix (NSM) — the paper's §3.2.2, on jaxprs.

The NSM counts, for every ordered operator pair (src, dst), the number of
edges src->dst in the computation DAG. The paper builds it in one pass
over a topological ordering of the framework graph; a jaxpr *is* a
topologically-ordered equation list, so the construction is a single
traversal: each equation consumes variables whose producing primitive is
already known, incrementing cell (producer, consumer).

Call-like primitives (pjit, custom_jvp/vjp, remat) are transparent —
edges flow through them via the argument mapping. ``scan``/``while``
bodies are traversed once and their edge counts multiplied by the trip
count, so the NSM reflects executed structure (a 100-layer scanned stack
is 100x one layer, exactly like the paper's per-layer graphs).
"""

from __future__ import annotations

from collections import defaultdict
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np
from jax.extend import core as jcore

EdgeCounts = Dict[Tuple[str, str], float]

# primitive-name canonicalization (merge aliases / minor variants)
_CANON = {
    "add_any": "add",
    "convert_element_type": "convert",
    "dot_general": "dot",
    "conv_general_dilated": "conv",
    "broadcast_in_dim": "broadcast",
    "squeeze": "reshape",
    "expand_dims": "reshape",
    "dynamic_update_slice": "dus",
    "dynamic_slice": "ds",
    "select_n": "select",
    "reduce_precision": "convert",
    "stop_gradient": "identity",
    "copy": "identity",
}

_TRANSPARENT = {
    "jit", "pjit", "closed_call", "core_call", "xla_call", "remat", "checkpoint",
    "remat2", "custom_jvp_call", "custom_vjp_call", "custom_jvp_call_jaxpr",
    "custom_vjp_call_jaxpr", "custom_vjp_call_jaxpr_p", "sharding_constraint",
}


def canonical(name: str) -> str:
    return _CANON.get(name, name)


def _sub_closed_jaxprs(eqn):
    """[(closed_jaxpr, multiplier)] of call-like params."""
    out = []
    p = eqn.params
    name = eqn.primitive.name
    if name == "scan":
        out.append((p["jaxpr"], float(p.get("length", 1))))
    elif name == "while":
        out.append((p["cond_jaxpr"], 1.0))
        out.append((p["body_jaxpr"], 1.0))
    elif name == "cond":
        for b in p.get("branches", ()):
            out.append((b, 1.0))
    else:
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            if key in p:
                out.append((p[key], 1.0))
    return out


def _as_jaxpr(j):
    return j.jaxpr if hasattr(j, "jaxpr") else j


def nsm_edges(closed_jaxpr, mult: float = 1.0) -> EdgeCounts:
    counts: EdgeCounts = defaultdict(float)
    _traverse(_as_jaxpr(closed_jaxpr), {}, mult, counts)
    return dict(counts)


def _traverse(jaxpr, env: Dict[Any, str], mult: float, counts: EdgeCounts):
    # env is owned by this call (callers always construct a fresh dict) and
    # is mutated in place so producers are visible when reading outvars.
    for v in jaxpr.constvars:
        env[v] = "const"
    for v in jaxpr.invars:
        env.setdefault(v, "input")
    for eqn in jaxpr.eqns:
        name = canonical(eqn.primitive.name)
        subs = _sub_closed_jaxprs(eqn)
        if subs and (eqn.primitive.name in _TRANSPARENT
                     or eqn.primitive.name in ("scan", "while", "cond")):
            loop_like = eqn.primitive.name in ("scan", "while", "cond")
            for cj, m in subs:
                inner = _as_jaxpr(cj)
                outer_names = [env.get(v, "input") if not isinstance(v, jcore.Literal)
                               else "const" for v in eqn.invars]

                def run_body(inv_names, body_mult):
                    ienv: Dict[Any, str] = {}
                    for v in inner.constvars:
                        ienv[v] = "const"
                    for i, v in enumerate(inner.invars):
                        ienv[v] = (inv_names[i] if i < len(inv_names)
                                   else "input")
                    _traverse(inner, ienv, body_mult, counts)
                    return [ienv.get(v, "const")
                            if not isinstance(v, jcore.Literal) else "const"
                            for v in inner.outvars]

                if eqn.primitive.name == "scan" and m > 1:
                    # first iteration reads the outer init; iterations 2..m
                    # read the previous iteration's carry producers
                    nc = eqn.params.get("num_consts", 0)
                    ncar = eqn.params.get("num_carry", 0)
                    first_out = run_body(outer_names, mult)
                    fb = list(outer_names)
                    fb[nc:nc + ncar] = first_out[:ncar]
                    # re-run only to add boundary-edge corrections: the body
                    # was already counted mult*1; count remaining (m-1)
                    run_body(fb, mult * (m - 1))
                    inner_out = first_out
                else:
                    inner_out = run_body(outer_names, mult * m)
                for i, v in enumerate(eqn.outvars):
                    env[v] = (inner_out[i] if i < len(inner_out)
                              else (name if loop_like else "identity"))
            continue
        for v in eqn.invars:
            if isinstance(v, jcore.Literal):
                continue
            src = env.get(v)
            if src and src not in ("input", "const"):
                counts[(src, name)] += mult
        for v in eqn.outvars:
            env[v] = name


# ---------------------------------------------------------------------------
# Fixed-vocabulary featurization
# ---------------------------------------------------------------------------


class NSMFeaturizer:
    """Maps edge-count dicts to a fixed (V x V) matrix / flat vector.

    Featurization is vectorized: op->index resolution goes through a dict
    (rebuilt lazily whenever ``vocab`` is replaced, e.g. by ``fit`` or a
    predictor ``load``) and cell accumulation is a single NumPy
    scatter-add over all edges, so per-query cost is O(E) dict lookups
    instead of O(E*V) ``list.index`` calls.
    """

    def __init__(self, vocab=None, max_vocab: int = 28):
        self.vocab = list(vocab) if vocab else None
        self.max_vocab = max_vocab
        self._index: Optional[Dict[str, int]] = None
        self._index_vocab = None  # vocab contents the index was built from

    def fit(self, edge_dicts) -> "NSMFeaturizer":
        freq: Dict[str, float] = defaultdict(float)
        for d in edge_dicts:
            for (a, b), n in d.items():
                freq[a] += n
                freq[b] += n
        ops = sorted(freq, key=lambda k: -freq[k])[: self.max_vocab - 1]
        self.vocab = sorted(ops) + ["<other>"]
        return self

    def _op_index(self) -> Dict[str, int]:
        key = tuple(self.vocab)  # content-based: survives in-place edits
        if self._index is None or self._index_vocab != key:
            self._index = {op: i for i, op in enumerate(self.vocab)}
            self._index_vocab = key
        return self._index

    def _idx(self, op: str) -> int:
        return self._op_index().get(op, len(self.vocab) - 1)

    def matrix(self, edges: EdgeCounts) -> np.ndarray:
        v = len(self.vocab)
        m = np.zeros((v, v), np.float64)
        if not edges:
            return m
        idx = self._op_index()
        other = v - 1
        rows = np.fromiter((idx.get(a, other) for a, _ in edges),
                           np.intp, count=len(edges))
        cols = np.fromiter((idx.get(b, other) for _, b in edges),
                           np.intp, count=len(edges))
        vals = np.fromiter(edges.values(), np.float64, count=len(edges))
        np.add.at(m, (rows, cols), vals)
        return m

    def vector(self, edges: EdgeCounts, log_scale: bool = True) -> np.ndarray:
        m = self.matrix(edges)
        flat = m.reshape(-1)
        aug = np.concatenate([flat, m.sum(0), m.sum(1)])  # + in/out degrees
        return np.log1p(aug) if log_scale else aug

    def vectors(self, edge_dicts, log_scale: bool = True) -> np.ndarray:
        """One (N, dim) block for N edge dicts. Per-record loop: the
        vectorization lives inside ``matrix`` (the scatter-add)."""
        if not edge_dicts:
            return np.zeros((0, self.dim), np.float64)
        return np.stack([self.vector(e, log_scale=log_scale)
                         for e in edge_dicts])

    @property
    def dim(self) -> int:
        v = len(self.vocab)
        return v * v + 2 * v


def nsm_of_fn(fn: Callable, *example_args, **kw) -> EdgeCounts:
    """NSM edges of ``fn`` traced at the given (Shape/array) arguments."""
    closed = jax.make_jaxpr(fn)(*example_args, **kw)
    return nsm_edges(closed)
