"""The paper's 29 classic CNNs, in JAX (CIFAR-scale, NHWC).

A small sequential/block framework: each layer exposes
``spec(cin) -> (param_spec, cout)`` and ``apply(params, x)``; networks are
layer lists built by family constructors. The profiler trains these for
real on the host backend to collect (features -> time, memory) points —
the reproduction of the paper's data-collection rig (§2, §4).

The unseen-model split of Fig. 13 (InceptionV3, StochasticDepth-34,
ResNet-50, PreActResNet-152, SE-ResNet-34) matches the paper exactly.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.module import ParamSpec, init_params, spec

# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------


class Layer:
    def spec(self, cin: int):
        raise NotImplementedError

    def apply(self, p, x):
        raise NotImplementedError


class Conv(Layer):
    def __init__(self, cout, k=3, stride=1, groups=1, bias=False, pad="SAME"):
        self.cout, self.k, self.stride = cout, k, stride
        self.groups, self.bias, self.pad = groups, bias, pad

    def spec(self, cin):
        p = {"w": spec((self.k, self.k, cin // self.groups, self.cout),
                       (None, None, None, None))}
        if self.bias:
            p["b"] = spec((self.cout,), (None,), "zeros")
        return p, self.cout

    def apply(self, p, x):
        y = jax.lax.conv_general_dilated(
            x, p["w"], (self.stride, self.stride), self.pad,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=self.groups)
        if "b" in p:
            y = y + p["b"]
        return y


class Depthwise(Conv):
    def __init__(self, k=3, stride=1, bias=False):
        super().__init__(cout=0, k=k, stride=stride, bias=bias)

    def spec(self, cin):
        self.cout = cin
        self.groups = cin
        return super().spec(cin)


class BN(Layer):
    def spec(self, cin):
        return {"g": spec((cin,), (None,), "ones"),
                "b": spec((cin,), (None,), "zeros")}, cin

    def apply(self, p, x):
        mu = x.mean(axis=(0, 1, 2), keepdims=True)
        var = x.var(axis=(0, 1, 2), keepdims=True)
        return (x - mu) * jax.lax.rsqrt(var + 1e-5) * p["g"] + p["b"]


class Act(Layer):
    def __init__(self, kind="relu"):
        self.kind = kind

    def spec(self, cin):
        return {}, cin

    def apply(self, p, x):
        return {"relu": jax.nn.relu, "relu6": jax.nn.relu6,
                "swish": jax.nn.silu, "tanh": jnp.tanh}[self.kind](x)


class Pool(Layer):
    def __init__(self, kind="max", k=2, stride=None, pad="VALID"):
        self.kind, self.k = kind, k
        self.stride = stride or k
        self.pad = pad

    def spec(self, cin):
        return {}, cin

    def apply(self, p, x):
        init = -jnp.inf if self.kind == "max" else 0.0
        op = jax.lax.max if self.kind == "max" else jax.lax.add
        y = jax.lax.reduce_window(
            x, init, op, (1, self.k, self.k, 1),
            (1, self.stride, self.stride, 1), self.pad)
        if self.kind == "avg":
            y = y / (self.k * self.k)
        return y


class Flatten(Layer):
    """Flatten HxWxC -> features; spatial extent given statically."""

    def __init__(self, spatial: int):
        self.spatial = spatial

    def spec(self, cin):
        return {}, cin * self.spatial * self.spatial

    def apply(self, p, x):
        return x.reshape(x.shape[0], -1)


class GlobalAvg(Layer):
    def spec(self, cin):
        return {}, cin

    def apply(self, p, x):
        return x.mean(axis=(1, 2))


class Dense(Layer):
    def __init__(self, cout):
        self.cout = cout

    def spec(self, cin):
        return {"w": spec((cin, self.cout), (None, None)),
                "b": spec((self.cout,), (None,), "zeros")}, self.cout

    def apply(self, p, x):
        if x.ndim > 2:
            x = x.reshape(x.shape[0], -1)
        return x @ p["w"] + p["b"]


class Seq(Layer):
    def __init__(self, *layers):
        self.layers = [l for l in layers if l is not None]

    def spec(self, cin):
        specs = []
        for l in self.layers:
            s, cin = l.spec(cin)
            specs.append(s)
        return specs, cin

    def apply(self, p, x):
        for l, pi in zip(self.layers, p):
            x = l.apply(pi, x)
        return x


class Residual(Layer):
    """x + f(x), with an optional 1x1 projection when shape changes."""

    def __init__(self, inner: Layer, stride=1, scale=1.0):
        self.inner = inner
        self.stride = stride
        self.scale = scale

    def spec(self, cin):
        s, cout = self.inner.spec(cin)
        proj = None
        if cout != cin or self.stride != 1:
            proj, _ = Seq(Conv(cout, 1, self.stride), BN()).spec(cin)
            self._proj_l = Seq(Conv(cout, 1, self.stride), BN())
        self._cin = cin
        return {"f": s, "proj": proj if proj is not None else {}}, cout

    def apply(self, p, x):
        y = self.inner.apply(p["f"], x)
        sc = x
        if p["proj"]:
            sc = self._proj_l.apply(p["proj"], x)
        return sc + self.scale * y


class Branches(Layer):
    """Parallel branches, channel-concatenated (Inception / Fire)."""

    def __init__(self, *branches):
        self.branches = branches

    def spec(self, cin):
        specs, couts = [], []
        for b in self.branches:
            s, c = b.spec(cin)
            specs.append(s)
            couts.append(c)
        return specs, sum(couts)

    def apply(self, p, x):
        return jnp.concatenate(
            [b.apply(pi, x) for b, pi in zip(self.branches, p)], axis=-1)


class SE(Layer):
    """Squeeze-and-excitation."""

    def __init__(self, r=4):
        self.r = r

    def spec(self, cin):
        hid = max(4, cin // self.r)
        return {"w1": spec((cin, hid), (None, None)),
                "w2": spec((hid, cin), (None, None))}, cin

    def apply(self, p, x):
        s = x.mean(axis=(1, 2))
        s = jax.nn.relu(s @ p["w1"])
        s = jax.nn.sigmoid(s @ p["w2"])
        return x * s[:, None, None, :]


class Shuffle(Layer):
    def __init__(self, groups):
        self.g = groups

    def spec(self, cin):
        return {}, cin

    def apply(self, p, x):
        b, h, w, c = x.shape
        return (x.reshape(b, h, w, self.g, c // self.g)
                .swapaxes(3, 4).reshape(b, h, w, c))


class Lambda(Layer):
    def __init__(self, fn):
        self.fn = fn

    def spec(self, cin):
        return {}, cin

    def apply(self, p, x):
        return self.fn(x)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def cbr(cout, k=3, stride=1, groups=1, act="relu"):
    return Seq(Conv(cout, k, stride, groups), BN(), Act(act))


def basic_block(cout, stride=1, se=False, scale=1.0):
    inner = Seq(Conv(cout, 3, stride), BN(), Act(),
                Conv(cout, 3), BN(), SE() if se else None)
    return Seq(Residual(inner, stride, scale), Act())


def bottleneck(cout, stride=1, expansion=4, se=False):
    inner = Seq(Conv(cout, 1), BN(), Act(),
                Conv(cout, 3, stride), BN(), Act(),
                Conv(cout * expansion, 1), BN(), SE() if se else None)
    return Seq(Residual(inner, stride), Act())


def preact_basic(cout, stride=1):
    return Residual(Seq(BN(), Act(), Conv(cout, 3, stride),
                        BN(), Act(), Conv(cout, 3)), stride)


def preact_bottleneck(cout, stride=1, expansion=4):
    return Residual(Seq(BN(), Act(), Conv(cout, 1),
                        BN(), Act(), Conv(cout, 3, stride),
                        BN(), Act(), Conv(cout * expansion, 1)), stride)


def inception(c1, c3r, c3, c5r, c5, pp):
    return Branches(
        cbr(c1, 1),
        Seq(cbr(c3r, 1), cbr(c3, 3)),
        Seq(cbr(c5r, 1), cbr(c5, 5)),
        Seq(Pool("max", 3, 1, "SAME"), cbr(pp, 1)))


def fire(s1, e1, e3):
    return Seq(cbr(s1, 1), Branches(cbr(e1, 1), cbr(e3, 3)))


def inv_residual(cout, stride, expand):
    def block(cin):  # returns closure-free: expansion known at spec time
        pass
    class _Inv(Layer):
        def spec(self, cin):
            hid = cin * expand
            self.seq = Seq(cbr(hid, 1, act="relu6"),
                           Depthwise(3, stride), BN(), Act("relu6"),
                           Conv(cout, 1), BN())
            self.use_res = (stride == 1 and cin == cout)
            return self.seq.spec(cin)

        def apply(self, p, x):
            y = self.seq.apply(p, x)
            return x + y if self.use_res else y
    return _Inv()


def mbconv(cout, stride, expand, se=True):
    class _MB(Layer):
        def spec(self, cin):
            hid = max(cin * expand, cin)
            self.seq = Seq(cbr(hid, 1, act="swish") if expand > 1 else None,
                           Depthwise(3, stride), BN(), Act("swish"),
                           SE(4) if se else None,
                           Conv(cout, 1), BN())
            self.use_res = (stride == 1 and cin == cout)
            return self.seq.spec(cin)

        def apply(self, p, x):
            y = self.seq.apply(p, x)
            return x + y if self.use_res else y
    return _MB()


def shuffle_unit_v1(cout, stride, groups=4):
    class _SU(Layer):
        def spec(self, cin):
            mid = cout // 4
            self.body = Seq(Conv(mid, 1, groups=groups), BN(), Act(),
                            Shuffle(groups),
                            Depthwise(3, stride), BN(),
                            Conv(cout if stride == 1 else cout - cin, 1,
                                 groups=groups), BN())
            self.stride = stride
            self.pool = Pool("avg", 3, 2, "SAME")
            bs, _ = self.body.spec(cin)
            return bs, cout

        def apply(self, p, x):
            y = self.body.apply(p, x)
            if self.stride == 1:
                return jax.nn.relu(x + y) if x.shape == y.shape else jax.nn.relu(y)
            sc = self.pool.apply({}, x)
            return jax.nn.relu(jnp.concatenate([sc, y], axis=-1))
    return _SU()


def shuffle_unit_v2(cout, stride):
    class _SU2(Layer):
        def spec(self, cin):
            half = cout // 2
            self.stride = stride
            self.right = Seq(cbr(half, 1), Depthwise(3, stride), BN(),
                             cbr(half, 1))
            rs, _ = self.right.spec(cin if stride > 1 else cin // 2)
            if stride > 1:
                self.left = Seq(Depthwise(3, stride), BN(), cbr(half, 1))
                ls, _ = self.left.spec(cin)
            else:
                self.left = None
                ls = {}
            self.shuffle = Shuffle(2)
            return {"l": ls, "r": rs}, cout

        def apply(self, p, x):
            if self.stride > 1:
                l = self.left.apply(p["l"], x)
                r = self.right.apply(p["r"], x)
            else:
                c = x.shape[-1] // 2
                l, r = x[..., :c], x[..., c:]
                r = self.right.apply(p["r"], r)
            return self.shuffle.apply({}, jnp.concatenate([l, r], axis=-1))
    return _SU2()


def dense_block(n, growth):
    class _DB(Layer):
        def spec(self, cin):
            self.units = []
            specs = []
            c = cin
            for _ in range(n):
                u = Seq(BN(), Act(), Conv(growth, 3))
                s, _ = u.spec(c)
                self.units.append(u)
                specs.append(s)
                c += growth
            return specs, c

        def apply(self, p, x):
            for u, pi in zip(self.units, p):
                y = u.apply(pi, x)
                x = jnp.concatenate([x, y], axis=-1)
            return x
    return _DB()


# ---------------------------------------------------------------------------
# Networks (CIFAR-scale stem; 10-class head)
# ---------------------------------------------------------------------------


def _stack(block, cfgs):
    return Seq(*[block(c, s) for c, s in cfgs])


def _resnet(layers: Sequence[int], block="basic", width=64, se=False,
            preact=False, scale=1.0):
    blocks: List[Layer] = [cbr(width, 3)]
    cmul = [1, 2, 4, 8]
    for i, n in enumerate(layers):
        c = width * cmul[i]
        for j in range(n):
            stride = 2 if (j == 0 and i > 0) else 1
            if preact:
                b = (preact_basic(c, stride) if block == "basic"
                     else preact_bottleneck(c, stride))
            elif block == "basic":
                b = basic_block(c, stride, se=se, scale=scale)
            else:
                b = bottleneck(c, stride, se=se)
            blocks.append(b)
    blocks += [GlobalAvg(), Dense(10)]
    return Seq(*blocks)


def _vgg(cfg: Sequence) -> Seq:
    blocks: List[Layer] = []
    for v in cfg:
        if v == "M":
            blocks.append(Pool("max", 2))
        else:
            blocks.append(cbr(v, 3))
    blocks += [GlobalAvg(), Dense(512), Act(), Dense(10)]
    return Seq(*blocks)


_VGG_CFG = {
    11: [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512],
    13: [64, 64, "M", 128, 128, "M", 256, 256, "M", 512, 512, "M", 512, 512],
    16: [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
         512, 512, 512, "M", 512, 512, 512],
    19: [64, 64, "M", 128, 128, "M", 256, 256, 256, 256, "M",
         512, 512, 512, 512, "M", 512, 512, 512, 512],
}


def _googlenet():
    return Seq(
        cbr(64, 3), cbr(192, 3), Pool("max", 2),
        inception(64, 96, 128, 16, 32, 32),
        inception(128, 128, 192, 32, 96, 64), Pool("max", 2),
        inception(192, 96, 208, 16, 48, 64),
        inception(160, 112, 224, 24, 64, 64),
        inception(128, 128, 256, 24, 64, 64),
        inception(112, 144, 288, 32, 64, 64),
        inception(256, 160, 320, 32, 128, 128), Pool("max", 2),
        inception(256, 160, 320, 32, 128, 128),
        inception(384, 192, 384, 48, 128, 128),
        GlobalAvg(), Dense(10))


def _inception_v3_lite():
    def factored(c):
        return Branches(cbr(c, 1),
                        Seq(cbr(c, 1), Conv(c, 3), BN(), Act()),
                        Seq(cbr(c, 1), Conv(c, 3), BN(), Act(),
                            Conv(c, 3), BN(), Act()),
                        Seq(Pool("avg", 3, 1, "SAME"), cbr(c, 1)))
    return Seq(cbr(32, 3), cbr(64, 3), Pool("max", 2),
               factored(48), factored(64), Pool("max", 2),
               factored(96), factored(96), Pool("max", 2),
               factored(128), GlobalAvg(), Dense(10))


def _squeezenet():
    return Seq(cbr(64, 3), Pool("max", 2),
               fire(16, 64, 64), fire(16, 64, 64), Pool("max", 2),
               fire(32, 128, 128), fire(32, 128, 128), Pool("max", 2),
               fire(48, 192, 192), fire(48, 192, 192),
               fire(64, 256, 256), fire(64, 256, 256),
               Conv(10, 1), GlobalAvg())


def _mobilenet_v1():
    def dw(cout, stride=1):
        return Seq(Depthwise(3, stride), BN(), Act(),
                   Conv(cout, 1), BN(), Act())
    return Seq(cbr(32, 3), dw(64), dw(128, 2), dw(128), dw(256, 2), dw(256),
               dw(512, 2), *[dw(512) for _ in range(5)], dw(1024, 2),
               dw(1024), GlobalAvg(), Dense(10))


def _mobilenet_v2():
    cfg = [(16, 1, 1), (24, 1, 6), (24, 1, 6), (32, 2, 6), (32, 1, 6),
           (32, 1, 6), (64, 2, 6), (64, 1, 6), (64, 1, 6), (64, 1, 6),
           (96, 1, 6), (96, 1, 6), (96, 1, 6), (160, 2, 6), (160, 1, 6),
           (160, 1, 6), (320, 1, 6)]
    return Seq(cbr(32, 3), *[inv_residual(c, s, e) for c, s, e in cfg],
               cbr(1280, 1), GlobalAvg(), Dense(10))


def _shufflenet_v1():
    return Seq(cbr(24, 3),
               shuffle_unit_v1(240, 2), *[shuffle_unit_v1(240, 1)] * 3,
               shuffle_unit_v1(480, 2), *[shuffle_unit_v1(480, 1)] * 7,
               shuffle_unit_v1(960, 2), *[shuffle_unit_v1(960, 1)] * 3,
               GlobalAvg(), Dense(10))


def _shufflenet_v2():
    return Seq(cbr(24, 3),
               shuffle_unit_v2(116, 2), *[shuffle_unit_v2(116, 1)] * 3,
               shuffle_unit_v2(232, 2), *[shuffle_unit_v2(232, 1)] * 7,
               shuffle_unit_v2(464, 2), *[shuffle_unit_v2(464, 1)] * 3,
               cbr(1024, 1), GlobalAvg(), Dense(10))


def _densenet63():
    return Seq(cbr(32, 3),
               dense_block(6, 16), cbr(64, 1), Pool("avg", 2),
               dense_block(8, 16), cbr(96, 1), Pool("avg", 2),
               dense_block(8, 16), cbr(128, 1), Pool("avg", 2),
               dense_block(6, 16), GlobalAvg(), Dense(10))


def _nin():
    return Seq(cbr(192, 5), cbr(160, 1), cbr(96, 1), Pool("max", 2),
               cbr(192, 5), cbr(192, 1), cbr(192, 1), Pool("avg", 2),
               cbr(192, 3), cbr(192, 1), Conv(10, 1), GlobalAvg())


def _resnext29():
    def block(cout, stride=1):
        inner = Seq(Conv(cout // 2, 1), BN(), Act(),
                    Conv(cout // 2, 3, stride, groups=8), BN(), Act(),
                    Conv(cout, 1), BN())
        return Seq(Residual(inner, stride), Act())
    return Seq(cbr(64, 3),
               *[block(256, 2 if i == 0 else 1) for i in range(3)],
               *[block(512, 2 if i == 0 else 1) for i in range(3)],
               *[block(1024, 2 if i == 0 else 1) for i in range(3)],
               GlobalAvg(), Dense(10))


def _efficientnet_lite0():
    cfg = [(16, 1, 1), (24, 2, 6), (24, 1, 6), (40, 2, 6), (40, 1, 6),
           (80, 2, 6), (80, 1, 6), (80, 1, 6), (112, 1, 6), (112, 1, 6),
           (192, 2, 6), (192, 1, 6), (192, 1, 6), (320, 1, 6)]
    return Seq(cbr(32, 3, act="swish"),
               *[mbconv(c, s, e) for c, s, e in cfg],
               cbr(1280, 1, act="swish"), GlobalAvg(), Dense(10))


def _convmixer_lite(dim=256, depth=8, k=9):
    def mixer():
        return Seq(Residual(Seq(Depthwise(k, 1), Act("swish"), BN())),
                   Conv(dim, 1), Act("swish"), BN())
    return Seq(Conv(dim, 2, 2), Act("swish"), BN(),
               *[mixer() for _ in range(depth)], GlobalAvg(), Dense(10))


def _lenet5(image=32):
    s1 = (image - 4) // 2
    s2 = (s1 - 4) // 2
    return Seq(Conv(6, 5, bias=True, pad="VALID"), Act("tanh"), Pool("avg", 2),
               Conv(16, 5, bias=True, pad="VALID"), Act("tanh"), Pool("avg", 2),
               Flatten(s2),
               Dense(120), Act("tanh"), Dense(84), Act("tanh"), Dense(10))


def _alexnet(image=32):
    return Seq(cbr(64, 5), Pool("max", 2), cbr(192, 5), Pool("max", 2),
               cbr(384, 3), cbr(256, 3), cbr(256, 3), Pool("max", 2),
               Flatten(image // 8),
               Dense(1024), Act(), Dense(512), Act(), Dense(10))


ZOO: Dict[str, Callable[[], Seq]] = {
    "lenet5": _lenet5,  # image-aware
    "alexnet": _alexnet,
    "vgg11": lambda: _vgg(_VGG_CFG[11]),
    "vgg13": lambda: _vgg(_VGG_CFG[13]),
    "vgg16": lambda: _vgg(_VGG_CFG[16]),
    "vgg19": lambda: _vgg(_VGG_CFG[19]),
    "resnet18": lambda: _resnet([2, 2, 2, 2]),
    "resnet34": lambda: _resnet([3, 4, 6, 3]),
    "resnet50": lambda: _resnet([3, 4, 6, 3], "bottleneck"),
    "resnet101": lambda: _resnet([3, 4, 23, 3], "bottleneck"),
    "resnet152": lambda: _resnet([3, 8, 36, 3], "bottleneck"),
    "preact_resnet18": lambda: _resnet([2, 2, 2, 2], preact=True),
    "preact_resnet152": lambda: _resnet([3, 8, 36, 3], "bottleneck",
                                        preact=True),
    "se_resnet18": lambda: _resnet([2, 2, 2, 2], se=True),
    "se_resnet34": lambda: _resnet([3, 4, 6, 3], se=True),
    "googlenet": _googlenet,
    "inception_v3_lite": _inception_v3_lite,
    "squeezenet": _squeezenet,
    "mobilenet_v1": _mobilenet_v1,
    "mobilenet_v2": _mobilenet_v2,
    "shufflenet_v1": _shufflenet_v1,
    "shufflenet_v2": _shufflenet_v2,
    "densenet63": _densenet63,
    "nin": _nin,
    "wideresnet16_4": lambda: _resnet([2, 2, 2], width=64 * 4 // 4),
    "stochastic_depth34": lambda: _resnet([3, 4, 6, 3], scale=0.8),
    "resnext29": _resnext29,
    "efficientnet_lite0": _efficientnet_lite0,
    "convmixer_lite": _convmixer_lite,
}

# the paper's Fig.13 zero-shot holdout — identical families
UNSEEN = ("inception_v3_lite", "stochastic_depth34", "resnet50",
          "preact_resnet152", "se_resnet34")

LIGHTWEIGHT = ("squeezenet", "mobilenet_v1", "mobilenet_v2",
               "shufflenet_v1", "shufflenet_v2")  # paper's 1x1-conv group


@dataclasses.dataclass
class ZooModel:
    name: str
    net: Seq
    cin: int

    def init(self, key, image=32):
        s, _ = self.net.spec(self.cin)
        return init_params(s, key)

    def apply(self, params, x):
        return self.net.apply(params, x)

    def layer_count(self) -> int:
        def count(l) -> int:
            if isinstance(l, (Conv, Dense)):
                return 1
            inner = []
            if isinstance(l, Seq):
                inner = l.layers
            elif isinstance(l, Residual):
                inner = [l.inner]
                if getattr(l, "_proj_l", None) is not None:
                    inner.append(l._proj_l)
            elif isinstance(l, Branches):
                inner = list(l.branches)
            else:  # closure-built blocks expose their sub-layers as attrs
                for attr in ("seq", "body", "left", "right", "units"):
                    v = getattr(l, attr, None)
                    if isinstance(v, Layer):
                        inner.append(v)
                    elif isinstance(v, list):
                        inner.extend(v)
            return sum(count(i) for i in inner)
        return count(self.net)


def build_zoo_model(name: str, channels: int = 3, image: int = 32) -> ZooModel:
    import inspect
    builder = ZOO[name]
    if "image" in inspect.signature(builder).parameters:
        net = builder(image=image)
    else:
        net = builder()
    m = ZooModel(name, net, channels)
    # Materialize block inner layers (some blocks build layers in spec()).
    m.net.spec(channels)
    return m
