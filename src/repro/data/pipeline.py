"""Sharded data pipeline: synthetic + memmap token sources, prefetching.

The loader produces global batches already placed on the mesh with the
``batch``-axis sharding. Sources are deterministic in (seed, step) so an
elastic restart resumes the exact token stream from the checkpointed step
— a data pipeline requirement for reproducible fault recovery.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np


class SyntheticLM:
    """Deterministic synthetic next-token data (zipf-ish unigram stream)."""

    def __init__(self, vocab_size: int, batch: int, seq: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq = seq
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        # zipf-like marginal so losses are non-degenerate
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        tokens = np.minimum(z - 1, self.vocab_size - 1).astype(np.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class MemmapLM:
    """Windows over a flat binary token file (np.uint16/uint32 memmap)."""

    def __init__(self, path: str, batch: int, seq: int, dtype=np.uint16,
                 seed: int = 0):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.batch = batch
        self.seq = seq
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        max_start = len(self.data) - self.seq - 1
        starts = rng.integers(0, max_start, size=self.batch)
        toks = np.stack([self.data[s:s + self.seq + 1] for s in starts])
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class ShardedLoader:
    """Places host batches on the mesh, with background prefetch."""

    def __init__(self, source, shardings=None, start_step: int = 0,
                 prefetch: int = 2):
        self.source = source
        self.shardings = shardings
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, prefetch))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _put(self, batch):
        if self.shardings is not None:
            batch = jax.tree.map(
                lambda x, s: jax.device_put(x, s), batch, self.shardings)
        return batch

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            b = self.source.batch_at(step)
            try:
                self._q.put((step, self._put(b)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()
