"""Serving layer: prediction gateway + cluster fabric + decode engine.

Import-light by design: the admission-gateway stack (``TraceStore``,
``PredictionService``, ``AbacusServer``, ``AdmissionController``), the
online-refit loop (``FeedbackStore``, ``OnlineRefitter``), the
multi-host fabric (``ClusterFrontend``, ``GatewayReplica``,
``GenerationPublisher``), and the RPC transport (``RemoteReplica``,
``ReplicaServer``, ``spawn_fleet``) are pure numpy/stdlib and
re-exported here; ``repro.serve.engine`` (the jax decode engine) is
imported lazily by consumers that need it. All durable maps share one
store contract (``repro.serve.kvstore.KVStoreBase``) over two
interchangeable engines: file-per-key ``JsonFileStore`` and the
append-only ``SegmentLogStore`` (``make_trace_store`` /
``make_feedback_store`` select by name or ``REPRO_STORE_BACKEND``).
"""

from repro.serve.admission import AdmissionController, Verdict
from repro.serve.cluster import (ClusterFrontend, GatewayReplica,
                                 GenerationPublisher, HashRing,
                                 ReplicaNotRunning, ReplicaUnavailable,
                                 RingDiff)
from repro.serve.feedback_store import (CalibrationWindow, FeedbackStore,
                                        Observation, SegmentFeedbackStore,
                                        TenantCalibration,
                                        make_feedback_store)
from repro.serve.kvstore import (JsonFileStore, KVStoreBase, SegmentLogStore,
                                 atomic_write_json, store_backend)
from repro.serve.prediction_service import (PredictionService, Query,
                                            config_fingerprint)
from repro.serve.refit import ModelGeneration, OnlineRefitter
from repro.serve.server import (AbacusServer, DeadlineExceeded,
                                QuotaExceeded)
from repro.serve.trace_store import (SegmentTraceStore, TraceStore,
                                     make_trace_store)

# Lazy (PEP 562) so `python -m repro.serve.rpc` does not import the rpc
# module twice (once via this package, once as __main__ — runpy warns).
_RPC_EXPORTS = ("RemoteReplica", "ReplicaServer", "spawn_replica",
                "spawn_fleet", "shutdown_fleet")


def __getattr__(name):
    if name in _RPC_EXPORTS:
        from repro.serve import rpc

        return getattr(rpc, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = ["AdmissionController", "Verdict", "PredictionService", "Query",
           "config_fingerprint", "AbacusServer", "DeadlineExceeded",
           "QuotaExceeded", "TraceStore", "SegmentTraceStore",
           "make_trace_store",
           "FeedbackStore", "SegmentFeedbackStore", "make_feedback_store",
           "Observation", "CalibrationWindow",
           "TenantCalibration",
           "OnlineRefitter", "ModelGeneration", "KVStoreBase",
           "JsonFileStore", "SegmentLogStore", "store_backend",
           "atomic_write_json", "ClusterFrontend", "GatewayReplica",
           "GenerationPublisher", "HashRing", "RingDiff",
           "ReplicaUnavailable", "ReplicaNotRunning", "RemoteReplica",
           "ReplicaServer", "spawn_replica", "spawn_fleet",
           "shutdown_fleet"]
