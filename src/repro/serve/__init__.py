"""Serving layer: prediction gateway + decode engine.

Import-light by design: the admission-gateway stack (``TraceStore``,
``PredictionService``, ``AbacusServer``, ``AdmissionController``) and
the online-refit loop (``FeedbackStore``, ``OnlineRefitter``) are pure
numpy/stdlib and re-exported here; ``repro.serve.engine`` (the jax
decode engine) is imported lazily by consumers that need it.
"""

from repro.serve.admission import AdmissionController, Verdict
from repro.serve.feedback_store import (CalibrationWindow, FeedbackStore,
                                        Observation)
from repro.serve.prediction_service import (PredictionService, Query,
                                            config_fingerprint)
from repro.serve.refit import ModelGeneration, OnlineRefitter
from repro.serve.server import AbacusServer
from repro.serve.trace_store import TraceStore

__all__ = ["AdmissionController", "Verdict", "PredictionService", "Query",
           "config_fingerprint", "AbacusServer", "TraceStore",
           "FeedbackStore", "Observation", "CalibrationWindow",
           "OnlineRefitter", "ModelGeneration"]
