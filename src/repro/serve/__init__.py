"""Serving layer: prediction gateway + cluster fabric + decode engine.

Import-light by design: the admission-gateway stack (``TraceStore``,
``PredictionService``, ``AbacusServer``, ``AdmissionController``), the
online-refit loop (``FeedbackStore``, ``OnlineRefitter``), the
multi-host fabric (``ClusterFrontend``, ``GatewayReplica``,
``GenerationPublisher``), and the RPC transport (``RemoteReplica``,
``ReplicaServer``, ``spawn_fleet``) are pure numpy/stdlib and
re-exported here; ``repro.serve.engine`` (the jax decode engine) is
imported lazily by consumers that need it. All durable maps share one
persistence base, ``repro.serve.kvstore.JsonFileStore``.
"""

from repro.serve.admission import AdmissionController, Verdict
from repro.serve.cluster import (ClusterFrontend, GatewayReplica,
                                 GenerationPublisher, HashRing,
                                 ReplicaNotRunning, ReplicaUnavailable,
                                 RingDiff)
from repro.serve.feedback_store import (CalibrationWindow, FeedbackStore,
                                        Observation, TenantCalibration)
from repro.serve.kvstore import JsonFileStore, atomic_write_json
from repro.serve.prediction_service import (PredictionService, Query,
                                            config_fingerprint)
from repro.serve.refit import ModelGeneration, OnlineRefitter
from repro.serve.server import (AbacusServer, DeadlineExceeded,
                                QuotaExceeded)
from repro.serve.trace_store import TraceStore

# Lazy (PEP 562) so `python -m repro.serve.rpc` does not import the rpc
# module twice (once via this package, once as __main__ — runpy warns).
_RPC_EXPORTS = ("RemoteReplica", "ReplicaServer", "spawn_replica",
                "spawn_fleet", "shutdown_fleet")


def __getattr__(name):
    if name in _RPC_EXPORTS:
        from repro.serve import rpc

        return getattr(rpc, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = ["AdmissionController", "Verdict", "PredictionService", "Query",
           "config_fingerprint", "AbacusServer", "DeadlineExceeded",
           "QuotaExceeded", "TraceStore",
           "FeedbackStore", "Observation", "CalibrationWindow",
           "TenantCalibration",
           "OnlineRefitter", "ModelGeneration", "JsonFileStore",
           "atomic_write_json", "ClusterFrontend", "GatewayReplica",
           "GenerationPublisher", "HashRing", "RingDiff",
           "ReplicaUnavailable", "ReplicaNotRunning", "RemoteReplica",
           "ReplicaServer", "spawn_replica", "spawn_fleet",
           "shutdown_fleet"]
