"""Measured-cost feedback: the observations that close the refit loop.

The serving stack (PR 1-2) predicts every admitted job's (time, memory)
and then throws the *measured* outcome away, so drift between predicted
and realized cost silently degrades admission quality. This module
persists those outcomes and tracks calibration:

  * ``Observation`` — one finished job's measured ``(time_s, mem_bytes)``
    plus the prediction context (generation, timestamp, job id).
  * ``FeedbackStore`` — durable ``(config fingerprint, batch, seq) ->
    {obs_id: Observation}`` map on disk, same atomic temp+``os.replace``
    / versioned-schema / corrupt-files-are-skipped discipline as
    ``TraceStore``. Observation ids are content-derived when the caller
    supplies none, so re-reporting the same completion is idempotent and
    ``merge`` (union by id) is order-independent — the property multi-
    host aggregation will rely on.
  * ``CalibrationWindow`` — rolling predicted-vs-observed window with
    per-generation MRE and signed drift, surfaced via
    ``AbacusServer.stats()``.

Cross-process writes to the *same key* are last-writer-wins (one file
per key, re-read + union under a process-local lock before each write);
concurrent writers never corrupt a file, they can only drop each
other's newest observation for that key — one lost data point, never a
torn record.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import threading
import time
from collections import deque
from typing import Dict, Iterator, List, Optional, Tuple

StoreKey = Tuple[str, int, int]  # (config fingerprint, batch, seq)

SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Observation:
    """One finished job's measured cost (plus prediction context)."""
    time_s: float
    mem_bytes: float
    generation: Optional[int] = None  # generation that predicted this job
    ts: float = 0.0                   # wall-clock seconds (0 = unknown)
    job_id: str = ""                  # admission job id ('' = anonymous)

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "Observation":
        return cls(time_s=float(d["time_s"]), mem_bytes=float(d["mem_bytes"]),
                   generation=(None if d.get("generation") is None
                               else int(d["generation"])),
                   ts=float(d.get("ts", 0.0)), job_id=str(d.get("job_id", "")))


def observation_id(key: StoreKey, obs: Observation) -> str:
    """Content-derived id: identical reports dedupe, merges commute.

    For job-identified observations the wall-clock ``ts`` is excluded
    from the id — a *retried* completion report for the same job (and
    same measurements) dedupes even though it carries a fresh
    timestamp. Anonymous observations keep ``ts`` in the id so two
    genuinely distinct runs with identical measurements stay distinct.
    """
    payload = obs.as_dict()
    if obs.job_id:
        payload.pop("ts")
    blob = json.dumps([list(key), payload], sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


@dataclasses.dataclass
class FeedbackStats:
    adds: int = 0        # observations accepted (new ids)
    duplicates: int = 0  # re-reported ids ignored
    merged: int = 0      # observations imported by merge()
    corrupt: int = 0     # files skipped: unparseable / wrong version / bad key

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class FeedbackStore:
    """Durable measured-cost observations, one JSON file per key."""

    def __init__(self, root: str):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)
        self.stats = FeedbackStats()
        # reentrant: read-modify-write holds it across _load_payload,
        # which may itself take it to count a corrupt file
        self._lock = threading.RLock()
        # observation count is cached: threshold checks / stats polls run
        # on every observe() and must not re-scan the whole directory.
        # Seeded by one startup scan; add/merge/clear keep it current for
        # THIS process (a concurrent process's writes surface on rescan).
        self._total: Optional[int] = None

    # -- key/file mapping ---------------------------------------------------
    @staticmethod
    def filename(key: StoreKey) -> str:
        fp, batch, seq = key
        return f"fb_{fp}_b{int(batch)}_s{int(seq)}.json"

    def path_for(self, key: StoreKey) -> str:
        return os.path.join(self.root, self.filename(key))

    def _files(self) -> List[str]:
        try:
            names = os.listdir(self.root)
        except OSError:
            return []
        return sorted(n for n in names
                      if n.startswith("fb_") and n.endswith(".json"))

    def _load_payload(self, path: str) -> Optional[Dict]:
        """Parsed payload for one key file, or None (corrupt counted)."""
        if not os.path.exists(path):
            return None
        try:
            with open(path) as f:
                payload = json.load(f)
            if payload.get("version") != SCHEMA_VERSION:
                raise ValueError(f"schema version {payload.get('version')!r}")
            fp, batch, seq = payload["key"]
            payload["key"] = (str(fp), int(batch), int(seq))
            if not isinstance(payload.get("obs"), dict):
                raise ValueError("missing observation map")
            return payload
        except (OSError, ValueError, KeyError, TypeError):
            with self._lock:
                self.stats.corrupt += 1
                self._total = None  # count is suspect: rescan on next total()
            return None

    def _write_payload(self, key: StoreKey, obs: Dict[str, Dict]) -> None:
        from repro.serve.trace_store import atomic_write_json

        payload = {"version": SCHEMA_VERSION,
                   "key": [key[0], int(key[1]), int(key[2])], "obs": obs}
        atomic_write_json(self.root, self.path_for(key), payload)

    # -- writes -------------------------------------------------------------
    def add(self, key: StoreKey, time_s: float, mem_bytes: float,
            generation: Optional[int] = None, job_id: str = "",
            ts: Optional[float] = None) -> str:
        """Record one measured outcome; returns its observation id.

        Re-adding an identical observation (same content-derived id) is
        a no-op, so completion reports can be retried safely.
        """
        obs = Observation(time_s=float(time_s), mem_bytes=float(mem_bytes),
                          generation=generation,
                          ts=time.time() if ts is None else float(ts),
                          job_id=str(job_id))
        oid = observation_id(key, obs)
        with self._lock:
            payload = self._load_payload(self.path_for(key))
            existing = payload["obs"] if payload is not None else {}
            if oid in existing:
                self.stats.duplicates += 1
                return oid
            existing[oid] = obs.as_dict()
            self._write_payload(key, existing)
            self.stats.adds += 1
            if self._total is not None:
                self._total += 1
        return oid

    def merge(self, other: "FeedbackStore") -> int:
        """Union another store's observations into this one (by id).

        Union-by-content-id makes the merge commutative and idempotent:
        ``a.merge(b)`` then ``a.merge(c)`` yields the same contents as
        any other order — the property multi-host aggregation needs.
        Returns how many observations were new to this store.
        """
        imported = 0
        for key, obs_map in other.items():
            with self._lock:
                payload = self._load_payload(self.path_for(key))
                existing = payload["obs"] if payload is not None else {}
                fresh = {oid: o.as_dict() for oid, o in obs_map.items()
                         if oid not in existing}
                if not fresh:
                    continue
                existing.update(fresh)
                self._write_payload(key, existing)
                self.stats.merged += len(fresh)
                if self._total is not None:
                    self._total += len(fresh)
            imported += len(fresh)
        return imported

    # -- reads --------------------------------------------------------------
    def get(self, key: StoreKey) -> List[Observation]:
        """Observations for ``key`` in deterministic (ts, id) order."""
        payload = self._load_payload(self.path_for(key))
        if payload is None:
            return []
        out = []
        for oid, d in payload["obs"].items():
            try:
                out.append((oid, Observation.from_dict(d)))
            except (KeyError, TypeError, ValueError):
                with self._lock:
                    self.stats.corrupt += 1
        return [o for _, o in sorted(out, key=lambda e: (e[1].ts, e[0]))]

    def items(self) -> Iterator[Tuple[StoreKey, Dict[str, Observation]]]:
        """(key, {obs_id: Observation}) for every loadable key file."""
        for name in self._files():
            payload = self._load_payload(os.path.join(self.root, name))
            if payload is None:
                continue
            obs = {}
            for oid, d in payload["obs"].items():
                try:
                    obs[oid] = Observation.from_dict(d)
                except (KeyError, TypeError, ValueError):
                    with self._lock:
                        self.stats.corrupt += 1
            yield payload["key"], obs

    def grouped(self) -> Dict[StoreKey, List[Observation]]:
        """key -> observations, each list in deterministic (ts, id) order."""
        return {key: [o for _, o in
                      sorted(obs.items(), key=lambda e: (e[1].ts, e[0]))]
                for key, obs in self.items()}

    def keys(self) -> List[StoreKey]:
        return [key for key, _ in self.items()]

    def snapshot(self) -> Dict[StoreKey, Dict[str, Dict]]:
        """Canonical content view (for equality checks across stores)."""
        return {key: {oid: o.as_dict() for oid, o in obs.items()}
                for key, obs in self.items()}

    def total(self, rescan: bool = False) -> int:
        """Total observation count across all keys.

        Served from the in-process counter (seeded by one directory
        scan, maintained by ``add``/``merge``/``clear``) so hot callers
        — refit threshold checks, ``server.stats()`` polls — cost O(1)
        instead of re-parsing every file. ``rescan=True`` forces a
        directory scan (picks up writes from other processes).
        """
        with self._lock:
            if rescan or self._total is None:
                self._total = sum(len(obs) for _, obs in self.items())
            return self._total

    def __len__(self) -> int:
        """Number of keys with at least one loadable observation."""
        return sum(1 for _ in self.items())

    def oldest_ts(self) -> Optional[float]:
        """Earliest observation timestamp, or None when empty."""
        ts = [o.ts for _, obs in self.items() for o in obs.values()]
        return min(ts) if ts else None

    def clear(self) -> int:
        n = 0
        for name in self._files():
            try:
                os.unlink(os.path.join(self.root, name))
                n += 1
            except OSError:
                pass
        with self._lock:
            self._total = 0
        return n

    def compact(self, max_age_s: Optional[float] = None,
                max_per_key: Optional[int] = None) -> Dict[str, int]:
        """Prune the store: drop stale observations, cap per-key history.

        A long-lived deployment (e.g. every ``dryrun --predict`` sweep
        appending here) grows without bound otherwise — and refit
        targets only use each key's newest window anyway. Observations
        older than ``max_age_s`` are dropped; each key keeps at most its
        ``max_per_key`` newest (by timestamp); unparseable files and
        keys left empty are deleted. Returns removal counts.
        """
        now = time.time()
        removed = {"expired": 0, "over_cap": 0, "corrupt_files": 0}
        for name in self._files():
            path = os.path.join(self.root, name)
            with self._lock:
                payload = self._load_payload(path)
                if payload is None:
                    try:
                        os.unlink(path)
                        removed["corrupt_files"] += 1
                    except OSError:
                        pass
                    continue
                obs = payload["obs"]
                keep = dict(obs)
                if max_age_s is not None:
                    fresh = {oid: d for oid, d in keep.items()
                             if now - float(d.get("ts", 0.0)) <= max_age_s}
                    removed["expired"] += len(keep) - len(fresh)
                    keep = fresh
                if max_per_key is not None and len(keep) > max_per_key:
                    newest = sorted(keep.items(),
                                    key=lambda e: (float(e[1].get("ts", 0.0)),
                                                   e[0]))[-max_per_key:]
                    removed["over_cap"] += len(keep) - len(newest)
                    keep = dict(newest)
                if len(keep) == len(obs):
                    continue
                if keep:
                    self._write_payload(payload["key"], keep)
                else:
                    try:
                        os.unlink(path)
                    except OSError:
                        pass
                self._total = None  # recount lazily
        return {**removed,
                "removed": removed["expired"] + removed["over_cap"],
                "kept": self.total(rescan=True)}

    def info(self) -> Dict[str, int]:
        return {"feedback_keys": len(self._files()),
                "feedback_total": self.total(), **self.stats.as_dict()}


class CalibrationWindow:
    """Rolling predicted-vs-observed calibration (windowed MRE + drift).

    ``observe`` records one completed job; ``metrics`` reports, over the
    last ``window`` completions: MRE for time and memory (the paper's
    metric, now measured online), signed relative drift
    (mean((pred - obs) / obs); negative = the predictor underestimates),
    and the same per prediction generation — the split that shows a
    refit actually helped (old-generation MRE vs new-generation MRE).
    """

    def __init__(self, window: int = 256):
        self.window = int(window)
        self._obs: deque = deque(maxlen=self.window)
        self._lock = threading.Lock()

    def observe(self, pred_time_s: float, obs_time_s: float,
                pred_mem_bytes: float, obs_mem_bytes: float,
                generation: Optional[int] = None) -> None:
        with self._lock:
            self._obs.append((float(pred_time_s), float(obs_time_s),
                              float(pred_mem_bytes), float(obs_mem_bytes),
                              generation))

    @staticmethod
    def _agg(rows) -> Dict[str, float]:
        def rel(pred, obs):
            return (pred - obs) / obs if obs else math.inf
        t_rel = [rel(pt, ot) for pt, ot, _, _, _ in rows]
        m_rel = [rel(pm, om) for _, _, pm, om, _ in rows]
        n = len(rows)
        return {"count": n,
                "time_mre": sum(abs(r) for r in t_rel) / n,
                "mem_mre": sum(abs(r) for r in m_rel) / n,
                "time_drift": sum(t_rel) / n,
                "mem_drift": sum(m_rel) / n}

    def metrics(self) -> Dict:
        with self._lock:
            rows = list(self._obs)
        if not rows:
            return {"count": 0, "time_mre": None, "mem_mre": None,
                    "time_drift": None, "mem_drift": None,
                    "by_generation": {}}
        by_gen: Dict[Optional[int], list] = {}
        for row in rows:
            by_gen.setdefault(row[4], []).append(row)
        out = self._agg(rows)
        out["by_generation"] = {gen: self._agg(grp)
                                for gen, grp in sorted(
                                    by_gen.items(),
                                    key=lambda e: (-1 if e[0] is None
                                                   else e[0]))}
        return out

    def reset(self) -> None:
        with self._lock:
            self._obs.clear()
