"""Measured-cost feedback: the observations that close the refit loop.

The serving stack (PR 1-2) predicts every admitted job's (time, memory)
and then throws the *measured* outcome away, so drift between predicted
and realized cost silently degrades admission quality. This module
persists those outcomes and tracks calibration:

  * ``Observation`` — one finished job's measured ``(time_s, mem_bytes)``
    plus the prediction context (generation, timestamp, job id).
  * ``FeedbackStore`` / ``SegmentFeedbackStore`` — durable
    ``(config fingerprint, batch, seq) -> {obs_id: Observation}`` map on
    disk, the ``FeedbackValues`` mixin composed with either
    ``repro.serve.kvstore`` engine (file-per-key JSON, or the
    append-only segment log; ``make_feedback_store`` selects by name or
    the ``REPRO_STORE_BACKEND`` env var). All persistence mechanics
    (atomic writes, the shared schema version, corrupt-records-skipped
    loads, order-independent ``merge``) live in the engines.
    Observation ids are content-derived when the caller supplies none,
    so re-reporting the same completion is idempotent and ``merge``
    (union by id) is order-independent — the property multi-host
    aggregation relies on.
  * ``CalibrationWindow`` — rolling predicted-vs-observed window with
    per-generation MRE and signed drift, surfaced via
    ``AbacusServer.stats()``.

Cross-process writes to the *same key* are last-writer-wins (one file
per key, re-read + union under a process-local lock before each write);
concurrent writers never corrupt a file, they can only drop each
other's newest observation for that key — one lost data point, never a
torn record.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import threading
import time
from collections import deque
from typing import Dict, Iterator, List, Optional, Tuple

from repro.serve.kvstore import (SCHEMA_VERSION, JsonFileStore,
                                 SegmentLogStore, StoreKey, store_backend)

__all__ = ["Observation", "observation_id", "FeedbackStats", "FeedbackStore",
           "SegmentFeedbackStore", "make_feedback_store", "FeedbackValues",
           "CalibrationWindow", "TenantCalibration", "StoreKey",
           "SCHEMA_VERSION"]


@dataclasses.dataclass(frozen=True)
class Observation:
    """One finished job's measured cost (plus prediction context)."""
    time_s: float
    mem_bytes: float
    generation: Optional[int] = None  # generation that predicted this job
    ts: float = 0.0                   # wall-clock seconds (0 = unknown)
    job_id: str = ""                  # admission job id ('' = anonymous)

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "Observation":
        return cls(time_s=float(d["time_s"]), mem_bytes=float(d["mem_bytes"]),
                   generation=(None if d.get("generation") is None
                               else int(d["generation"])),
                   ts=float(d.get("ts", 0.0)), job_id=str(d.get("job_id", "")))


def observation_id(key: StoreKey, obs: Observation) -> str:
    """Content-derived id: identical reports dedupe, merges commute.

    For job-identified observations the wall-clock ``ts`` is excluded
    from the id — a *retried* completion report for the same job (and
    same measurements) dedupes even though it carries a fresh
    timestamp. Anonymous observations keep ``ts`` in the id so two
    genuinely distinct runs with identical measurements stay distinct.
    """
    payload = obs.as_dict()
    if obs.job_id:
        payload.pop("ts")
    blob = json.dumps([list(key), payload], sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


@dataclasses.dataclass
class FeedbackStats:
    adds: int = 0        # observations accepted (new ids)
    duplicates: int = 0  # re-reported ids ignored
    merged: int = 0      # observations imported by merge()
    corrupt: int = 0     # files skipped: unparseable / wrong version / bad key

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class FeedbackValues:
    """Feedback value semantics, independent of physical layout.

    Defines what a *feedback* value is — the ``{obs_id: Observation}``
    map, id-union merge, dedup-on-add, the observation-level ``compact``
    — as a mixin over any ``repro.serve.kvstore`` engine.
    """

    FILE_PREFIX = "fb_"
    VALUE_FIELD = "obs"

    def __init__(self, root: str, **kwargs):
        super().__init__(root, **kwargs)
        self.stats = FeedbackStats()
        # observation count is cached: threshold checks / stats polls run
        # on every observe() and must not re-scan the whole directory.
        # Seeded by one startup scan; add/merge/clear keep it current for
        # THIS process (a concurrent process's writes surface on rescan).
        self._total: Optional[int] = None

    # -- store engine hooks -------------------------------------------------
    def _check_raw(self, raw):
        if not isinstance(raw, dict):
            raise ValueError("missing observation map")
        return raw

    def _merge_raw(self, mine, theirs):
        """Union by observation id; malformed foreign entries skipped."""
        existing = dict(mine or {})
        fresh = {}
        for oid, d in theirs.items():
            if oid in existing:
                continue
            try:
                Observation.from_dict(d)
            except (KeyError, TypeError, ValueError):
                self._note_corrupt()
                continue
            fresh[oid] = d
        if not fresh:
            return existing, 0
        existing.update(fresh)
        return existing, len(fresh)

    def _note_corrupt(self) -> None:
        with self._lock:
            self.stats.corrupt += 1
            self._total = None  # count is suspect: rescan on next total()

    def _on_merge(self, key: StoreKey, n_new: int) -> None:
        with self._lock:
            self.stats.merged += n_new
            if self._total is not None:
                self._total += n_new

    def _on_split(self, n_removed: int) -> None:
        with self._lock:
            self._total = None  # whole key files left: recount lazily

    # -- writes -------------------------------------------------------------
    def add(self, key: StoreKey, time_s: float, mem_bytes: float,
            generation: Optional[int] = None, job_id: str = "",
            ts: Optional[float] = None) -> str:
        """Record one measured outcome; returns its observation id.

        Re-adding an identical observation (same content-derived id) is
        a no-op, so completion reports can be retried safely.
        """
        obs = Observation(time_s=float(time_s), mem_bytes=float(mem_bytes),
                          generation=generation,
                          ts=time.time() if ts is None else float(ts),
                          job_id=str(job_id))
        oid = observation_id(key, obs)
        with self._lock:
            existing = self.get_raw(key) or {}
            if oid in existing:
                self.stats.duplicates += 1
                return oid
            existing[oid] = obs.as_dict()
            self.put_raw(key, existing)
            self.stats.adds += 1
            if self._total is not None:
                self._total += 1
        return oid

    # -- reads --------------------------------------------------------------
    def _validated(self, raw: Dict) -> Dict[str, Observation]:
        out = {}
        for oid, d in raw.items():
            try:
                out[oid] = Observation.from_dict(d)
            except (KeyError, TypeError, ValueError):
                self._note_corrupt()
        return out

    def get(self, key: StoreKey) -> List[Observation]:
        """Observations for ``key`` in deterministic (ts, id) order."""
        raw = self.get_raw(key)
        if raw is None:
            return []
        obs = self._validated(raw)
        return [o for _, o in sorted(obs.items(),
                                     key=lambda e: (e[1].ts, e[0]))]

    def items(self) -> Iterator[Tuple[StoreKey, Dict[str, Observation]]]:
        """(key, {obs_id: Observation}) for every loadable key file."""
        for key, raw in self.iter_raw():
            yield key, self._validated(raw)

    def grouped(self) -> Dict[StoreKey, List[Observation]]:
        """key -> observations, each list in deterministic (ts, id) order."""
        return {key: [o for _, o in
                      sorted(obs.items(), key=lambda e: (e[1].ts, e[0]))]
                for key, obs in self.items()}

    def keys(self) -> List[StoreKey]:
        return [key for key, _ in self.items()]

    def snapshot(self) -> Dict[StoreKey, Dict[str, Dict]]:
        """Canonical content view (for equality checks across stores)."""
        return {key: {oid: o.as_dict() for oid, o in obs.items()}
                for key, obs in self.items()}

    def total(self, rescan: bool = False) -> int:
        """Total observation count across all keys.

        Served from the in-process counter (seeded by one directory
        scan, maintained by ``add``/``merge``/``clear``) so hot callers
        — refit threshold checks, ``server.stats()`` polls — cost O(1)
        instead of re-parsing every file. ``rescan=True`` forces a
        directory scan (picks up writes from other processes).
        """
        with self._lock:
            if rescan or self._total is None:
                self._total = sum(len(obs) for _, obs in self.items())
            return self._total

    def __len__(self) -> int:
        """Number of keys with at least one loadable observation."""
        return sum(1 for _ in self.items())

    def oldest_ts(self) -> Optional[float]:
        """Earliest observation timestamp, or None when empty."""
        ts = [o.ts for _, obs in self.items() for o in obs.values()]
        return min(ts) if ts else None

    def clear(self) -> int:
        n = super().clear()
        with self._lock:
            self._total = 0
        return n

    def compact(self, max_age_s: Optional[float] = None,
                max_per_key: Optional[int] = None) -> Dict[str, int]:
        """Prune the store: drop stale observations, cap per-key history.

        Finer-grained than the base file-level compact: observations
        older than ``max_age_s`` are dropped *within* each key file,
        each key keeps at most its ``max_per_key`` newest (by
        timestamp; the newest observation per key always survives),
        unparseable files and keys left empty are deleted. A long-lived
        deployment (e.g. every ``dryrun --predict`` sweep appending
        here) grows without bound otherwise — and refit targets only
        use each key's newest window anyway. Returns removal counts.

        Layout-agnostic: records that no longer load are purged through
        the engine (``_purge_unloadable``), per-observation pruning goes
        through ``get_raw``/``put_raw``/``_delete_key``, and the final
        ``_reclaim`` lets the segment engine rewrite away dead bytes
        (a no-op for the file-per-key layout).
        """
        now = time.time()
        removed = {"expired": 0, "over_cap": 0,
                   "corrupt_files": self._purge_unloadable()}
        for key in [k for k, _ in self.iter_raw()]:
            with self._lock:
                obs = self.get_raw(key)
                if obs is None:
                    continue  # vanished/corrupted since the listing
                keep = dict(obs)
                if max_age_s is not None:
                    fresh = {oid: d for oid, d in keep.items()
                             if now - float(d.get("ts", 0.0)) <= max_age_s}
                    removed["expired"] += len(keep) - len(fresh)
                    keep = fresh
                if max_per_key is not None and len(keep) > max_per_key:
                    newest = sorted(keep.items(),
                                    key=lambda e: (float(e[1].get("ts", 0.0)),
                                                   e[0]))[-max_per_key:]
                    removed["over_cap"] += len(keep) - len(newest)
                    keep = dict(newest)
                if len(keep) == len(obs):
                    continue
                if keep:
                    self.put_raw(key, keep)
                else:
                    self._delete_key(key)
                self._total = None  # recount lazily
        self._reclaim()  # segment engine: rewrite away the dead bytes
        return {**removed,
                "removed": removed["expired"] + removed["over_cap"],
                "kept": self.total(rescan=True)}

    def info(self) -> Dict[str, int]:
        return {"feedback_keys": len(self),
                "feedback_total": self.total(), **self.stats.as_dict()}


class FeedbackStore(FeedbackValues, JsonFileStore):
    """Durable measured-cost observations, one JSON file per key (the
    historical layout)."""


class SegmentFeedbackStore(FeedbackValues, SegmentLogStore):
    """Feedback store on the append-only segment-log engine."""


def make_feedback_store(root: str,
                        backend: Optional[str] = None) -> FeedbackValues:
    """Feedback store on the selected engine (arg >
    ``REPRO_STORE_BACKEND`` env var > ``json``)."""
    cls = {"json": FeedbackStore,
           "segment": SegmentFeedbackStore}[store_backend(backend)]
    return cls(root)


class CalibrationWindow:
    """Rolling predicted-vs-observed calibration (windowed MRE + drift).

    ``observe`` records one completed job; ``metrics`` reports, over the
    last ``window`` completions: MRE for time and memory (the paper's
    metric, now measured online), signed relative drift
    (mean((pred - obs) / obs); negative = the predictor underestimates),
    and the same per prediction generation — the split that shows a
    refit actually helped (old-generation MRE vs new-generation MRE).
    """

    def __init__(self, window: int = 256):
        self.window = int(window)
        self._obs: deque = deque(maxlen=self.window)
        self._lock = threading.Lock()

    def observe(self, pred_time_s: float, obs_time_s: float,
                pred_mem_bytes: float, obs_mem_bytes: float,
                generation: Optional[int] = None) -> None:
        with self._lock:
            self._obs.append((float(pred_time_s), float(obs_time_s),
                              float(pred_mem_bytes), float(obs_mem_bytes),
                              generation))

    @staticmethod
    def _agg(rows) -> Dict[str, float]:
        def rel(pred, obs):
            return (pred - obs) / obs if obs else math.inf
        t_rel = [rel(pt, ot) for pt, ot, _, _, _ in rows]
        m_rel = [rel(pm, om) for _, _, pm, om, _ in rows]
        n = len(rows)
        return {"count": n,
                "time_mre": sum(abs(r) for r in t_rel) / n,
                "mem_mre": sum(abs(r) for r in m_rel) / n,
                "time_drift": sum(t_rel) / n,
                "mem_drift": sum(m_rel) / n}

    def metrics(self) -> Dict:
        with self._lock:
            rows = list(self._obs)
        if not rows:
            return {"count": 0, "time_mre": None, "mem_mre": None,
                    "time_drift": None, "mem_drift": None,
                    "by_generation": {}}
        by_gen: Dict[Optional[int], list] = {}
        for row in rows:
            by_gen.setdefault(row[4], []).append(row)
        out = self._agg(rows)
        out["by_generation"] = {gen: self._agg(grp)
                                for gen, grp in sorted(
                                    by_gen.items(),
                                    key=lambda e: (-1 if e[0] is None
                                                   else e[0]))}
        return out

    def reset(self) -> None:
        with self._lock:
            self._obs.clear()


class TenantCalibration:
    """Per-tenant :class:`CalibrationWindow` family, keyed by job owner.

    Admission inflates a tenant's reservations by that tenant's own
    observed drift (a tenant whose jobs consistently run 30% hotter than
    predicted reserves 30% more), instead of letting one noisy tenant
    skew the shared window. Untenanted observations (``tenant == ""``)
    still land in the shared ``CalibrationWindow`` owned by the server;
    this class only tracks named tenants.
    """

    def __init__(self, window: int = 256):
        self.window = int(window)
        self._tenants: Dict[str, CalibrationWindow] = {}
        self._lock = threading.Lock()

    def window_for(self, tenant: str) -> CalibrationWindow:
        with self._lock:
            win = self._tenants.get(tenant)
            if win is None:
                win = self._tenants[tenant] = CalibrationWindow(self.window)
            return win

    def observe(self, tenant: str, pred_time_s: float, obs_time_s: float,
                pred_mem_bytes: float, obs_mem_bytes: float,
                generation: Optional[int] = None) -> None:
        if not tenant:
            return
        self.window_for(tenant).observe(pred_time_s, obs_time_s,
                                        pred_mem_bytes, obs_mem_bytes,
                                        generation=generation)

    def inflation(self, tenant: str, kind: str = "time", *,
                  cap: float = 2.0, min_count: int = 8) -> float:
        """Reservation multiplier from the tenant's observed drift.

        Drift is ``mean((pred - obs) / obs)``; negative means the
        predictor underestimates this tenant, so reservations scale by
        ``1 / (1 + drift)`` (clamped to ``[1.0, cap]``). Overestimating
        tenants are left alone — admission never shrinks a reservation
        below the prediction. Fewer than ``min_count`` observations is
        no evidence: multiplier 1.0.
        """
        if not tenant:
            return 1.0
        with self._lock:
            win = self._tenants.get(tenant)
        if win is None:
            return 1.0
        m = win.metrics()
        if (m["count"] or 0) < min_count:
            return 1.0
        drift = m.get(f"{kind}_drift")
        if drift is None or drift >= 0.0:
            return 1.0
        denom = 1.0 + drift
        if denom <= 0.0:
            return float(cap)
        return float(min(cap, max(1.0, 1.0 / denom)))

    def metrics(self) -> Dict[str, Dict]:
        with self._lock:
            tenants = dict(self._tenants)
        return {t: win.metrics() for t, win in sorted(tenants.items())}

    def reset(self) -> None:
        with self._lock:
            self._tenants.clear()
