"""RPC transport: the in-process fleet, process-separated.

``ClusterFrontend`` (``repro.serve.cluster``) was built transport-
agnostic: it touches replicas only through the gateway interface
(``submit``/``observe``/``publish_generation``/``stats``/``stop``/
``service``). This module supplies the first real transport so the
fleet matches the paper's datacenter setting — predictors deployed
across hosts that crash, stall, and answer over a wire:

  * **frame protocol** — length-prefixed JSON over TCP: a 4-byte
    big-endian payload length followed by one UTF-8 JSON object.
    Requests carry ``{"id", "op", ...params}``; responses echo the id
    with ``{"ok": true, "result"}`` or ``{"ok": false, "error",
    "kind"}``. Replies are matched by id, so they may arrive out of
    order — a slow micro-batch never head-of-line-blocks a ping.
  * **``ReplicaServer``** — an asyncio TCP server wrapping one
    ``GatewayReplica`` in its own process (``python -m
    repro.serve.rpc``). Blocking gateway calls run on an executor and
    submit replies are sent from the worker's Future callback, so the
    event loop keeps answering heartbeats while a batch is in flight.
  * **``RemoteReplica``** — the client stub implementing the replica
    interface over a blocking socket + background reader thread
    (request-id multiplexed Futures) + heartbeat thread. Every call is
    timeout-bounded. Missed heartbeats (or a dropped connection — a
    ``kill -9`` closes the socket) mark the replica ``dead``, fail all
    in-flight Futures with ``ReplicaUnavailable``, and fire ``on_dead``
    — which the frontend answers by resharding the member out
    (``ClusterFrontend.exclude_replica``) and hedging/retrying the
    affected queries to the next ring owner.

**Shared-disk assumption.** ``RemoteReplica`` holds *local*
``TraceStore``/``FeedbackStore`` handles over the same directories its
server process writes through. That one assumption makes the PR 5
reshard machinery work unchanged for remote members: slice migration
(``JsonFileStore.split``) and the crash-restart rebuild read the dead
replica's authoritative on-disk state directly — warm keys move to the
new owners with zero re-traces and no new transport code. Deployments
without a shared filesystem would substitute a store proxy here.

``synthetic_trace`` is the deterministic, dependency-free tracer the
multi-process tests and the chaos bench point every replica at (via
``--tracer repro.serve.rpc:synthetic_trace``): real jaxpr tracing in N
spawned processes would dwarf the transport behavior under test, and
determinism is what lets a hedged duplicate or a rebuilt slice converge
byte-for-byte with the in-process fleet.
"""

from __future__ import annotations

import argparse
import asyncio
import dataclasses
import importlib
import itertools
import json
import os
import select
import socket
import subprocess
import sys
import threading
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FutureTimeout
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.features import ProfileRecord
from repro.core.predictor import DNNAbacus
from repro.obs import events
from repro.serve.cluster import (GatewayReplica, ReplicaNotRunning,
                                 ReplicaUnavailable)
from repro.serve.feedback_store import make_feedback_store
from repro.serve.prediction_service import Query
from repro.serve.refit import ModelGeneration
from repro.serve.server import (DeadlineExceeded, QuotaExceeded,
                                ServerStats)
from repro.serve.trace_store import TraceStore, make_trace_store

MAX_FRAME = 64 << 20  # one serialized DNNAbacus generation fits with room


class RPCError(RuntimeError):
    """The remote gateway raised while serving the call (application
    error, e.g. an untraceable config). NOT retryable — the same query
    fails the same way on any replica."""


# -- frame protocol ----------------------------------------------------------

def pack_frame(obj) -> bytes:
    """One wire frame: 4-byte big-endian length + UTF-8 JSON payload."""
    data = json.dumps(obj, separators=(",", ":")).encode()
    if len(data) > MAX_FRAME:
        raise ValueError(f"frame of {len(data)} bytes exceeds MAX_FRAME")
    return len(data).to_bytes(4, "big") + data


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """Exactly ``n`` bytes from a blocking socket, or None on EOF."""
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


def read_frame_sock(sock: socket.socket) -> Optional[Dict]:
    """Read one frame from a blocking socket; None on clean EOF."""
    header = _recv_exact(sock, 4)
    if header is None:
        return None
    n = int.from_bytes(header, "big")
    if n > MAX_FRAME:
        raise ValueError(f"incoming frame of {n} bytes exceeds MAX_FRAME")
    data = _recv_exact(sock, n)
    if data is None:
        return None
    return json.loads(data.decode())


async def read_frame_async(reader: asyncio.StreamReader) -> Optional[Dict]:
    """Read one frame from an asyncio stream; None on clean EOF."""
    try:
        header = await reader.readexactly(4)
    except (asyncio.IncompleteReadError, ConnectionError):
        return None
    n = int.from_bytes(header, "big")
    if n > MAX_FRAME:
        raise ValueError(f"incoming frame of {n} bytes exceeds MAX_FRAME")
    try:
        data = await reader.readexactly(n)
    except (asyncio.IncompleteReadError, ConnectionError):
        return None
    return json.loads(data.decode())


# -- config codec ------------------------------------------------------------
#
# Configs cross the wire by value. Fingerprints distinguish tuples from
# lists (see prediction_service._canonical), so the codec must round-trip
# that distinction — tuples are tagged, never silently listified.

def _encode_value(v):
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, tuple):
        return {"__tuple__": [_encode_value(x) for x in v]}
    if isinstance(v, list):
        return [_encode_value(x) for x in v]
    if isinstance(v, dict):
        if not all(isinstance(k, str) for k in v):
            raise TypeError("config dict fields need str keys on the wire")
        return {"__dict__": {k: _encode_value(x) for k, x in v.items()}}
    raise TypeError(
        f"config field of type {type(v).__name__} is not wire-serializable")


def _decode_value(v):
    if isinstance(v, list):
        return [_decode_value(x) for x in v]
    if isinstance(v, dict):
        if set(v) == {"__tuple__"}:
            return tuple(_decode_value(x) for x in v["__tuple__"])
        if set(v) == {"__dict__"}:
            return {k: _decode_value(x) for k, x in v["__dict__"].items()}
        raise ValueError(f"unrecognized wire value: {sorted(v)}")
    return v


class WireConfig:
    """Attribute-duck reconstruction of a config that crossed the wire.

    ``config_fingerprint`` canonicalizes duck-typed configs over
    ``vars()``, so a ``WireConfig`` carrying the same attributes
    fingerprints identically to the original duck-typed config — and
    tracers read config *attributes*, never its class.
    """

    def __init__(self, attrs: Dict):
        self.__dict__.update(attrs)

    def __repr__(self) -> str:
        return f"WireConfig({self.__dict__!r})"


def encode_config(cfg) -> Dict:
    if dataclasses.is_dataclass(cfg) and not isinstance(cfg, type):
        return {"dataclass":
                f"{type(cfg).__module__}:{type(cfg).__qualname__}",
                "fields": {f.name: _encode_value(getattr(cfg, f.name))
                           for f in dataclasses.fields(cfg)}}
    return {"attrs": {k: _encode_value(v) for k, v in vars(cfg).items()}}


def decode_config(d: Dict):
    if "dataclass" in d:
        fields = {k: _decode_value(v) for k, v in d["fields"].items()}
        mod, _, qual = d["dataclass"].partition(":")
        try:
            cls = importlib.import_module(mod)
            for part in qual.split("."):
                cls = getattr(cls, part)
            return cls(**fields)
        except Exception:
            # class not importable here: the attribute-duck stands in
            # (fingerprint parity matters only when fp isn't forwarded)
            return WireConfig(fields)
    return WireConfig({k: _decode_value(v) for k, v in d["attrs"].items()})


# -- deterministic tracer for spawned replicas -------------------------------

def synthetic_trace(cfg, batch: int, seq: int) -> ProfileRecord:
    """Deterministic stand-in tracer (no jax, no model build).

    Derives a stable ``ProfileRecord`` purely from the config's
    attributes and ``(batch, seq)`` — any two processes given equal
    inputs produce byte-identical records, which is what lets an RPC
    fleet's estimates match an in-process fleet's exactly and lets a
    hedge-window duplicate trace converge with a migrated slice.
    """
    name = str(getattr(cfg, "name", "anon"))
    # never builtin hash(): records must be process/seed-deterministic
    rng = np.random.default_rng(sum(name.encode()) * 7 + int(batch))
    dots = float(rng.integers(4, 60))
    edges = {("dot", "add"): dots, ("add", "tanh"): dots}
    return ProfileRecord(
        model_name=name, family=str(getattr(cfg, "family", "dense")),
        batch_size=int(batch), input_size=int(seq),
        channels=int(getattr(cfg, "d_model", 64)), learning_rate=1e-3,
        epoch=1, optimizer="adamw",
        layers=int(getattr(cfg, "num_layers", 4)),
        flops=int(batch) * int(seq) * dots * 1e6, params=int(dots * 1e5),
        nsm_edges=edges)


def resolve_tracer(spec: str):
    """``"module:attr"`` -> tracer callable (spawned replicas' CLI)."""
    mod, _, attr = spec.partition(":")
    return getattr(importlib.import_module(mod), attr or "trace_query")


# -- server side -------------------------------------------------------------

_GEN_FIELDS = {f.name for f in dataclasses.fields(ModelGeneration)} \
    - {"number", "abacus"}


class ReplicaServer:
    """Asyncio TCP front for one ``GatewayReplica`` in this process.

    Each connection is served concurrently: every incoming frame
    dispatches as its own task, blocking gateway calls (``observe``,
    ``stop``, ``stats``) run on the default executor, and a ``submit``
    reply is sent from the gateway Future's callback — the event loop
    itself never blocks, so heartbeats stay honest while a micro-batch
    (or a drain) is in flight.
    """

    def __init__(self, replica: GatewayReplica, host: str = "127.0.0.1",
                 port: int = 0):
        self.replica = replica
        self.host = host
        self.port = int(port)
        self._stopping: Optional[asyncio.Event] = None

    def run_forever(self, ready_cb=None) -> None:
        """Serve until a ``shutdown`` op arrives; blocks the caller."""
        asyncio.run(self._serve(ready_cb))

    async def _serve(self, ready_cb=None) -> None:
        self._stopping = asyncio.Event()
        server = await asyncio.start_server(self._handle, self.host,
                                            self.port)
        self.port = server.sockets[0].getsockname()[1]
        if ready_cb is not None:
            ready_cb(self.port)
        async with server:
            await self._stopping.wait()

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        send_lock = asyncio.Lock()

        async def send(payload: Dict) -> None:
            async with send_lock:
                writer.write(pack_frame(payload))
                await writer.drain()

        try:
            while True:
                msg = await read_frame_async(reader)
                if msg is None:
                    break
                asyncio.ensure_future(self._dispatch(msg, send))
        except (ConnectionError, ValueError):
            pass
        finally:
            try:
                writer.close()
            except Exception:
                pass

    async def _dispatch(self, msg: Dict, send) -> None:
        mid, op = msg.get("id"), msg.get("op")
        loop = asyncio.get_running_loop()
        replica, svc = self.replica, self.replica.service
        try:
            if op == "submit":
                # "tc" carries the frontend's trace context across the
                # process boundary; the gateway's tick stamps spans and
                # ships them back inside the estimate ("_trace").
                # Deadlines cross as a *remaining budget* ("deadline_in",
                # seconds) because monotonic clocks don't compare across
                # processes; the absolute deadline is rebuilt here.
                kw = {}
                if msg.get("tenant"):
                    kw["tenant"] = str(msg["tenant"])
                if msg.get("deadline_in") is not None:
                    kw["deadline"] = (time.monotonic()
                                      + float(msg["deadline_in"]))
                fut = replica.submit(decode_config(msg["cfg"]),
                                     msg["batch"], msg["seq"],
                                     fp=msg.get("fp"), tc=msg.get("tc"),
                                     **kw)

                def relay(f: Future, mid=mid) -> None:
                    # worker thread -> event loop: schedule the reply
                    try:
                        payload = {"id": mid, "ok": True,
                                   "result": f.result()}
                    except Exception as e:
                        if isinstance(e, DeadlineExceeded):
                            kind = "deadline"
                        elif isinstance(e, QuotaExceeded):
                            kind = "quota"
                        else:
                            kind = "query"
                        payload = {"id": mid, "ok": False,
                                   "error": f"{type(e).__name__}: {e}",
                                   "kind": kind}
                    asyncio.run_coroutine_threadsafe(send(payload), loop)

                fut.add_done_callback(relay)
                return  # reply is deferred to the worker's callback
            elif op == "ping":
                result = {"pid": os.getpid(), "running": replica.running,
                          "draining": replica.draining,
                          "generation": svc.generation,
                          "ticks": replica.stats.ticks}
            elif op == "state":
                result = {"running": replica.running,
                          "draining": replica.draining,
                          "generation": svc.generation}
            elif op == "observe":

                def _observe(m=msg):
                    replica.observe(
                        decode_config(m["cfg"]), m["batch"], m["seq"],
                        m["time_s"], m["mem_bytes"],
                        predicted_time_s=m.get("predicted_time_s"),
                        predicted_mem_bytes=m.get("predicted_mem_bytes"),
                        generation=m.get("generation"),
                        job_id=m.get("job_id", ""), fp=m.get("fp"),
                        tenant=m.get("tenant", ""))

                await loop.run_in_executor(None, _observe)
                result = True
            elif op == "publish_generation":
                gen = self._decode_generation(msg)
                result = bool(await loop.run_in_executor(
                    None, replica.publish_generation, gen))
            elif op == "adopt":
                abacus = DNNAbacus.from_dict(msg["abacus"])
                result = bool(svc.adopt(abacus, int(msg["generation"])))
            elif op == "snapshot":
                abacus, generation = svc.snapshot()
                result = {"abacus": abacus.to_dict(),
                          "generation": generation}
            elif op == "stats":
                result = await loop.run_in_executor(None, replica.stats)
            elif op == "counters":
                result = replica.stats.as_dict()
            elif op == "overload":
                result = replica.overload_counters()
            elif op == "metrics":
                result = await loop.run_in_executor(
                    None, replica.metrics_snapshot)
            elif op == "server_info":
                result = await loop.run_in_executor(None,
                                                    replica.server_info)
            elif op == "start":
                replica.start()
                result = True
            elif op == "stop":
                await loop.run_in_executor(
                    None, lambda: replica.stop(timeout=msg.get("timeout")))
                result = {"draining": replica.draining}
            elif op == "shutdown":
                await loop.run_in_executor(
                    None, lambda: replica.stop(timeout=msg.get("timeout")))
                await send({"id": mid, "ok": True, "result": True})
                self._stopping.set()
                return
            else:
                raise ValueError(f"unknown op {op!r}")
            await send({"id": mid, "ok": True, "result": result})
        except Exception as e:
            # overload raises are typed BEFORE the not_running string
            # check: both subclass RuntimeError, and a quota rejection
            # must never be mistaken for a drained replica (which the
            # frontend would answer by re-routing the query).
            if isinstance(e, QuotaExceeded):
                kind = "quota"
            elif isinstance(e, DeadlineExceeded):
                kind = "deadline"
            elif (op in ("submit",) and isinstance(e, RuntimeError)
                  and "not running" in str(e)):
                kind = "not_running"
            else:
                kind = "error"
            try:
                await send({"id": mid, "ok": False,
                            "error": f"{type(e).__name__}: {e}",
                            "kind": kind})
            except Exception:
                pass  # client went away mid-reply

    @staticmethod
    def _decode_generation(msg: Dict) -> ModelGeneration:
        extra = {k: v for k, v in (msg.get("summary") or {}).items()
                 if k in _GEN_FIELDS}
        return ModelGeneration(number=int(msg["number"]),
                               abacus=DNNAbacus.from_dict(msg["abacus"]),
                               **extra)


# -- client side -------------------------------------------------------------

def _resolve(fut: Future, result) -> None:
    try:
        fut.set_result(result)
    except Exception:
        pass  # cancelled / already failed by a timeout sweep


def _fail(fut: Future, err: Exception) -> None:
    try:
        fut.set_exception(err)
    except Exception:
        pass


def _normalize_calibration(cal: Optional[Dict]) -> Dict:
    """Undo JSON's stringification of ``by_generation`` int/None keys."""
    cal = dict(cal or {})
    by_gen = cal.get("by_generation")
    if isinstance(by_gen, dict):
        fixed = {}
        for k, v in by_gen.items():
            if k in ("null", "None"):
                fixed[None] = v
            else:
                try:
                    fixed[int(k)] = v
                except (TypeError, ValueError):
                    fixed[k] = v
        cal["by_generation"] = fixed
    return cal


class _RemoteStats:
    """Remote ``ServerStats`` mirror: attribute-addressable AND callable.

    ``replica.stats.ticks`` fetches the live counters over the wire
    (cached last-known values once the replica is dead — the exclusion
    reshard still sums ticks over members that can no longer answer);
    ``replica.stats()`` returns the full stats dict, calibration keys
    re-normalized after their JSON round trip.
    """

    _COUNTERS = tuple(ServerStats.COUNTERS)

    def __init__(self, replica: "RemoteReplica"):
        self._replica = replica

    def __call__(self) -> Dict:
        return self._replica._full_stats()

    def as_dict(self) -> Dict[str, int]:
        return dict(self._replica._counters())

    @property
    def mean_batch(self) -> float:
        c = self._replica._counters()
        ticks = c.get("ticks", 0)
        return (c.get("completed", 0) + c.get("failed", 0)) / ticks \
            if ticks else 0.0

    def __getattr__(self, item):
        if item in _RemoteStats._COUNTERS:
            return self._replica._counters().get(item, 0)
        raise AttributeError(item)


class _RemoteService:
    """The slice of ``PredictionService`` the frontend touches, remoted.

    ``store`` is a LOCAL ``TraceStore`` handle over the replica
    process's trace directory (the shared-disk assumption): slice
    migration and crash rebuild read/move the authoritative files
    directly. ``generation`` falls back to the last heartbeat-cached
    value once the replica is dead.
    """

    def __init__(self, replica: "RemoteReplica",
                 store: Optional[TraceStore]):
        self._replica = replica
        self.store = store
        self._generation = 0

    @property
    def generation(self) -> int:
        try:
            st = self._replica._call("state")
            self._generation = int(st.get("generation", self._generation))
        except ReplicaUnavailable:
            pass
        return self._generation

    @property
    def abacus(self) -> DNNAbacus:
        return self.snapshot()[0]

    def snapshot(self) -> Tuple[DNNAbacus, int]:
        d = self._replica._call("snapshot")
        self._generation = int(d["generation"])
        return DNNAbacus.from_dict(d["abacus"]), self._generation

    def adopt(self, abacus, generation: int) -> bool:
        return bool(self._replica._call(
            "adopt", {"abacus": abacus.to_dict(),
                      "generation": int(generation)}))

    def cached_record(self, key):
        """The remote memory cache is unreachable; the store handle
        (same files the remote traced into) answers instead."""
        return None


class RemoteReplica:
    """Client stub for one ``ReplicaServer``: the replica interface,
    over the wire.

    A background reader thread multiplexes replies onto per-request
    Futures; a heartbeat thread pings every ``heartbeat_interval``
    seconds and sweeps timed-out calls. ``heartbeat_misses`` consecutive
    failed pings — or the connection dropping (a ``kill -9``'d server
    closes its socket) — mark the replica ``dead``: every in-flight
    Future fails with ``ReplicaUnavailable`` (the frontend's guard
    re-routes them) and ``on_dead`` fires exactly once.
    """

    supports_hedge = True  # frontend: guard futures, hedge, retry

    def __init__(self, name: str, host: str, port: int, *,
                 trace_root: Optional[str] = None,
                 feedback_root: Optional[str] = None,
                 proc: Optional[subprocess.Popen] = None,
                 call_timeout: float = 10.0, submit_timeout: float = 120.0,
                 heartbeat_interval: float = 0.5, heartbeat_misses: int = 3,
                 connect_timeout: float = 10.0, on_dead=None):
        self.name = str(name)
        self.host, self.port = host, int(port)
        self.proc = proc
        self.on_dead = on_dead
        self.dead = False
        self.dead_reason: Optional[str] = None
        self.call_timeout = float(call_timeout)
        self.submit_timeout = float(submit_timeout)
        self.heartbeat_interval = float(heartbeat_interval)
        self.heartbeat_misses = int(heartbeat_misses)
        # backend from REPRO_STORE_BACKEND (inherited by spawned server
        # children, so both sides of the wire read one physical layout)
        self.feedback = (make_feedback_store(feedback_root)
                         if feedback_root else None)
        self.service = _RemoteService(
            self, make_trace_store(trace_root) if trace_root else None)
        self.stats = _RemoteStats(self)
        self._counters_cache: Dict[str, int] = {}
        self._overload_cache: Dict[str, int] = {}
        self._cache_at: Optional[float] = None  # monotonic age of the cache
        self._closing = False
        self._dead_fired = False
        self._wlock = threading.Lock()
        self._plock = threading.Lock()
        self._pending: Dict[int, Tuple[Future, float]] = {}
        self._ids = itertools.count(1)
        self._sock = socket.create_connection((host, self.port),
                                              timeout=connect_timeout)
        self._sock.settimeout(None)
        try:
            self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass
        self._reader = threading.Thread(
            target=self._read_loop, name=f"rpc-read-{self.name}",
            daemon=True)
        self._reader.start()
        self._beat = threading.Thread(
            target=self._heartbeat_loop, name=f"rpc-beat-{self.name}",
            daemon=True)
        self._beat.start()

    # -- wire plumbing ------------------------------------------------------
    def _request(self, op: str, params: Optional[Dict],
                 timeout: float) -> Future:
        if self.dead:
            raise ReplicaUnavailable(
                f"replica {self.name} is dead ({self.dead_reason})")
        fut: Future = Future()
        mid = next(self._ids)
        with self._plock:
            self._pending[mid] = (fut, time.monotonic() + timeout)
        frame = pack_frame({"id": mid, "op": op, **(params or {})})
        try:
            with self._wlock:
                self._sock.sendall(frame)
        except OSError as e:
            with self._plock:
                self._pending.pop(mid, None)
            self._mark_dead(f"send failed: {e}")
            raise ReplicaUnavailable(
                f"replica {self.name}: send failed: {e}") from e
        return fut

    def _call(self, op: str, params: Optional[Dict] = None,
              timeout: Optional[float] = None):
        timeout = self.call_timeout if timeout is None else float(timeout)
        fut = self._request(op, params, timeout)
        try:
            # the heartbeat sweep fails the Future at its deadline; the
            # margin here only covers a dead sweeper (closed stub)
            return fut.result(timeout + 2 * self.heartbeat_interval + 1.0)
        except FutureTimeout:
            raise ReplicaUnavailable(
                f"replica {self.name}: {op} timed out after {timeout}s")

    def _read_loop(self) -> None:
        try:
            while True:
                msg = read_frame_sock(self._sock)
                if msg is None:
                    break
                with self._plock:
                    entry = self._pending.pop(msg.get("id"), None)
                if entry is None:
                    continue  # reply raced a timeout sweep: dropped
                fut = entry[0]
                if msg.get("ok"):
                    _resolve(fut, msg.get("result"))
                elif msg.get("kind") == "not_running":
                    _fail(fut, ReplicaNotRunning(msg.get("error", "")))
                elif msg.get("kind") == "deadline":
                    _fail(fut, DeadlineExceeded(msg.get("error", "")))
                elif msg.get("kind") == "quota":
                    _fail(fut, QuotaExceeded(msg.get("error", "")))
                else:
                    _fail(fut, RPCError(msg.get("error", "")))
        except (OSError, ValueError):
            pass
        self._mark_dead("connection closed")

    def _heartbeat_loop(self) -> None:
        misses = 0
        while not self._closing and not self.dead:
            time.sleep(self.heartbeat_interval)
            if self._closing or self.dead:
                return
            self._sweep(time.monotonic())
            try:
                pong = self._call("ping",
                                  timeout=self.heartbeat_interval + 0.25)
                self.service._generation = int(
                    pong.get("generation", self.service._generation))
                misses = 0
            except Exception:
                misses += 1
                if misses >= self.heartbeat_misses:
                    self._mark_dead(f"{misses} heartbeats missed")
                    return

    def _sweep(self, now: float) -> None:
        """Fail calls whose deadline passed (bounded-call guarantee)."""
        expired: List[Future] = []
        with self._plock:
            for mid, (fut, deadline) in list(self._pending.items()):
                if now > deadline:
                    expired.append(fut)
                    del self._pending[mid]
        for fut in expired:
            _fail(fut, ReplicaUnavailable(
                f"replica {self.name}: call deadline passed"))

    def _mark_dead(self, reason: str) -> None:
        with self._plock:
            if self._dead_fired:
                return
            self._dead_fired = True
            self.dead = True
            self.dead_reason = reason
            pending = list(self._pending.values())
            self._pending = {}
            fire = not self._closing
        for fut, _ in pending:
            _fail(fut, ReplicaUnavailable(
                f"replica {self.name} died: {reason}"))
        try:
            self._sock.close()
        except OSError:
            pass
        cb = self.on_dead
        if fire:
            events.emit("replica_dead", replica=self.name, reason=reason)
        if fire and cb is not None:
            try:
                cb(self)
            except Exception:
                pass  # a broken callback must not kill the transport

    # -- replica interface ---------------------------------------------------
    def submit(self, cfg, batch: int, seq: int,
               fp: Optional[str] = None, tc=None, *, tenant: str = "",
               deadline: Optional[float] = None) -> Future:
        params = {"cfg": encode_config(cfg), "batch": int(batch),
                  "seq": int(seq), "fp": fp}
        if tc is not None:  # trace context crosses inside the frame header
            params["tc"] = tc
        if tenant:
            params["tenant"] = str(tenant)
        if deadline is not None:
            # monotonic clocks don't compare across processes: ship the
            # remaining budget, the server re-anchors it on its clock.
            params["deadline_in"] = max(0.0,
                                        float(deadline) - time.monotonic())
        return self._request("submit", params, self.submit_timeout)

    def submit_many(self, queries: Sequence) -> List[Future]:
        """Pipelined per-query frames: the server's gateway coalesces
        back-to-back arrivals into one micro-batch tick anyway."""
        futs = []
        for q in queries:
            q = q if isinstance(q, Query) else Query(*q)
            futs.append(self.submit(
                q.cfg, q.batch, q.seq, fp=q.fp, tc=q.tc,
                tenant=getattr(q, "tenant", ""),
                deadline=getattr(q, "deadline", None)))
        return futs

    def predict_one(self, cfg, batch: int, seq: int,
                    timeout: Optional[float] = None) -> Dict:
        return self.submit(cfg, batch, seq).result(timeout)

    def observe(self, cfg, batch: int, seq: int, time_s: float,
                mem_bytes: float, *,
                predicted_time_s: Optional[float] = None,
                predicted_mem_bytes: Optional[float] = None,
                generation: Optional[int] = None, job_id: str = "",
                fp: Optional[str] = None, tenant: str = "") -> None:
        params = {
            "cfg": encode_config(cfg), "batch": int(batch),
            "seq": int(seq), "time_s": float(time_s),
            "mem_bytes": float(mem_bytes),
            "predicted_time_s": predicted_time_s,
            "predicted_mem_bytes": predicted_mem_bytes,
            "generation": generation, "job_id": str(job_id), "fp": fp}
        if tenant:
            params["tenant"] = str(tenant)
        self._call("observe", params)

    def publish_generation(self, gen) -> bool:
        to_dict = getattr(gen.abacus, "to_dict", None)
        if to_dict is None:
            raise TypeError(
                f"generation {gen.number} carries a predictor without "
                "to_dict(); it cannot cross the wire")
        return bool(self._call("publish_generation",
                               {"number": int(gen.number),
                                "abacus": to_dict(),
                                "summary": gen.summary()}))

    # -- stats ---------------------------------------------------------------
    def _counters(self) -> Dict[str, int]:
        try:
            c = self._call("counters")
        except ReplicaUnavailable:
            return dict(self._counters_cache)
        self._counters_cache = dict(c)
        self._cache_at = time.monotonic()
        return c

    def overload_counters(self) -> Dict[str, int]:
        """Remote shed/expired/quota counters; last-known values once
        dead, so the exclusion reshard can still bank them."""
        try:
            c = self._call("overload")
        except ReplicaUnavailable:
            return dict(self._overload_cache)
        self._overload_cache = dict(c)
        return c

    def _full_stats(self) -> Dict:
        try:
            d = self._call("stats")
        except ReplicaUnavailable:
            # cached fallback, explicitly marked: a dead member's last
            # words must be distinguishable from live data, and
            # as_of_monotonic says how old they are.
            return {"replica": self.name, "dead": True, "stale": True,
                    "as_of_monotonic": self._cache_at,
                    **dict(self._counters_cache)}
        d["calibration"] = _normalize_calibration(d.get("calibration"))
        self._counters_cache = {k: d[k] for k in _RemoteStats._COUNTERS
                                if k in d}
        self._cache_at = time.monotonic()
        return d

    def server_info(self) -> Dict:
        try:
            info = self._call("server_info")
        except ReplicaUnavailable:
            return {"replica": self.name, "dead": True, "stale": True,
                    "as_of_monotonic": self._cache_at, "running": False,
                    "queued": 0, **dict(self._counters_cache)}
        self._counters_cache = {k: info[k] for k in _RemoteStats._COUNTERS
                                if k in info}
        self._cache_at = time.monotonic()
        return info

    def metrics_snapshot(self) -> Dict:
        """The remote gateway's registry snapshot (``metrics`` op).
        Raises ``ReplicaUnavailable`` when the replica is dead — the
        fleet merge skips it and counts it unreachable."""
        return self._call("metrics")

    # -- lifecycle -----------------------------------------------------------
    @property
    def running(self) -> bool:
        if self.dead:
            return False
        try:
            return bool(self._call("state")["running"])
        except ReplicaUnavailable:
            return False

    @property
    def draining(self) -> bool:
        if self.dead:
            return False  # a dead process has no worker left to drain
        try:
            return bool(self._call("state")["draining"])
        except ReplicaUnavailable:
            return False

    def start(self) -> "RemoteReplica":
        self._call("start")
        return self

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        if self.dead:
            return
        try:
            self._call("stop", {"timeout": timeout},
                       timeout=(timeout or 10.0) + self.call_timeout)
        except ReplicaUnavailable:
            pass  # died mid-drain: exclusion handles it

    def close(self) -> None:
        """Tear down the stub (threads exit; ``on_dead`` will not fire)."""
        self._closing = True
        try:
            self._sock.close()
        except OSError:
            pass
        with self._plock:
            pending = list(self._pending.values())
            self._pending = {}
        for fut, _ in pending:
            _fail(fut, ReplicaUnavailable(f"replica {self.name} closed"))

    def shutdown(self, timeout: float = 10.0) -> None:
        """Graceful teardown of stub AND server process."""
        if not self.dead:
            try:
                self._call("shutdown", {"timeout": timeout},
                           timeout=timeout + self.call_timeout)
            except Exception:
                pass
        self.close()
        if self.proc is not None:
            try:
                self.proc.wait(timeout)
            except Exception:
                self.proc.kill()
                try:
                    self.proc.wait(5)
                except Exception:
                    pass

    def kill(self) -> None:
        """``kill -9`` the spawned server process (chaos testing)."""
        if self.proc is not None:
            self.proc.kill()


# -- process management ------------------------------------------------------

def _src_dir() -> str:
    """The PYTHONPATH entry that makes ``repro`` importable in a child."""
    import repro
    return os.path.dirname(list(repro.__path__)[0])


def spawn_replica(name: str, predictor_path: str, *,
                  trace_root: Optional[str] = None,
                  feedback_root: Optional[str] = None,
                  tracer: Optional[str] = None, host: str = "127.0.0.1",
                  startup_timeout: float = 60.0,
                  python: Optional[str] = None,
                  event_log: Optional[str] = None,
                  max_queue: Optional[int] = None,
                  shed_watermark: Optional[int] = None,
                  **remote_kw) -> RemoteReplica:
    """Spawn ``python -m repro.serve.rpc`` and connect a stub to it.

    The child prints a single ``{"event": "ready", "port": ...}`` JSON
    line once it is listening (port 0 means kernel-assigned); stderr is
    inherited so a crashing child is diagnosable from the parent's
    output. ``tracer`` is a ``module:attr`` spec (tests/benches pass
    ``repro.serve.rpc:synthetic_trace``).
    """
    cmd = [python or sys.executable, "-m", "repro.serve.rpc",
           "--name", str(name), "--predictor", str(predictor_path),
           "--host", host, "--port", "0"]
    if trace_root:
        cmd += ["--trace-store", str(trace_root)]
    if feedback_root:
        cmd += ["--feedback-store", str(feedback_root)]
    if tracer:
        cmd += ["--tracer", tracer]
    if event_log:
        cmd += ["--event-log", str(event_log)]
    if max_queue is not None:
        cmd += ["--max-queue", str(max_queue)]
    if shed_watermark is not None:
        cmd += ["--shed-watermark", str(shed_watermark)]
    env = dict(os.environ)
    env["PYTHONPATH"] = _src_dir() + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, env=env, text=True)
    deadline = time.monotonic() + startup_timeout
    ready = None
    try:
        while time.monotonic() < deadline:
            if proc.poll() is not None:
                raise RuntimeError(
                    f"replica {name} exited with code {proc.returncode} "
                    "before becoming ready")
            rl, _, _ = select.select([proc.stdout], [], [], 0.2)
            if not rl:
                continue
            line = proc.stdout.readline()
            if not line:
                continue
            try:
                msg = json.loads(line)
            except ValueError:
                continue  # stray stdout noise
            if msg.get("event") == "ready":
                ready = msg
                break
        if ready is None:
            raise TimeoutError(
                f"replica {name} not ready within {startup_timeout}s")
        return RemoteReplica(name, host, int(ready["port"]),
                             trace_root=trace_root,
                             feedback_root=feedback_root, proc=proc,
                             **remote_kw)
    except BaseException:
        proc.kill()
        raise


def spawn_fleet(n_or_names, predictor_path: str, root: str, *,
                tracer: Optional[str] = None,
                event_log: Optional[str] = None,
                **kw) -> List[RemoteReplica]:
    """Spawn a homogeneous fleet with per-replica store slices under
    ``root`` — the layout ``ClusterFrontend(abacus, n, trace_root=...,
    feedback_root=...)`` uses, so RPC and in-process fleets over the
    same ``root`` shard identically."""
    names = ([f"r{i}" for i in range(n_or_names)]
             if isinstance(n_or_names, int)
             else [str(n) for n in n_or_names])
    replicas: List[RemoteReplica] = []
    try:
        for name in names:
            replicas.append(spawn_replica(
                name, predictor_path,
                trace_root=os.path.join(root, "traces", name),
                feedback_root=os.path.join(root, "feedback", name),
                tracer=tracer, event_log=event_log, **kw))
    except BaseException:
        shutdown_fleet(replicas)
        raise
    return replicas


def shutdown_fleet(replicas: Sequence[RemoteReplica],
                   timeout: float = 10.0) -> None:
    for r in replicas:
        try:
            r.shutdown(timeout)
        except Exception:
            pass


# -- CLI ---------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.rpc",
        description="Serve one GatewayReplica over the TCP frame protocol")
    ap.add_argument("--name", required=True)
    ap.add_argument("--predictor", required=True,
                    help="DNNAbacus.save path (without the .json suffix)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = kernel-assigned (reported on the ready line)")
    ap.add_argument("--trace-store", default=None)
    ap.add_argument("--feedback-store", default=None)
    ap.add_argument("--tracer",
                    default="repro.serve.prediction_service:trace_query",
                    help="module:attr of the tracer callable")
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--trace-workers", type=int, default=4)
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the pending queue; per-tenant weighted-"
                         "fair shares of it gate admission")
    ap.add_argument("--shed-watermark", type=int, default=None,
                    help="queue depth past which submits are answered "
                         "from the roofline floor (degraded)")
    ap.add_argument("--event-log", default=None,
                    help="JSONL file for this replica's lifecycle events "
                         "(gen swaps etc.); safe to share across a fleet "
                         "(line-append writes)")
    args = ap.parse_args(argv)

    if args.event_log:
        events.configure(path=args.event_log)
    server_kw = {}
    if args.max_queue is not None:
        server_kw["max_queue"] = args.max_queue
    if args.shed_watermark is not None:
        server_kw["shed_watermark"] = args.shed_watermark
    replica = GatewayReplica(
        args.name, DNNAbacus.load(args.predictor),
        store=(make_trace_store(args.trace_store)
               if args.trace_store else None),
        feedback=(make_feedback_store(args.feedback_store)
                  if args.feedback_store else None),
        tracer=resolve_tracer(args.tracer), max_batch=args.max_batch,
        trace_workers=args.trace_workers, **server_kw)
    replica.start()
    server = ReplicaServer(replica, host=args.host, port=args.port)

    # the ready handshake is itself a structured event; it ALSO goes to
    # stdout (same wire shape as before: {"event": "ready", "port": ...})
    # because spawn_replica blocks on that line. Only this one event may
    # use stdout — the parent stops draining the pipe afterwards.
    handshake = events.EventLog(stream=sys.stdout)

    def ready(port: int) -> None:
        events.emit("replica_started", replica=args.name, port=port)
        handshake.emit("ready", name=args.name, port=port)

    try:
        server.run_forever(ready_cb=ready)
    finally:
        replica.stop(timeout=10.0)
        events.emit("replica_stopped", replica=args.name)
    return 0


if __name__ == "__main__":
    sys.exit(main())
