"""Batched prediction service with jaxpr-trace caching (paper §4.3 online).

``DNNAbacus.predict_config`` answers one admission-control query by
building the model, tracing the train step, and extracting the NSM — all
from scratch. At datacenter query rates (scheduler loops, per-job
admission control) that trace dominates end-to-end latency, and it is
fully determined by ``(config, batch, seq)``. ``PredictionService``
amortizes it:

  * **Trace cache** — content-addressed by ``(config fingerprint, batch,
    seq)`` where the fingerprint hashes every ``ModelConfig`` field, so
    structurally identical queries (including distinct-but-equal config
    objects) never re-build or re-trace. LRU-bounded, thread-safe, with
    in-flight deduplication of concurrent identical misses.
  * **Batched queries** — ``predict_many`` featurizes N queries into one
    design matrix and runs the time/memory ensembles once, instead of N
    single-row predictions.
  * **Scheduling bridge** — ``jobs``/``schedule`` turn query estimates
    directly into GA/optimal/random placement (``repro.core.scheduler``).

The service holds a *reference* to the fitted ``DNNAbacus``; re-fitting
the predictor is picked up automatically (cached records store raw NSM
edges, featurization happens at predict time).
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.features import ProfileRecord
from repro.core.predictor import HBM_PER_DEVICE
from repro.core.scheduler import Machine, jobs_from_estimates, schedule_jobs

CacheKey = Tuple[str, int, int]


@dataclasses.dataclass(frozen=True)
class Query:
    """One admission-control question: cost of (config, batch, seq).

    ``fp`` optionally carries a precomputed config fingerprint: the
    cluster frontend fingerprints each query once to route it, and the
    owning replica reuses that key instead of re-hashing the config
    (the fingerprint is the hot path's dominant per-query cost).

    ``tc`` optionally carries a trace context
    (``{"trace": id, "span": root}``, see :mod:`repro.obs.tracing`);
    it rides the query across process boundaries so every stage stamps
    spans into one coherent per-query trace.

    ``tenant`` names the submitting job owner for per-tenant admission
    quotas and tenant-keyed calibration; ``""`` means untenanted (the
    default shared quota bucket). ``deadline`` is an absolute
    ``time.monotonic()`` instant after which serving the query is wasted
    work: the tick expires it with ``DeadlineExceeded`` instead.
    """
    cfg: Any  # ModelConfig
    batch: int
    seq: int
    fp: Optional[str] = None  # precomputed config fingerprint
    tc: Optional[Dict] = None  # trace context (repro.obs.tracing)
    tenant: str = ""  # job owner for quotas + calibration ("" = shared)
    deadline: Optional[float] = None  # absolute time.monotonic() deadline

    def key(self) -> Optional[CacheKey]:
        """Cache key when the fingerprint was precomputed, else None."""
        if self.fp is None:
            return None
        return (self.fp, int(self.batch), int(self.seq))


def _canonical(value):
    """Recursively reduce ``value`` to JSON-safe, process-stable primitives.

    ``json.dumps(..., default=str)`` is NOT stable across processes: any
    object whose ``str`` embeds ``id()`` (the ``<Foo object at 0x..>``
    default repr) fingerprints differently per process, and sets iterate
    in hash-seed order. Tuples and lists are also kept distinct here
    (JSON flattens both to arrays), so ``(1, 2)`` and ``[1, 2]`` config
    fields cannot collide into one cache entry.
    """
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    if dataclasses.is_dataclass(value) and not isinstance(value, type):
        return {f.name: _canonical(getattr(value, f.name))
                for f in dataclasses.fields(value)}
    if isinstance(value, tuple):
        return {"__tuple__": [_canonical(v) for v in value]}
    if isinstance(value, list):
        return [_canonical(v) for v in value]
    if isinstance(value, (set, frozenset)):
        items = [json.dumps(_canonical(v), sort_keys=True) for v in value]
        return {"__set__": sorted(items)}
    if isinstance(value, dict):
        items = [(json.dumps(_canonical(k), sort_keys=True), _canonical(v))
                 for k, v in value.items()]
        return {"__dict__": sorted(items, key=lambda kv: kv[0])}
    if isinstance(value, bytes):
        return {"__bytes__": value.hex()}
    if hasattr(value, "dtype") and hasattr(value, "ndim"):  # numpy
        if value.ndim == 0:  # scalar (or 0-d array): plain python value
            return _canonical(value.item())
        return {"__ndarray__": _canonical(value.tolist()),
                "dtype": str(value.dtype)}
    if isinstance(value, functools.partial):
        return {"__partial__": [_canonical(value.func),
                                _canonical(value.args),
                                _canonical(dict(value.keywords))]}
    if isinstance(value, type) or callable(value):
        qn = getattr(value, "__qualname__", None)
        if qn is not None:  # named function/class: a stable identity
            return {"__name__": f"{getattr(value, '__module__', '')}.{qn}"}
        # callable *instances* (objects defining __call__) fall through to
        # the attrs-based last resort — their repr embeds id()
    # last resort: type identity + public attributes (never id()-bearing repr)
    cls = type(value)
    tag = f"{cls.__module__}.{cls.__qualname__}"
    try:
        attrs = {k: _canonical(v) for k, v in sorted(vars(value).items())
                 if not k.startswith("_")}
    except TypeError:
        s = str(value)
        if " at 0x" in s:  # default repr embeds id(): type identity only
            return {"__obj__": tag}
        return {"__obj__": tag, "str": s}
    return {"__obj__": tag, "attrs": attrs}


def config_fingerprint(cfg) -> str:
    """Content hash over every config field (stable across processes).

    The payload is canonicalized recursively (``_canonical``) before
    hashing, so nested tuples/sets/objects hash identically in every
    process — the persistent ``TraceStore`` depends on this key.
    """
    if dataclasses.is_dataclass(cfg):
        payload = _canonical(cfg)
    else:  # duck-typed config (tests): hash its public attributes
        payload = {k: _canonical(v) for k, v in sorted(vars(cfg).items())}
    blob = json.dumps(payload, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def trace_query(cfg, batch: int, seq: int) -> ProfileRecord:
    """Build + trace one train step at abstract shapes; features only.

    This is the expensive path the cache exists to amortize: model
    construction, jaxpr tracing of the full train step, and NSM
    extraction. No arrays are allocated and nothing is compiled. Uses
    the profiler's ``lm_trace``/``lm_record`` so online features match
    the offline profiling rig exactly.
    """
    import jax

    from repro.core import nsm as nsm_lib
    from repro.core.profiler import lm_record, lm_trace

    model, step, state_sds, b = lm_trace(cfg, batch, seq)
    closed = jax.make_jaxpr(step)(state_sds, b)
    edges = nsm_lib.nsm_edges(closed)
    return lm_record(
        cfg, model, batch, seq,
        flops=6.0 * model.param_count(active_only=True) * batch * seq,
        nsm_edges=edges)


class ServiceStats:
    """Cache counters, refactored onto a ``MetricsRegistry``.

    Byte-compatible with the dataclass it replaces: attribute access
    and ``+=`` mutate registry counters (``service_hits_total``, ...),
    ``as_dict()`` keeps the same keys including the derived ``queries``,
    and keyword construction (``ServiceStats(hits=3)``) still works.
    Counters are unlocked — callers mutate them under
    ``PredictionService._lock`` exactly as before.

    - hits: served from the in-memory cache
    - misses: not in memory (filled by store load or trace)
    - store_hits: misses answered by the persistent TraceStore
    - traces: misses that actually ran the tracer
    - store_errors: failed write-throughs (served memory-only)
    - est_hits: queries served from the prediction cache
    - adopts: generations adopted (prediction cache cleared)
    """

    COUNTERS = ("hits", "misses", "evictions", "store_hits", "traces",
                "store_errors", "est_hits", "adopts")

    def __init__(self, registry=None, **initial):
        from repro.obs.metrics import MetricsRegistry
        object.__setattr__(self, "_metrics", {})
        registry = registry if registry is not None else MetricsRegistry()
        object.__setattr__(self, "registry", registry)
        metrics = self.__dict__["_metrics"]
        for name in self.COUNTERS:
            metrics[name] = registry.counter(f"service_{name}_total")
        for k, v in initial.items():
            setattr(self, k, v)

    def __getattr__(self, name):
        metrics = self.__dict__.get("_metrics")
        if metrics is not None and name in metrics:
            return metrics[name].value
        raise AttributeError(name)

    def __setattr__(self, name, value):
        metrics = self.__dict__.get("_metrics")
        if metrics is not None and name in metrics:
            metrics[name].set(value)
        else:
            object.__setattr__(self, name, value)

    @property
    def queries(self) -> int:
        return self.hits + self.misses

    def as_dict(self) -> Dict[str, int]:
        metrics = self.__dict__["_metrics"]
        d = {name: metrics[name].value for name in self.COUNTERS}
        d["queries"] = d["hits"] + d["misses"]
        return d

    def reset(self) -> None:
        for name in self.COUNTERS:
            setattr(self, name, 0)


class PredictionService:
    """Online query engine over a fitted ``DNNAbacus``."""

    def __init__(self, abacus, max_cache_entries: int = 1024,
                 hbm_budget: float = HBM_PER_DEVICE,
                 tracer: Callable[..., ProfileRecord] = trace_query,
                 store=None, cache_predictions: bool = True, metrics=None):
        from repro.obs.metrics import MetricsRegistry
        self.abacus = abacus
        self.hbm_budget = float(hbm_budget)
        self.max_cache_entries = max_cache_entries
        self.cache_predictions = bool(cache_predictions)
        self._tracer = tracer  # injectable: tests count trace calls
        self.store = store  # optional TraceStore: cross-process persistence
        self._cache: "OrderedDict[CacheKey, ProfileRecord]" = OrderedDict()
        self._inflight: Dict[CacheKey, threading.Event] = {}
        self._lock = threading.Lock()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.stats = ServiceStats(self.metrics)
        # computed gauges, snapshot-time only: never touched on the hot path
        self.metrics.register_callback(
            lambda: {"service_cache_entries": len(self._cache),
                     "service_est_entries": len(self._est_cache),
                     "service_generation": self.generation})
        # model generation (bumped by adopt()) + per-generation prediction
        # cache: (key -> (time, mem)) valid only for the generation that
        # computed it — invalidated wholesale on every swap, while the
        # trace cache and persistent store survive (traces are
        # generation-independent raw features).
        self.generation = 0
        self._est_cache: "OrderedDict[CacheKey, Tuple[float, float]]" = \
            OrderedDict()

    # -- trace cache --------------------------------------------------------
    def cache_key(self, cfg, batch: int, seq: int) -> CacheKey:
        return (config_fingerprint(cfg), int(batch), int(seq))

    def record_for(self, cfg, batch: int, seq: int) -> ProfileRecord:
        """Cached (config, batch, seq) -> ProfileRecord feature template.

        Concurrent identical queries are deduplicated: one thread runs
        the trace, the rest wait on its in-flight event and read the
        cache — a burst of N equal queries costs one trace, not N.

        With a backing ``TraceStore``, a memory miss first tries the
        store (a prior process may have traced this key) and only then
        runs the tracer; fresh traces are written through to the store.
        """
        return self._record_for_key(self.cache_key(cfg, batch, seq),
                                    cfg, batch, seq)

    def _record_for_key(self, key: CacheKey, cfg, batch: int,
                        seq: int) -> ProfileRecord:
        """``record_for`` with a precomputed key (the fingerprint is the
        hot path's dominant per-query cost; batched callers compute it
        once and reuse it for record, prediction cache, and store)."""
        while True:
            with self._lock:
                rec = self._cache.get(key)
                if rec is not None:
                    self._cache.move_to_end(key)
                    self.stats.hits += 1
                    return rec
                ev = self._inflight.get(key)
                if ev is None:
                    ev = threading.Event()
                    self._inflight[key] = ev
                    self.stats.misses += 1
                    break
            ev.wait()  # another thread is tracing this key; then re-check
        try:
            rec = self.store.get(key) if self.store is not None else None
            if rec is not None:  # warm start: a prior process traced this
                with self._lock:
                    self.stats.store_hits += 1
            else:
                rec = self._tracer(cfg, batch, seq)
                with self._lock:
                    self.stats.traces += 1
                if self.store is not None:
                    try:
                        self.store.put(key, rec)
                    except Exception:  # full/read-only disk: the store is
                        with self._lock:  # an accelerator, never a gate —
                            self.stats.store_errors += 1  # stay memory-only

            with self._lock:
                self._cache[key] = rec
                self._cache.move_to_end(key)
                while len(self._cache) > self.max_cache_entries:
                    self._cache.popitem(last=False)
                    self.stats.evictions += 1
        finally:
            with self._lock:
                self._inflight.pop(key, None)
            ev.set()
        return rec

    def cached_record(self, key: CacheKey) -> Optional[ProfileRecord]:
        """Already-traced record for ``key`` from memory only (no trace).

        The refit path uses this to join feedback observations with
        their feature templates without paying a trace for keys the
        service has never seen.
        """
        with self._lock:
            return self._cache.get(key)

    def cache_info(self) -> Dict[str, int]:
        """Counters, with in-memory entries distinct from store entries."""
        store_entries = len(self.store) if self.store is not None else 0
        with self._lock:
            return {"entries": len(self._cache),
                    "est_entries": len(self._est_cache),
                    "store_entries": store_entries,
                    "generation": self.generation,
                    **self.stats.as_dict()}

    def clear_cache(self, reset_stats: bool = False) -> None:
        """Drop cached records AND wake/forget in-flight traces.

        Waiters blocked on an in-flight event re-check the cache, find
        neither entry nor event, and become tracers themselves — a clear
        mid-trace costs at most one duplicate trace, never a deadlock.
        The backing store (if any) is NOT cleared: it is the durable
        layer shared with other processes (``store.clear()`` is explicit).
        """
        with self._lock:
            self._cache.clear()
            self._est_cache.clear()
            inflight, self._inflight = self._inflight, {}
            if reset_stats:
                self.stats.reset()
        for ev in inflight.values():
            ev.set()

    # -- model generations --------------------------------------------------
    def adopt(self, abacus, generation: Optional[int] = None) -> bool:
        """Hot-swap the predictor to a new model generation.

        Atomically replaces the ensembles and invalidates the
        per-generation prediction cache; the trace cache and persistent
        store are untouched (raw features outlive every generation).
        ``generation`` defaults to the next number; a stale publish
        (``generation`` <= the current one) is refused and returns
        False, so out-of-order deliveries cannot roll the predictor
        back — generations are monotone.
        """
        with self._lock:
            if generation is None:
                generation = self.generation + 1
            elif int(generation) <= self.generation:
                return False
            self.abacus = abacus
            self.generation = int(generation)
            self._est_cache.clear()
            self.stats.adopts += 1
        return True

    def publish_generation(self, gen) -> bool:
        """Sink API for ``OnlineRefitter``: adopt a ``ModelGeneration``."""
        return self.adopt(gen.abacus, gen.number)

    def snapshot(self):
        """Consistent (abacus, generation) pair for one batch of work.

        Callers that predict a whole micro-batch (``AbacusServer``) use
        the snapshot so a concurrent ``adopt`` cannot mix generations
        within the batch.
        """
        with self._lock:
            return self.abacus, self.generation

    # -- queries ------------------------------------------------------------
    def _estimate(self, rec: ProfileRecord, t: float, m: float,
                  generation: Optional[int] = None) -> Dict:
        return {"model": rec.model_name, "time_s": float(t),
                "memory_bytes": float(m), "hbm_budget": self.hbm_budget,
                "admitted": float(m) <= self.hbm_budget,
                "generation": (self.generation if generation is None
                               else int(generation))}

    def predict_one(self, cfg, batch: int, seq: int) -> Dict:
        """Admission-control estimate for a (ModelConfig, batch, seq) job."""
        return self.predict_many([Query(cfg, batch, seq)])[0]

    def predict_many(self, queries: Sequence) -> List[Dict]:
        """Batched queries: one design matrix, one ensemble pass per target.

        ``queries`` holds ``Query`` objects or ``(cfg, batch, seq)``
        tuples. Predictions are memoized per key in a per-generation
        cache (cleared by ``adopt``): a repeat query under the same
        generation skips the ensemble pass entirely.
        """
        qs = [q if isinstance(q, Query) else Query(*q) for q in queries]
        if not qs:
            return []
        keys = [q.key() or self.cache_key(q.cfg, q.batch, q.seq) for q in qs]
        recs = [self._record_for_key(k, q.cfg, q.batch, q.seq)
                for k, q in zip(keys, qs)]
        abacus, gen = self.snapshot()
        preds, _ = self.predict_keys(keys, recs, abacus=abacus,
                                     generation=gen)
        return [self._estimate(r, *preds[k], generation=gen)
                for r, k in zip(recs, keys)]

    def predict_keys(self, keys: Sequence[CacheKey],
                     records: Sequence[ProfileRecord], abacus=None,
                     generation: Optional[int] = None):
        """Keyed batched prediction with per-generation memoization.

        Returns ``({key: (time, mem)}, ran_ensemble)``. Keys already in
        the prediction cache (same generation) skip the ensemble; the
        rest run in ONE batched pass and are memoized — unless the
        snapshot generation no longer matches (a concurrent ``adopt``),
        in which case results are returned but never poison the newer
        generation's cache. Duplicate keys cost one prediction.
        """
        if abacus is None or generation is None:
            abacus, generation = self.snapshot()
        use_cache = self.cache_predictions
        cached: Dict[CacheKey, Tuple[float, float]] = {}
        with self._lock:
            if use_cache and generation == self.generation:
                for k in keys:
                    hit = self._est_cache.get(k)
                    if hit is not None:
                        self._est_cache.move_to_end(k)  # LRU, not FIFO
                        cached[k] = hit
            self.stats.est_hits += sum(1 for k in keys if k in cached)
        cold = [k for k in dict.fromkeys(keys) if k not in cached]
        rec_of = dict(zip(keys, records))
        preds: Dict[CacheKey, Tuple[float, float]] = dict(cached)
        if cold:
            t_pred, m_pred = abacus.predict([rec_of[k] for k in cold])
            for k, t, m in zip(cold, t_pred, m_pred):
                preds[k] = (float(t), float(m))
            with self._lock:
                if use_cache and generation == self.generation:
                    for k in cold:
                        self._est_cache[k] = preds[k]
                        self._est_cache.move_to_end(k)
                    while len(self._est_cache) > self.max_cache_entries:
                        self._est_cache.popitem(last=False)
        return preds, bool(cold)

    def predict_records(self, records: Sequence[ProfileRecord],
                        abacus=None):
        """Batched (time, memory) prediction for already-traced records.

        ``abacus`` pins the ensembles for the whole batch (pass a
        ``snapshot()`` result to keep a micro-batch on one generation
        even if ``adopt`` lands mid-flight).
        """
        return (abacus or self.abacus).predict(list(records))

    # -- scheduling bridge (paper §4.3) -------------------------------------
    def jobs(self, queries: Sequence, time_scale: float = 1.0,
             mem_pad: float = 0.0):
        """Scheduler ``Job``s from batched query estimates."""
        ests = self.predict_many(queries)
        return jobs_from_estimates(
            [e["model"] for e in ests], [e["time_s"] for e in ests],
            [e["memory_bytes"] for e in ests],
            time_scale=time_scale, mem_pad=mem_pad)

    def schedule(self, queries: Sequence, machines: Sequence[Machine],
                 plan: str = "ga", time_scale: float = 1.0,
                 mem_pad: float = 0.0, **kw):
        """Place predicted jobs on machines via the chosen plan."""
        return schedule_jobs(self.jobs(queries, time_scale, mem_pad),
                             machines, plan=plan, **kw)
