"""AbacusServer: async micro-batched admission gateway (paper §4.3 at scale).

``PredictionService`` answers queries synchronously, one caller at a
time. In the datacenter setting the paper targets, admission queries
arrive concurrently from many tenants; serving them serially wastes the
two batchable stages — the ensemble pass (one design matrix amortizes N
queries) and cold trace misses (independent, thread-parallel).

``AbacusServer`` mirrors the continuous-batching shape of
``repro.serve.engine.DecodeEngine``: clients ``submit()`` queries into a
queue and get a ``Future``; a single worker thread wakes, coalesces
*everything* pending into one micro-batch per tick, resolves cold
misses concurrently on a trace pool, runs ONE ensemble pass for the
whole batch, and resolves each future with its admission verdict.

    with AbacusServer(service) as srv:
        futs = [srv.submit(cfg, b, 2048) for b in (8, 16, 32)]
        ests = [f.result() for f in futs]          # admission verdicts

A burst of N identical queries costs one trace (the service's in-flight
dedup) and one ensemble pass (the micro-batch); distinct cold queries
trace concurrently instead of serially inside ``predict_many``.
"""

from __future__ import annotations

import math
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeout
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.analysis.roofline import floor_estimate
from repro.obs import events
from repro.obs.metrics import CounterDict, MetricsRegistry, merge_snapshots
from repro.obs.tracing import SpanSink, make_span
from repro.serve.feedback_store import CalibrationWindow, TenantCalibration
from repro.serve.prediction_service import PredictionService, Query


class DeadlineExceeded(RuntimeError):
    """The query's deadline passed before it could be served.

    ``where`` records which stage expired it: ``"server"`` when the
    serving tick found it already dead, ``"frontend"`` when a cluster
    frontend expired a parked query before replaying it onto a new ring
    (expired work is never replayed).
    """

    def __init__(self, msg: str, where: str = "server"):
        super().__init__(msg)
        self.where = where


class QuotaExceeded(RuntimeError):
    """The tenant's weighted-fair share of the queue is exhausted."""

    def __init__(self, msg: str, tenant: str = ""):
        super().__init__(msg)
        self.tenant = tenant


def _results_by_deadline(futs: Sequence[Future],
                         timeout: Optional[float]) -> List:
    """Collect ``fut.result()``s under ONE shared deadline.

    ``[f.result(timeout) for f in futs]`` compounds the timeout per
    future (N futures can wait up to N x timeout total); this converts
    ``timeout`` into a single absolute deadline and gives each future
    only what remains of it, raising the builtin ``TimeoutError`` naming
    how many futures were still pending.
    """
    if timeout is None:
        return [f.result() for f in futs]
    deadline = time.monotonic() + float(timeout)
    out = []
    for i, f in enumerate(futs):
        try:
            out.append(f.result(max(0.0, deadline - time.monotonic())))
        except FutureTimeout:
            pending = sum(1 for g in futs[i:] if not g.done())
            raise TimeoutError(
                f"predict_many deadline of {timeout}s exhausted with "
                f"{pending} of {len(futs)} futures still pending") from None
    return out


class ServerStats:
    """Gateway counters, refactored onto a ``MetricsRegistry``.

    Attribute access is byte-compatible with the dataclass this used to
    be: ``stats.ticks += 1`` mutates the registry counter named
    ``server_ticks_total``, ``as_dict()`` returns the same keys in the
    same order, and zero-arg / keyword construction still work (tests
    and stubs build bare ``ServerStats()`` instances). Counters stay
    unlocked — callers synchronize under ``AbacusServer._cond`` exactly
    as before; the registry only gives them names and an exposition
    path.
    """

    COUNTERS = ("submitted", "completed", "failed", "ticks",
                "ensemble_passes", "max_batch", "cold_traces",
                "gen_swaps", "observations")
    # high-water marks merge by max, not sum
    _GAUGES = frozenset({"max_batch"})

    def __init__(self, registry: Optional[MetricsRegistry] = None, **initial):
        object.__setattr__(self, "_metrics", {})
        registry = registry if registry is not None else MetricsRegistry()
        object.__setattr__(self, "registry", registry)
        metrics = self.__dict__["_metrics"]
        for name in self.COUNTERS:
            if name in self._GAUGES:
                metrics[name] = registry.gauge(f"server_{name}")
            else:
                metrics[name] = registry.counter(f"server_{name}_total")
        for k, v in initial.items():
            setattr(self, k, v)

    def __getattr__(self, name):
        metrics = self.__dict__.get("_metrics")
        if metrics is not None and name in metrics:
            return metrics[name].value
        raise AttributeError(name)

    def __setattr__(self, name, value):
        metrics = self.__dict__.get("_metrics")
        if metrics is not None and name in metrics:
            metrics[name].set(value)
        else:
            object.__setattr__(self, name, value)

    def as_dict(self) -> Dict[str, int]:
        metrics = self.__dict__["_metrics"]
        return {name: metrics[name].value for name in self.COUNTERS}

    @property
    def mean_batch(self) -> float:
        """Mean micro-batch size actually coalesced per tick.

        Failed queries still occupied a batch slot — dividing only
        ``completed`` by ``ticks`` would drag the reported coalescing
        size toward zero on failure-heavy workloads.
        """
        return (self.completed + self.failed) / self.ticks \
            if self.ticks else 0.0

    def __call__(self) -> Dict:
        """``server.stats()``: the full stats dict, counters included.

        The server stamps ``_full_stats`` onto its own ``ServerStats``
        instance so the counters stay attribute-addressable
        (``server.stats.ticks``) while ``server.stats()`` reports the
        whole picture — counters plus generation, rolling calibration,
        and refit state.
        """
        fn = getattr(self, "_full_stats", None)
        return fn() if fn is not None else self.as_dict()


class AbacusServer:
    """Event-loop front door over a ``PredictionService``.

    One worker thread owns the micro-batch loop; ``trace_workers``
    bounds the thread pool used for concurrent cold-miss traces.
    ``max_batch`` caps how many queued queries one tick coalesces
    (backpressure: the rest stay queued for the next tick).
    """

    def __init__(self, service: PredictionService, max_batch: int = 256,
                 trace_workers: int = 4, feedback=None, refitter=None,
                 calibration_window: int = 256,
                 metrics: Optional[MetricsRegistry] = None,
                 max_queue: Optional[int] = None,
                 tenant_weights: Optional[Dict[str, float]] = None,
                 shed_watermark: Optional[int] = None):
        self.service = service
        self.max_batch = int(max_batch)
        self.trace_workers = int(trace_workers)
        # overload controls (None = unbounded, the legacy behaviour):
        # `max_queue` bounds the queue with weighted-fair per-tenant
        # shares; `shed_watermark` is the saturation depth past which
        # new submits are answered from the zero-trace roofline floor.
        self.max_queue = None if max_queue is None else int(max_queue)
        self.shed_watermark = (None if shed_watermark is None
                               else int(shed_watermark))
        self.tenant_weights: Dict[str, float] = dict(tenant_weights or {})
        # merged into every estimate this server resolves: a cluster
        # replica stamps {"replica": name} so fleet-level tests and
        # clients can attribute (tick, generation) pairs per replica.
        self.est_tags: Dict[str, object] = {}
        # one registry per gateway: shared with the service (so server_*
        # and service_* counters land in one snapshot) unless the caller
        # supplies its own. `metrics.enabled=False` keeps counters live
        # (tick numbering is load-bearing) but skips histogram observes
        # and timing stamps — the baseline the <3% overhead gate uses.
        self.metrics = (metrics if metrics is not None
                        else getattr(service, "metrics", None)
                        or MetricsRegistry())
        self.stats = ServerStats(self.metrics)
        self.stats._full_stats = self._stats_dict  # server.stats() works too
        self.span_sink = SpanSink()
        self._h_latency = self.metrics.histogram(
            "server_query_latency_seconds",
            help="submit-to-resolution latency per query")
        self._h_queue_wait = self.metrics.histogram(
            "server_queue_wait_seconds",
            help="time between enqueue and the serving tick starting")
        self._h_tick = self.metrics.histogram(
            "server_tick_seconds", help="wall time per micro-batch tick")
        self._h_cold = self.metrics.histogram(
            "server_cold_trace_phase_seconds",
            help="record-resolution phase duration when cold traces ran")
        self._h_ensemble = self.metrics.histogram(
            "server_ensemble_phase_seconds",
            help="ensemble-pass phase duration when the pass ran")
        self.metrics.register_callback(
            lambda: {"server_queued": len(self._queue),
                     "server_running": int(self._running)})
        # feedback loop (optional): measured completions land in the
        # FeedbackStore, calibration tracks predicted-vs-observed, and
        # the refitter publishes new generations back through us.
        self.feedback = feedback      # FeedbackStore or None
        self.calibration = CalibrationWindow(window=calibration_window)
        self.tenant_calibration = TenantCalibration(window=calibration_window)
        self.refitter = refitter      # OnlineRefitter or None
        if refitter is not None:
            refitter.add_sink(self)
        # overload accounting: NEW metric series (server_shed_total, ...)
        # next to the legacy ServerStats counters, never replacing them.
        # Mutated under self._cond like every other counter here.
        self.overload = CounterDict(self.metrics, "server_",
                                    ("shed", "expired", "quota_rejected"))
        self._tenant_queued: Dict[str, int] = {}
        self._queue: Deque[Tuple[Query, Future]] = deque()
        self._cond = threading.Condition()
        self._pending_gen = None      # generation awaiting a tick boundary
        self._worker: Optional[threading.Thread] = None
        self._pool: Optional[ThreadPoolExecutor] = None
        self._running = False

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "AbacusServer":
        if self._running:
            return self
        if self._worker is not None and self._worker.is_alive():
            raise RuntimeError("previous worker is still draining; "
                               "call stop() again once it finishes")
        self._running = True
        if self._pool is None:
            self._pool = ThreadPoolExecutor(max_workers=self.trace_workers,
                                            thread_name_prefix="abacus-trace")
        self._worker = threading.Thread(target=self._loop,
                                        name="abacus-server", daemon=True)
        self._worker.start()
        return self

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        """Drain-then-stop: queued queries are served before shutdown.

        If the worker does not finish draining within ``timeout`` it is
        left running (it still exits after its current batch); the trace
        pool and queue are only torn down once the worker is gone —
        tearing them down under a live worker would strand its batch.
        """
        with self._cond:
            if not self._running and self._worker is None:
                return
            self._running = False
            self._cond.notify_all()
        worker = self._worker
        if worker is not None:
            worker.join(timeout)
            if worker.is_alive():  # still draining: do not yank the pool
                return
            self._worker = None
        # a publish that raced the worker's exit may still sit queued
        with self._cond:
            self._apply_pending_locked()
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        # anything still queued after the drain tick fails loudly
        with self._cond:
            leftovers, self._queue = list(self._queue), deque()
            self._tenant_queued.clear()
        for _, fut in leftovers:
            if not fut.done():
                try:
                    fut.set_exception(RuntimeError("AbacusServer stopped"))
                except Exception:
                    pass  # client cancelled it first

    def __enter__(self) -> "AbacusServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def running(self) -> bool:
        return self._running

    @property
    def draining(self) -> bool:
        """True while a stopped worker is still finishing its drain.

        ``stop(timeout)`` can return before the worker exits (a slow
        trace mid-tick); callers that need a *quiesced* server — the
        reshard protocol migrates store slices only once writes ceased
        — must check this, not just ``running``.
        """
        worker = self._worker
        return (worker is not None and worker.is_alive()
                and not self._running)

    # -- client API ---------------------------------------------------------
    def _quota_exceeded_locked(self, tenant: str) -> bool:
        """Weighted-fair share check; callers hold ``self._cond``.

        A tenant's share of ``max_queue`` is its weight over the total
        weight of tenants with queued work (plus itself): an idle fleet
        lets one tenant use the whole queue, contention splits it by
        weight, and every tenant keeps a floor of one slot.
        """
        if self.max_queue is None:
            return False
        queued = self._tenant_queued.get(tenant, 0)
        active = set(self._tenant_queued)
        active.add(tenant)
        w = float(self.tenant_weights.get(tenant, 1.0))
        w_active = sum(float(self.tenant_weights.get(t, 1.0))
                       for t in active)
        cap = max(1, math.ceil(self.max_queue * w / w_active))
        return queued >= cap

    def _shed_estimate(self, q: Query) -> Dict:
        """Roofline-floor answer for a query shed past the watermark."""
        est = floor_estimate(q.cfg, q.batch, q.seq)
        hbm = getattr(self.service, "hbm_budget", None)
        est["hbm_budget"] = hbm
        est["admitted"] = (est["memory_bytes"] <= hbm if hbm is not None
                           else True)
        est["generation"] = self.service.generation
        est.update(self.est_tags)
        return est

    def submit(self, cfg, batch: int, seq: int,
               fp: Optional[str] = None, tc=None, *, tenant: str = "",
               deadline: Optional[float] = None) -> Future:
        """Enqueue one admission query; resolves to the estimate dict.

        ``fp`` optionally carries the config fingerprint a router
        already computed, sparing this server's worker the re-hash.
        ``tc`` optionally carries a trace context (see
        :mod:`repro.obs.tracing`): the serving tick then records spans
        for this query and ships them back inside the estimate under
        ``"_trace"``.

        Overload ladder (each stage only when configured): a tenant past
        its weighted-fair queue share is rejected synchronously with
        :class:`QuotaExceeded`; a queue past ``shed_watermark`` answers
        immediately from the roofline floor (``degraded: True``); a
        queued query whose ``deadline`` (absolute ``time.monotonic()``)
        passes before its tick fails with :class:`DeadlineExceeded`.
        """
        fut: Future = Future()
        if self.metrics.enabled:
            fut._obs_t0 = time.perf_counter()
        q = Query(cfg, int(batch), int(seq), fp=fp, tc=tc,
                  tenant=tenant, deadline=deadline)
        shed = False
        with self._cond:
            if not self._running:
                raise RuntimeError("AbacusServer is not running "
                                   "(use `with AbacusServer(...)` or start())")
            if self._quota_exceeded_locked(q.tenant):
                self.overload["quota_rejected"] += 1
                raise QuotaExceeded(
                    f"tenant {q.tenant!r} queue quota exhausted",
                    tenant=q.tenant)
            if (self.shed_watermark is not None
                    and len(self._queue) >= self.shed_watermark):
                self.stats.submitted += 1
                self.stats.completed += 1
                self.overload["shed"] += 1
                shed = True
            else:
                self._queue.append((q, fut))
                self._tenant_queued[q.tenant] = \
                    self._tenant_queued.get(q.tenant, 0) + 1
                self.stats.submitted += 1
                self._cond.notify()
        if shed:
            fut.set_result(self._shed_estimate(q))
        return fut

    def submit_many(self, queries: Sequence) -> List[Future]:
        qs = [q if isinstance(q, Query) else Query(*q) for q in queries]
        futs: List[Future] = [Future() for _ in qs]
        if self.metrics.enabled:
            t0 = time.perf_counter()  # one clock read for the whole wave
            for fut in futs:
                fut._obs_t0 = t0
        shed_idx: List[int] = []
        quota_idx: List[int] = []
        with self._cond:
            if not self._running:
                raise RuntimeError("AbacusServer is not running "
                                   "(use `with AbacusServer(...)` or start())")
            for i, (q, fut) in enumerate(zip(qs, futs)):
                if self._quota_exceeded_locked(q.tenant):
                    # batch submits report quota per-future instead of
                    # failing the whole wave synchronously
                    self.overload["quota_rejected"] += 1
                    quota_idx.append(i)
                elif (self.shed_watermark is not None
                        and len(self._queue) >= self.shed_watermark):
                    self.stats.submitted += 1
                    self.stats.completed += 1
                    self.overload["shed"] += 1
                    shed_idx.append(i)
                else:
                    self._queue.append((q, fut))
                    self._tenant_queued[q.tenant] = \
                        self._tenant_queued.get(q.tenant, 0) + 1
                    self.stats.submitted += 1
            self._cond.notify()
        for i in quota_idx:
            futs[i].set_exception(QuotaExceeded(
                f"tenant {qs[i].tenant!r} queue quota exhausted",
                tenant=qs[i].tenant))
        for i in shed_idx:
            futs[i].set_result(self._shed_estimate(qs[i]))
        return futs

    def predict_one(self, cfg, batch: int, seq: int,
                    timeout: Optional[float] = None) -> Dict:
        """Synchronous convenience: submit and wait for the verdict."""
        return self.submit(cfg, batch, seq).result(timeout)

    def predict_many(self, queries: Sequence,
                     timeout: Optional[float] = None) -> List[Dict]:
        return _results_by_deadline(self.submit_many(queries), timeout)

    # -- model generations --------------------------------------------------
    def publish_generation(self, gen) -> bool:
        """Queue a ``ModelGeneration`` for adoption at a tick boundary.

        The swap is applied by the worker thread *between* micro-batch
        ticks, so an in-flight micro-batch always finishes on the
        generation it started with — a hot swap can never mix
        generations within one tick. With no live worker (bare server)
        nothing is in flight and the service adopts immediately.
        """
        with self._cond:
            # queue only while the worker is RUNNING: during shutdown the
            # worker may already be past its final pending check, so a
            # queued generation could be stranded — adopt directly
            # instead (safe: an in-flight tick predicts from its own
            # snapshot, so a mid-drain adopt still can't mix a tick).
            if (self._running and self._worker is not None
                    and self._worker.is_alive()):
                if (self._pending_gen is None
                        or gen.number > self._pending_gen.number):
                    self._pending_gen = gen
                self._cond.notify_all()
                return True
        adopted = self.service.adopt(gen.abacus, gen.number)
        if adopted:
            # the direct-adopt path bypasses _apply_pending_locked, but a
            # successful swap is a swap — count it, or fleet-level swap
            # accounting disagrees with the generations actually serving.
            with self._cond:
                self.stats.gen_swaps += 1
            events.emit("gen_swap", generation=gen.number, **self.est_tags)
        return adopted

    def _apply_pending_locked(self) -> None:
        """Adopt a queued generation; callers hold ``self._cond``."""
        gen, self._pending_gen = self._pending_gen, None
        if gen is not None and self.service.adopt(gen.abacus, gen.number):
            self.stats.gen_swaps += 1
            events.emit("gen_swap", generation=gen.number, **self.est_tags)

    # -- feedback loop ------------------------------------------------------
    def observe(self, cfg, batch: int, seq: int, time_s: float,
                mem_bytes: float, *, predicted_time_s: Optional[float] = None,
                predicted_mem_bytes: Optional[float] = None,
                generation: Optional[int] = None, job_id: str = "",
                fp: Optional[str] = None, tenant: str = "") -> None:
        """Report one finished job's measured cost.

        Feeds the rolling calibration window (when the prediction that
        admitted the job is supplied), persists the observation in the
        ``FeedbackStore`` (when attached), and wakes the refitter.
        Non-positive measurements are dropped at this shared entry
        point: they carry no calibration signal and would poison the
        window (inf MRE) and the refit targets (log of ~0).
        """
        if float(time_s) <= 0.0 or float(mem_bytes) <= 0.0:
            return
        with self._cond:  # concurrent observers race the unlocked += 1
            self.stats.observations += 1
        if predicted_time_s is not None and predicted_mem_bytes is not None:
            self.calibration.observe(predicted_time_s, time_s,
                                     predicted_mem_bytes, mem_bytes,
                                     generation)
            if tenant:
                self.tenant_calibration.observe(
                    tenant, predicted_time_s, time_s,
                    predicted_mem_bytes, mem_bytes, generation=generation)
        if self.feedback is not None:
            key = ((fp, int(batch), int(seq)) if fp is not None
                   else self.service.cache_key(cfg, batch, seq))
            self.feedback.add(key, time_s, mem_bytes,
                              generation=generation, job_id=job_id)
        if self.refitter is not None:
            self.refitter.notify()

    # -- worker loop --------------------------------------------------------
    def _tenant_dec_locked(self, tenant: str) -> None:
        n = self._tenant_queued.get(tenant, 0) - 1
        if n > 0:
            self._tenant_queued[tenant] = n
        else:
            self._tenant_queued.pop(tenant, None)

    def _take_batch_locked(self) -> Tuple[List[Tuple[Query, Future]],
                                          List[Tuple[Query, Future]]]:
        """Next tick's batch (EDF order) + already-expired entries.

        Callers hold ``self._cond``. Deadline-free workloads skip the
        scan entirely and keep the legacy FIFO popleft. With deadlines
        present, past-deadline entries are pulled out for expiry and the
        rest are stably sorted earliest-deadline-first (deadline-less
        queries sort last, FIFO preserved within every tie class).
        """
        if not any(q.deadline is not None for q, _ in self._queue):
            batch = [self._queue.popleft()
                     for _ in range(min(len(self._queue), self.max_batch))]
            for q, _ in batch:
                self._tenant_dec_locked(q.tenant)
            return batch, []
        now = time.monotonic()
        expired: List[Tuple[Query, Future]] = []
        pending: List[Tuple[Query, Future]] = []
        for item in self._queue:
            q, _ = item
            if q.deadline is not None and q.deadline <= now:
                expired.append(item)
            else:
                pending.append(item)
        pending.sort(key=lambda e: (e[0].deadline is None,
                                    e[0].deadline or 0.0))
        batch, rest = pending[:self.max_batch], pending[self.max_batch:]
        self._queue = deque(rest)
        for q, _ in expired:
            self._tenant_dec_locked(q.tenant)
        for q, _ in batch:
            self._tenant_dec_locked(q.tenant)
        return batch, expired

    def _expire(self, expired: List[Tuple[Query, Future]]) -> None:
        """Fail past-deadline futures with a structured DeadlineExceeded."""
        now = time.monotonic()
        for q, fut in expired:
            if not fut.set_running_or_notify_cancel():
                continue  # client cancelled it first
            with self._cond:
                self.stats.failed += 1
                self.overload["expired"] += 1
            try:
                fut.set_exception(DeadlineExceeded(
                    f"deadline passed {now - q.deadline:.4f}s before "
                    f"serving (tenant {q.tenant!r})"))
            except Exception:
                pass

    def _loop(self) -> None:
        while True:
            with self._cond:
                self._apply_pending_locked()
                while self._running and not self._queue:
                    self._cond.wait()
                    self._apply_pending_locked()
                if not self._queue:  # stopped and drained
                    self._apply_pending_locked()
                    return
                batch, expired = self._take_batch_locked()
            if expired:
                self._expire(expired)
            # client-cancelled futures drop out of the batch here; the
            # rest transition to RUNNING so cancel() can no longer race
            # our set_result below.
            live = [(q, fut) for q, fut in batch
                    if fut.set_running_or_notify_cancel()]
            try:
                if live:
                    self._serve_batch(live)
            except Exception as e:
                # catch-all: a tick must never kill the worker — that
                # would hang every pending and future query silently.
                for _, fut in live:
                    if not fut.done():
                        with self._cond:
                            self.stats.failed += 1
                        try:
                            fut.set_exception(e)
                        except Exception:
                            pass
            with self._cond:
                if not self._running and not self._queue:
                    self._apply_pending_locked()  # don't strand a publish
                    return

    def _serve_batch(self, batch: List[Tuple[Query, Future]]) -> None:
        # counter mutations here happen under self._cond: the worker is
        # not the only writer (observe() and remote stats readers run on
        # client threads), and unlocked read-modify-writes drop counts.
        svc = self.service
        obs_on = self.metrics.enabled
        t_start = time.perf_counter()
        with self._cond:
            self.stats.ticks += 1
            tick = self.stats.ticks
            self.stats.max_batch = max(self.stats.max_batch, len(batch))
        # one (abacus, generation) snapshot covers the whole tick: even a
        # direct service.adopt racing this batch cannot mix generations
        # within it (verdicts are tagged with the snapshot generation).
        abacus, generation = svc.snapshot()
        # 1) resolve records: unique keys, cold misses traced concurrently.
        #    record_for's in-flight dedup makes duplicate keys (within the
        #    batch or racing with direct service callers) cost one trace.
        traces_before = svc.stats.traces
        by_key: Dict[tuple, Future] = {}
        rec_of, err_of = {}, {}
        key_of = []
        for idx, (q, _) in enumerate(batch):
            try:
                key = q.key() or svc.cache_key(q.cfg, q.batch, q.seq)
            except Exception as e:  # unfingerprintable cfg: fail that query
                key = ("__badkey__", idx)
                err_of[key] = e
                key_of.append(key)
                continue
            key_of.append(key)
            if key not in by_key:  # reuse the computed key: one fingerprint
                by_key[key] = self._pool.submit(
                    svc._record_for_key, key, q.cfg, q.batch, q.seq)
        for key, f in by_key.items():
            try:
                rec_of[key] = f.result()
            except Exception as e:  # bad config: fail that query, not the tick
                err_of[key] = e
        traces_ran = svc.stats.traces - traces_before
        with self._cond:
            self.stats.cold_traces += traces_ran
        t_records = time.perf_counter()
        # 2) ONE ensemble pass over the unique resolvable records.
        uniq = [k for k in by_key if k in rec_of]
        preds = {}
        ran_ensemble = False
        if uniq:
            try:
                # at most ONE ensemble pass per tick — and zero when the
                # whole micro-batch hits the per-generation prediction
                # cache (repeat queries under an unchanged generation).
                preds, ran_ensemble = svc.predict_keys(
                    uniq, [rec_of[k] for k in uniq],
                    abacus=abacus, generation=generation)
                with self._cond:
                    self.stats.ensemble_passes += int(ran_ensemble)
            except Exception as e:
                err_of.update({k: e for k in uniq})
        t_ensemble = time.perf_counter()
        # 3) resolve futures with per-query admission verdicts.
        for (q, fut), key in zip(batch, key_of):
            if key in preds:
                t, m = preds[key]
                with self._cond:
                    self.stats.completed += 1
                est = svc._estimate(rec_of[key], t, m, generation=generation)
                est["tick"] = tick
                est.update(self.est_tags)
                if q.tc is not None:
                    est["_trace"] = self._spans_for(
                        q, fut, tick, generation, len(batch), t_start,
                        t_records, t_ensemble, traces_ran, ran_ensemble)
                fut.set_result(est)
            else:
                with self._cond:
                    self.stats.failed += 1
                fut.set_exception(err_of.get(
                    key, RuntimeError("prediction failed")))
        if obs_on:
            t_end = time.perf_counter()
            t0s = [t0 for _, fut in batch
                   if (t0 := getattr(fut, "_obs_t0", None)) is not None]
            self._h_queue_wait.observe_many([t_start - t0 for t0 in t0s])
            self._h_latency.observe_many([t_end - t0 for t0 in t0s])
            self._h_tick.observe(t_end - t_start)
            if traces_ran:
                self._h_cold.observe(t_records - t_start)
            if ran_ensemble:
                self._h_ensemble.observe(t_ensemble - t_records)

    def _spans_for(self, q: Query, fut: Future, tick: int, generation,
                   batch_len: int, t_start: float, t_records: float,
                   t_ensemble: float, traces_ran: int,
                   ran_ensemble: bool) -> List[Dict]:
        """Lifecycle spans for one traced query's pass through the tick.

        Off the warm path by construction: only queries carrying a trace
        context reach here. Spans are recorded locally and returned so
        the caller can ship them back inside the estimate dict."""
        tid = q.tc.get("trace")
        parent = q.tc.get("span")
        now_perf = time.perf_counter()
        now_wall = time.time()

        def wall(tp: float) -> float:
            return now_wall - (now_perf - tp)

        replica = self.est_tags.get("replica")
        spans = []
        t0 = getattr(fut, "_obs_t0", None)
        if t0 is not None:
            spans.append(make_span(tid, "queue_wait", t_start - t0,
                                   parent=parent, ts=wall(t0),
                                   replica=replica))
        spans.append(make_span(tid, "tick_batch", now_perf - t_start,
                               parent=parent, ts=wall(t_start), tick=tick,
                               batch=batch_len, generation=generation,
                               replica=replica))
        tick_span = spans[-1]["span"]
        if traces_ran:
            spans.append(make_span(tid, "cold_trace", t_records - t_start,
                                   parent=tick_span, ts=wall(t_start),
                                   traces=traces_ran, replica=replica))
        if ran_ensemble:
            spans.append(make_span(tid, "ensemble", t_ensemble - t_records,
                                   parent=tick_span, ts=wall(t_records),
                                   replica=replica))
        spans.append(make_span(tid, "reply", now_perf - t_ensemble,
                               parent=tick_span, ts=wall(t_ensemble),
                               replica=replica))
        self.span_sink.extend(spans)
        return spans

    # -- introspection ------------------------------------------------------
    def metrics_snapshot(self) -> Dict:
        """JSON-safe snapshot of this gateway's registry (server_* and,
        when the service shares the registry, service_* metrics)."""
        svc_reg = getattr(self.service, "metrics", None)
        if svc_reg is None or svc_reg is self.metrics:
            return self.metrics.snapshot()
        return merge_snapshots([self.metrics.snapshot(), svc_reg.snapshot()])

    def metrics_text(self) -> str:
        """Prometheus text exposition of :meth:`metrics_snapshot`."""
        from repro.obs.metrics import render_prometheus
        return render_prometheus(self.metrics_snapshot(),
                                 namespace=self.metrics.namespace)

    def trace_spans(self, trace_id: str) -> List[Dict]:
        """Spans recorded locally for one trace id."""
        return self.span_sink.for_trace(trace_id)

    def server_info(self) -> Dict:
        with self._cond:
            queued = len(self._queue)
        return {"running": self._running, "queued": queued,
                "mean_batch": round(self.stats.mean_batch, 2),
                **self.stats.as_dict(), **self.service.cache_info()}

    def _stats_dict(self) -> Dict:
        """Everything ``server.stats()`` reports: counters + calibration.

        ``calibration`` carries the rolling windowed MRE / drift for
        time and memory, overall and split by the generation that made
        each prediction — the numbers that show a refit paying off.
        """
        d = self.server_info()
        d["calibration"] = self.calibration.metrics()
        # NEW keys only (stats() compat, PR 7): shed/expired/quota
        # accounting and per-tenant calibration land beside the legacy
        # surface, never inside it.
        d["overload"] = self.overload.as_dict()
        d["tenants"] = self.tenant_calibration.metrics()
        if self.refitter is not None:
            d["refit"] = self.refitter.info()
        if self.feedback is not None:
            d["feedback"] = self.feedback.info()
        return d

    def overload_counters(self) -> Dict[str, int]:
        """Shed/expired/quota counters, in the replica-interface shape
        the cluster frontend banks when a member retires."""
        with self._cond:
            return self.overload.as_dict()
