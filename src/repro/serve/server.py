"""AbacusServer: async micro-batched admission gateway (paper §4.3 at scale).

``PredictionService`` answers queries synchronously, one caller at a
time. In the datacenter setting the paper targets, admission queries
arrive concurrently from many tenants; serving them serially wastes the
two batchable stages — the ensemble pass (one design matrix amortizes N
queries) and cold trace misses (independent, thread-parallel).

``AbacusServer`` mirrors the continuous-batching shape of
``repro.serve.engine.DecodeEngine``: clients ``submit()`` queries into a
queue and get a ``Future``; a single worker thread wakes, coalesces
*everything* pending into one micro-batch per tick, resolves cold
misses concurrently on a trace pool, runs ONE ensemble pass for the
whole batch, and resolves each future with its admission verdict.

    with AbacusServer(service) as srv:
        futs = [srv.submit(cfg, b, 2048) for b in (8, 16, 32)]
        ests = [f.result() for f in futs]          # admission verdicts

A burst of N identical queries costs one trace (the service's in-flight
dedup) and one ensemble pass (the micro-batch); distinct cold queries
trace concurrently instead of serially inside ``predict_many``.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.serve.feedback_store import CalibrationWindow
from repro.serve.prediction_service import PredictionService, Query


@dataclasses.dataclass
class ServerStats:
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    ticks: int = 0             # micro-batches served
    ensemble_passes: int = 0   # abacus.predict calls (== ticks served)
    max_batch: int = 0         # largest micro-batch coalesced
    cold_traces: int = 0       # unique keys traced on the pool
    gen_swaps: int = 0         # generations hot-swapped between ticks
    observations: int = 0      # measured completions reported via observe()

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    @property
    def mean_batch(self) -> float:
        """Mean micro-batch size actually coalesced per tick.

        Failed queries still occupied a batch slot — dividing only
        ``completed`` by ``ticks`` would drag the reported coalescing
        size toward zero on failure-heavy workloads.
        """
        return (self.completed + self.failed) / self.ticks \
            if self.ticks else 0.0

    def __call__(self) -> Dict:
        """``server.stats()``: the full stats dict, counters included.

        The server stamps ``_full_stats`` onto its own ``ServerStats``
        instance so the counters stay attribute-addressable
        (``server.stats.ticks``) while ``server.stats()`` reports the
        whole picture — counters plus generation, rolling calibration,
        and refit state.
        """
        fn = getattr(self, "_full_stats", None)
        return fn() if fn is not None else self.as_dict()


class AbacusServer:
    """Event-loop front door over a ``PredictionService``.

    One worker thread owns the micro-batch loop; ``trace_workers``
    bounds the thread pool used for concurrent cold-miss traces.
    ``max_batch`` caps how many queued queries one tick coalesces
    (backpressure: the rest stay queued for the next tick).
    """

    def __init__(self, service: PredictionService, max_batch: int = 256,
                 trace_workers: int = 4, feedback=None, refitter=None,
                 calibration_window: int = 256):
        self.service = service
        self.max_batch = int(max_batch)
        self.trace_workers = int(trace_workers)
        # merged into every estimate this server resolves: a cluster
        # replica stamps {"replica": name} so fleet-level tests and
        # clients can attribute (tick, generation) pairs per replica.
        self.est_tags: Dict[str, object] = {}
        self.stats = ServerStats()
        self.stats._full_stats = self._stats_dict  # server.stats() works too
        # feedback loop (optional): measured completions land in the
        # FeedbackStore, calibration tracks predicted-vs-observed, and
        # the refitter publishes new generations back through us.
        self.feedback = feedback      # FeedbackStore or None
        self.calibration = CalibrationWindow(window=calibration_window)
        self.refitter = refitter      # OnlineRefitter or None
        if refitter is not None:
            refitter.add_sink(self)
        self._queue: Deque[Tuple[Query, Future]] = deque()
        self._cond = threading.Condition()
        self._pending_gen = None      # generation awaiting a tick boundary
        self._worker: Optional[threading.Thread] = None
        self._pool: Optional[ThreadPoolExecutor] = None
        self._running = False

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "AbacusServer":
        if self._running:
            return self
        if self._worker is not None and self._worker.is_alive():
            raise RuntimeError("previous worker is still draining; "
                               "call stop() again once it finishes")
        self._running = True
        if self._pool is None:
            self._pool = ThreadPoolExecutor(max_workers=self.trace_workers,
                                            thread_name_prefix="abacus-trace")
        self._worker = threading.Thread(target=self._loop,
                                        name="abacus-server", daemon=True)
        self._worker.start()
        return self

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        """Drain-then-stop: queued queries are served before shutdown.

        If the worker does not finish draining within ``timeout`` it is
        left running (it still exits after its current batch); the trace
        pool and queue are only torn down once the worker is gone —
        tearing them down under a live worker would strand its batch.
        """
        with self._cond:
            if not self._running and self._worker is None:
                return
            self._running = False
            self._cond.notify_all()
        worker = self._worker
        if worker is not None:
            worker.join(timeout)
            if worker.is_alive():  # still draining: do not yank the pool
                return
            self._worker = None
        # a publish that raced the worker's exit may still sit queued
        with self._cond:
            self._apply_pending_locked()
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        # anything still queued after the drain tick fails loudly
        with self._cond:
            leftovers, self._queue = list(self._queue), deque()
        for _, fut in leftovers:
            if not fut.done():
                try:
                    fut.set_exception(RuntimeError("AbacusServer stopped"))
                except Exception:
                    pass  # client cancelled it first

    def __enter__(self) -> "AbacusServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def running(self) -> bool:
        return self._running

    @property
    def draining(self) -> bool:
        """True while a stopped worker is still finishing its drain.

        ``stop(timeout)`` can return before the worker exits (a slow
        trace mid-tick); callers that need a *quiesced* server — the
        reshard protocol migrates store slices only once writes ceased
        — must check this, not just ``running``.
        """
        worker = self._worker
        return (worker is not None and worker.is_alive()
                and not self._running)

    # -- client API ---------------------------------------------------------
    def submit(self, cfg, batch: int, seq: int,
               fp: Optional[str] = None) -> Future:
        """Enqueue one admission query; resolves to the estimate dict.

        ``fp`` optionally carries the config fingerprint a router
        already computed, sparing this server's worker the re-hash.
        """
        fut: Future = Future()
        q = Query(cfg, int(batch), int(seq), fp=fp)
        with self._cond:
            if not self._running:
                raise RuntimeError("AbacusServer is not running "
                                   "(use `with AbacusServer(...)` or start())")
            self._queue.append((q, fut))
            self.stats.submitted += 1
            self._cond.notify()
        return fut

    def submit_many(self, queries: Sequence) -> List[Future]:
        qs = [q if isinstance(q, Query) else Query(*q) for q in queries]
        futs: List[Future] = [Future() for _ in qs]
        with self._cond:
            if not self._running:
                raise RuntimeError("AbacusServer is not running "
                                   "(use `with AbacusServer(...)` or start())")
            self._queue.extend(zip(qs, futs))
            self.stats.submitted += len(qs)
            self._cond.notify()
        return futs

    def predict_one(self, cfg, batch: int, seq: int,
                    timeout: Optional[float] = None) -> Dict:
        """Synchronous convenience: submit and wait for the verdict."""
        return self.submit(cfg, batch, seq).result(timeout)

    def predict_many(self, queries: Sequence,
                     timeout: Optional[float] = None) -> List[Dict]:
        return [f.result(timeout) for f in self.submit_many(queries)]

    # -- model generations --------------------------------------------------
    def publish_generation(self, gen) -> bool:
        """Queue a ``ModelGeneration`` for adoption at a tick boundary.

        The swap is applied by the worker thread *between* micro-batch
        ticks, so an in-flight micro-batch always finishes on the
        generation it started with — a hot swap can never mix
        generations within one tick. With no live worker (bare server)
        nothing is in flight and the service adopts immediately.
        """
        with self._cond:
            # queue only while the worker is RUNNING: during shutdown the
            # worker may already be past its final pending check, so a
            # queued generation could be stranded — adopt directly
            # instead (safe: an in-flight tick predicts from its own
            # snapshot, so a mid-drain adopt still can't mix a tick).
            if (self._running and self._worker is not None
                    and self._worker.is_alive()):
                if (self._pending_gen is None
                        or gen.number > self._pending_gen.number):
                    self._pending_gen = gen
                self._cond.notify_all()
                return True
        adopted = self.service.adopt(gen.abacus, gen.number)
        if adopted:
            # the direct-adopt path bypasses _apply_pending_locked, but a
            # successful swap is a swap — count it, or fleet-level swap
            # accounting disagrees with the generations actually serving.
            with self._cond:
                self.stats.gen_swaps += 1
        return adopted

    def _apply_pending_locked(self) -> None:
        """Adopt a queued generation; callers hold ``self._cond``."""
        gen, self._pending_gen = self._pending_gen, None
        if gen is not None and self.service.adopt(gen.abacus, gen.number):
            self.stats.gen_swaps += 1

    # -- feedback loop ------------------------------------------------------
    def observe(self, cfg, batch: int, seq: int, time_s: float,
                mem_bytes: float, *, predicted_time_s: Optional[float] = None,
                predicted_mem_bytes: Optional[float] = None,
                generation: Optional[int] = None, job_id: str = "",
                fp: Optional[str] = None) -> None:
        """Report one finished job's measured cost.

        Feeds the rolling calibration window (when the prediction that
        admitted the job is supplied), persists the observation in the
        ``FeedbackStore`` (when attached), and wakes the refitter.
        Non-positive measurements are dropped at this shared entry
        point: they carry no calibration signal and would poison the
        window (inf MRE) and the refit targets (log of ~0).
        """
        if float(time_s) <= 0.0 or float(mem_bytes) <= 0.0:
            return
        with self._cond:  # concurrent observers race the unlocked += 1
            self.stats.observations += 1
        if predicted_time_s is not None and predicted_mem_bytes is not None:
            self.calibration.observe(predicted_time_s, time_s,
                                     predicted_mem_bytes, mem_bytes,
                                     generation)
        if self.feedback is not None:
            key = ((fp, int(batch), int(seq)) if fp is not None
                   else self.service.cache_key(cfg, batch, seq))
            self.feedback.add(key, time_s, mem_bytes,
                              generation=generation, job_id=job_id)
        if self.refitter is not None:
            self.refitter.notify()

    # -- worker loop --------------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._cond:
                self._apply_pending_locked()
                while self._running and not self._queue:
                    self._cond.wait()
                    self._apply_pending_locked()
                if not self._queue:  # stopped and drained
                    self._apply_pending_locked()
                    return
                batch = [self._queue.popleft()
                         for _ in range(min(len(self._queue), self.max_batch))]
            # client-cancelled futures drop out of the batch here; the
            # rest transition to RUNNING so cancel() can no longer race
            # our set_result below.
            live = [(q, fut) for q, fut in batch
                    if fut.set_running_or_notify_cancel()]
            try:
                if live:
                    self._serve_batch(live)
            except Exception as e:
                # catch-all: a tick must never kill the worker — that
                # would hang every pending and future query silently.
                for _, fut in live:
                    if not fut.done():
                        with self._cond:
                            self.stats.failed += 1
                        try:
                            fut.set_exception(e)
                        except Exception:
                            pass
            with self._cond:
                if not self._running and not self._queue:
                    self._apply_pending_locked()  # don't strand a publish
                    return

    def _serve_batch(self, batch: List[Tuple[Query, Future]]) -> None:
        # counter mutations here happen under self._cond: the worker is
        # not the only writer (observe() and remote stats readers run on
        # client threads), and unlocked read-modify-writes drop counts.
        svc = self.service
        with self._cond:
            self.stats.ticks += 1
            tick = self.stats.ticks
            self.stats.max_batch = max(self.stats.max_batch, len(batch))
        # one (abacus, generation) snapshot covers the whole tick: even a
        # direct service.adopt racing this batch cannot mix generations
        # within it (verdicts are tagged with the snapshot generation).
        abacus, generation = svc.snapshot()
        # 1) resolve records: unique keys, cold misses traced concurrently.
        #    record_for's in-flight dedup makes duplicate keys (within the
        #    batch or racing with direct service callers) cost one trace.
        traces_before = svc.stats.traces
        by_key: Dict[tuple, Future] = {}
        rec_of, err_of = {}, {}
        key_of = []
        for idx, (q, _) in enumerate(batch):
            try:
                key = q.key() or svc.cache_key(q.cfg, q.batch, q.seq)
            except Exception as e:  # unfingerprintable cfg: fail that query
                key = ("__badkey__", idx)
                err_of[key] = e
                key_of.append(key)
                continue
            key_of.append(key)
            if key not in by_key:  # reuse the computed key: one fingerprint
                by_key[key] = self._pool.submit(
                    svc._record_for_key, key, q.cfg, q.batch, q.seq)
        for key, f in by_key.items():
            try:
                rec_of[key] = f.result()
            except Exception as e:  # bad config: fail that query, not the tick
                err_of[key] = e
        with self._cond:
            self.stats.cold_traces += svc.stats.traces - traces_before
        # 2) ONE ensemble pass over the unique resolvable records.
        uniq = [k for k in by_key if k in rec_of]
        preds = {}
        if uniq:
            try:
                # at most ONE ensemble pass per tick — and zero when the
                # whole micro-batch hits the per-generation prediction
                # cache (repeat queries under an unchanged generation).
                preds, ran_ensemble = svc.predict_keys(
                    uniq, [rec_of[k] for k in uniq],
                    abacus=abacus, generation=generation)
                with self._cond:
                    self.stats.ensemble_passes += int(ran_ensemble)
            except Exception as e:
                err_of.update({k: e for k in uniq})
        # 3) resolve futures with per-query admission verdicts.
        for (q, fut), key in zip(batch, key_of):
            if key in preds:
                t, m = preds[key]
                with self._cond:
                    self.stats.completed += 1
                est = svc._estimate(rec_of[key], t, m, generation=generation)
                est["tick"] = tick
                est.update(self.est_tags)
                fut.set_result(est)
            else:
                with self._cond:
                    self.stats.failed += 1
                fut.set_exception(err_of.get(
                    key, RuntimeError("prediction failed")))

    # -- introspection ------------------------------------------------------
    def server_info(self) -> Dict:
        with self._cond:
            queued = len(self._queue)
        return {"running": self._running, "queued": queued,
                "mean_batch": round(self.stats.mean_batch, 2),
                **self.stats.as_dict(), **self.service.cache_info()}

    def _stats_dict(self) -> Dict:
        """Everything ``server.stats()`` reports: counters + calibration.

        ``calibration`` carries the rolling windowed MRE / drift for
        time and memory, overall and split by the generation that made
        each prediction — the numbers that show a refit paying off.
        """
        d = self.server_info()
        d["calibration"] = self.calibration.metrics()
        if self.refitter is not None:
            d["refit"] = self.refitter.info()
        if self.feedback is not None:
            d["feedback"] = self.feedback.info()
        return d
