"""AbacusServer: async micro-batched admission gateway (paper §4.3 at scale).

``PredictionService`` answers queries synchronously, one caller at a
time. In the datacenter setting the paper targets, admission queries
arrive concurrently from many tenants; serving them serially wastes the
two batchable stages — the ensemble pass (one design matrix amortizes N
queries) and cold trace misses (independent, thread-parallel).

``AbacusServer`` mirrors the continuous-batching shape of
``repro.serve.engine.DecodeEngine``: clients ``submit()`` queries into a
queue and get a ``Future``; a single worker thread wakes, coalesces
*everything* pending into one micro-batch per tick, resolves cold
misses concurrently on a trace pool, runs ONE ensemble pass for the
whole batch, and resolves each future with its admission verdict.

    with AbacusServer(service) as srv:
        futs = [srv.submit(cfg, b, 2048) for b in (8, 16, 32)]
        ests = [f.result() for f in futs]          # admission verdicts

A burst of N identical queries costs one trace (the service's in-flight
dedup) and one ensemble pass (the micro-batch); distinct cold queries
trace concurrently instead of serially inside ``predict_many``.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.serve.prediction_service import PredictionService, Query


@dataclasses.dataclass
class ServerStats:
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    ticks: int = 0             # micro-batches served
    ensemble_passes: int = 0   # abacus.predict calls (== ticks served)
    max_batch: int = 0         # largest micro-batch coalesced
    cold_traces: int = 0       # unique keys traced on the pool

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    @property
    def mean_batch(self) -> float:
        return self.completed / self.ticks if self.ticks else 0.0


class AbacusServer:
    """Event-loop front door over a ``PredictionService``.

    One worker thread owns the micro-batch loop; ``trace_workers``
    bounds the thread pool used for concurrent cold-miss traces.
    ``max_batch`` caps how many queued queries one tick coalesces
    (backpressure: the rest stay queued for the next tick).
    """

    def __init__(self, service: PredictionService, max_batch: int = 256,
                 trace_workers: int = 4):
        self.service = service
        self.max_batch = int(max_batch)
        self.trace_workers = int(trace_workers)
        self.stats = ServerStats()
        self._queue: Deque[Tuple[Query, Future]] = deque()
        self._cond = threading.Condition()
        self._worker: Optional[threading.Thread] = None
        self._pool: Optional[ThreadPoolExecutor] = None
        self._running = False

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "AbacusServer":
        if self._running:
            return self
        if self._worker is not None and self._worker.is_alive():
            raise RuntimeError("previous worker is still draining; "
                               "call stop() again once it finishes")
        self._running = True
        if self._pool is None:
            self._pool = ThreadPoolExecutor(max_workers=self.trace_workers,
                                            thread_name_prefix="abacus-trace")
        self._worker = threading.Thread(target=self._loop,
                                        name="abacus-server", daemon=True)
        self._worker.start()
        return self

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        """Drain-then-stop: queued queries are served before shutdown.

        If the worker does not finish draining within ``timeout`` it is
        left running (it still exits after its current batch); the trace
        pool and queue are only torn down once the worker is gone —
        tearing them down under a live worker would strand its batch.
        """
        with self._cond:
            if not self._running and self._worker is None:
                return
            self._running = False
            self._cond.notify_all()
        worker = self._worker
        if worker is not None:
            worker.join(timeout)
            if worker.is_alive():  # still draining: do not yank the pool
                return
            self._worker = None
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        # anything still queued after the drain tick fails loudly
        with self._cond:
            leftovers, self._queue = list(self._queue), deque()
        for _, fut in leftovers:
            if not fut.done():
                try:
                    fut.set_exception(RuntimeError("AbacusServer stopped"))
                except Exception:
                    pass  # client cancelled it first

    def __enter__(self) -> "AbacusServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def running(self) -> bool:
        return self._running

    # -- client API ---------------------------------------------------------
    def submit(self, cfg, batch: int, seq: int) -> Future:
        """Enqueue one admission query; resolves to the estimate dict."""
        fut: Future = Future()
        q = Query(cfg, int(batch), int(seq))
        with self._cond:
            if not self._running:
                raise RuntimeError("AbacusServer is not running "
                                   "(use `with AbacusServer(...)` or start())")
            self._queue.append((q, fut))
            self.stats.submitted += 1
            self._cond.notify()
        return fut

    def submit_many(self, queries: Sequence) -> List[Future]:
        qs = [q if isinstance(q, Query) else Query(*q) for q in queries]
        futs: List[Future] = [Future() for _ in qs]
        with self._cond:
            if not self._running:
                raise RuntimeError("AbacusServer is not running "
                                   "(use `with AbacusServer(...)` or start())")
            self._queue.extend(zip(qs, futs))
            self.stats.submitted += len(qs)
            self._cond.notify()
        return futs

    def predict_one(self, cfg, batch: int, seq: int,
                    timeout: Optional[float] = None) -> Dict:
        """Synchronous convenience: submit and wait for the verdict."""
        return self.submit(cfg, batch, seq).result(timeout)

    def predict_many(self, queries: Sequence,
                     timeout: Optional[float] = None) -> List[Dict]:
        return [f.result(timeout) for f in self.submit_many(queries)]

    # -- worker loop --------------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._cond:
                while self._running and not self._queue:
                    self._cond.wait()
                if not self._queue:  # stopped and drained
                    return
                batch = [self._queue.popleft()
                         for _ in range(min(len(self._queue), self.max_batch))]
            # client-cancelled futures drop out of the batch here; the
            # rest transition to RUNNING so cancel() can no longer race
            # our set_result below.
            live = [(q, fut) for q, fut in batch
                    if fut.set_running_or_notify_cancel()]
            try:
                if live:
                    self._serve_batch(live)
            except Exception as e:
                # catch-all: a tick must never kill the worker — that
                # would hang every pending and future query silently.
                for _, fut in live:
                    if not fut.done():
                        self.stats.failed += 1
                        try:
                            fut.set_exception(e)
                        except Exception:
                            pass
            with self._cond:
                if not self._running and not self._queue:
                    return

    def _serve_batch(self, batch: List[Tuple[Query, Future]]) -> None:
        svc = self.service
        self.stats.ticks += 1
        self.stats.max_batch = max(self.stats.max_batch, len(batch))
        # 1) resolve records: unique keys, cold misses traced concurrently.
        #    record_for's in-flight dedup makes duplicate keys (within the
        #    batch or racing with direct service callers) cost one trace.
        traces_before = svc.stats.traces
        by_key: Dict[tuple, Future] = {}
        rec_of, err_of = {}, {}
        key_of = []
        for idx, (q, _) in enumerate(batch):
            try:
                key = svc.cache_key(q.cfg, q.batch, q.seq)
            except Exception as e:  # unfingerprintable cfg: fail that query
                key = ("__badkey__", idx)
                err_of[key] = e
                key_of.append(key)
                continue
            key_of.append(key)
            if key not in by_key:
                by_key[key] = self._pool.submit(
                    svc.record_for, q.cfg, q.batch, q.seq)
        for key, f in by_key.items():
            try:
                rec_of[key] = f.result()
            except Exception as e:  # bad config: fail that query, not the tick
                err_of[key] = e
        self.stats.cold_traces += svc.stats.traces - traces_before
        # 2) ONE ensemble pass over the unique resolvable records.
        uniq = [k for k in by_key if k in rec_of]
        preds = {}
        if uniq:
            try:
                t_pred, m_pred = svc.predict_records([rec_of[k] for k in uniq])
                self.stats.ensemble_passes += 1
                preds = {k: (t, m) for k, t, m in zip(uniq, t_pred, m_pred)}
            except Exception as e:
                err_of.update({k: e for k in uniq})
        # 3) resolve futures with per-query admission verdicts.
        for (q, fut), key in zip(batch, key_of):
            if key in preds:
                t, m = preds[key]
                self.stats.completed += 1
                fut.set_result(svc._estimate(rec_of[key], t, m))
            else:
                self.stats.failed += 1
                fut.set_exception(err_of.get(
                    key, RuntimeError("prediction failed")))

    # -- introspection ------------------------------------------------------
    def server_info(self) -> Dict:
        with self._cond:
            queued = len(self._queue)
        return {"running": self._running, "queued": queued,
                "mean_batch": round(self.stats.mean_batch, 2),
                **self.stats.as_dict(), **self.service.cache_info()}
