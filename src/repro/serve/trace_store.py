"""Persistent cross-process trace store for the prediction server.

The trace cache in ``PredictionService`` dies with the process, so every
scheduler restart re-pays the jaxpr trace for every admission query it
has ever answered. ``TraceStore`` persists traced ``ProfileRecord``s
(including NSM edges) to disk, content-addressed by the same
``(config fingerprint, batch, seq)`` key the in-memory cache uses, so a
fresh process warm-starts from prior traces: load-on-miss, atomic
write-on-trace.

All persistence mechanics — versioned schema, corrupt/foreign records
skipped (counted, never fatal), atomic writes, TTL/entry-cap
``compact``, order-independent ``merge`` — live in the shared
``repro.serve.kvstore`` engines; this module only defines what a
*trace* value is (the ``TraceValues`` mixin), composed with either
physical layout:

  * ``TraceStore`` — the historical file-per-key JSON layout.
  * ``SegmentTraceStore`` — the append-only segment-log layout.
  * ``make_trace_store`` — backend-selected construction
    (``REPRO_STORE_BACKEND`` chooses the fleet-wide default).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.core.features import ProfileRecord, record_from_json, record_to_json
from repro.serve.kvstore import (SCHEMA_VERSION, STORE_BACKENDS,
                                 JsonFileStore, SegmentLogStore, StoreKey,
                                 atomic_write_json, store_backend)

__all__ = ["TraceStore", "SegmentTraceStore", "make_trace_store",
           "TraceValues", "StoreStats", "StoreKey", "SCHEMA_VERSION",
           "atomic_write_json"]


@dataclasses.dataclass
class StoreStats:
    hits: int = 0        # get() served a record from disk
    misses: int = 0      # get() found no (servable) file
    writes: int = 0      # put() persisted a record
    corrupt: int = 0     # files skipped: unparseable / wrong version / bad key
    merged: int = 0      # records imported by merge()

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class TraceValues:
    """Trace value semantics, independent of physical layout.

    Defines what a *trace* value is — validation, the deterministic
    record-union merge, stats accounting, the typed ``get``/``put``
    API — as a mixin over any ``repro.serve.kvstore`` engine.
    """

    VALUE_FIELD = "record"

    def __init__(self, root: str, **kwargs):
        super().__init__(root, **kwargs)
        self.stats = StoreStats()

    # -- store engine hooks -------------------------------------------------
    def _check_raw(self, raw):
        if not isinstance(raw, dict):
            raise ValueError("missing record payload")
        return raw

    def _servable(self, raw) -> None:
        record_from_json(raw)  # a record that cannot load is dead weight

    def _merge_raw(self, mine, theirs):
        """Deterministic record union: identical contents dedupe; two
        hosts that (exceptionally) traced different records for one key
        converge on the same winner regardless of merge order, chosen
        by canonical-JSON ordering — never by who merged first."""
        if mine is None:
            return theirs, 1
        if mine == theirs:
            return mine, 0
        keep_mine = (json.dumps(mine, sort_keys=True)
                     >= json.dumps(theirs, sort_keys=True))
        return (mine, 0) if keep_mine else (theirs, 1)

    def _note_corrupt(self) -> None:
        with self._lock:
            self.stats.corrupt += 1

    def _on_merge(self, key: StoreKey, n_new: int) -> None:
        with self._lock:
            self.stats.merged += n_new

    # -- load / save --------------------------------------------------------
    def get(self, key: StoreKey) -> Optional[ProfileRecord]:
        """Record for ``key``, or None. Corrupted files are skipped."""
        raw = self.get_raw(key)  # corrupt counted by the shared load path
        if raw is not None:
            try:
                rec = record_from_json(raw)
            except (ValueError, KeyError, TypeError):
                self._note_corrupt()
                rec = None
            if rec is not None:
                with self._lock:
                    self.stats.hits += 1
                return rec
        with self._lock:
            self.stats.misses += 1
        return None

    def put(self, key: StoreKey, rec: ProfileRecord) -> str:
        """Atomically persist ``rec`` under ``key``; returns the file path."""
        path = self.put_raw(key, record_to_json(rec))
        with self._lock:
            self.stats.writes += 1
        return path

    # -- introspection ------------------------------------------------------
    def info(self) -> Dict[str, int]:
        return {"store_entries": len(self), **self.stats.as_dict()}


class TraceStore(TraceValues, JsonFileStore):
    """Durable ``(fingerprint, batch, seq) -> ProfileRecord`` map on disk,
    one JSON file per key (the historical layout)."""


class SegmentTraceStore(TraceValues, SegmentLogStore):
    """Trace store on the append-only segment-log engine."""


def make_trace_store(root: str, backend: Optional[str] = None) -> TraceValues:
    """Trace store on the selected engine (arg > ``REPRO_STORE_BACKEND``
    env var > ``json``). Both engines serve the identical contract; the
    backend only changes the physical layout under ``root``."""
    cls = {"json": TraceStore,
           "segment": SegmentTraceStore}[store_backend(backend)]
    return cls(root)


assert set(STORE_BACKENDS) == {"json", "segment"}  # keep factories in sync
