"""Persistent cross-process trace store for the prediction server.

The trace cache in ``PredictionService`` dies with the process, so every
scheduler restart re-pays the jaxpr trace for every admission query it
has ever answered. ``TraceStore`` persists traced ``ProfileRecord``s
(including NSM edges) to disk, content-addressed by the same
``(config fingerprint, batch, seq)`` key the in-memory cache uses, so a
fresh process warm-starts from prior traces: load-on-miss, atomic
write-on-trace.

All persistence mechanics — one JSON file per key, versioned schema,
corrupt/foreign files skipped (counted, never fatal), temp +
``os.replace`` writes, TTL/entry-cap ``compact``, order-independent
``merge`` — live in the shared ``repro.serve.kvstore.JsonFileStore``
base; this module only defines what a *trace* value is.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.core.features import ProfileRecord, record_from_json, record_to_json
from repro.serve.kvstore import (SCHEMA_VERSION, JsonFileStore, StoreKey,
                                 atomic_write_json)

__all__ = ["TraceStore", "StoreStats", "StoreKey", "SCHEMA_VERSION",
           "atomic_write_json"]


@dataclasses.dataclass
class StoreStats:
    hits: int = 0        # get() served a record from disk
    misses: int = 0      # get() found no (servable) file
    writes: int = 0      # put() persisted a record
    corrupt: int = 0     # files skipped: unparseable / wrong version / bad key
    merged: int = 0      # records imported by merge()

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class TraceStore(JsonFileStore):
    """Durable ``(fingerprint, batch, seq) -> ProfileRecord`` map on disk."""

    VALUE_FIELD = "record"

    def __init__(self, root: str):
        super().__init__(root)
        self.stats = StoreStats()

    # -- JsonFileStore hooks ------------------------------------------------
    def _check_raw(self, raw):
        if not isinstance(raw, dict):
            raise ValueError("missing record payload")
        return raw

    def _servable(self, raw) -> None:
        record_from_json(raw)  # a record that cannot load is dead weight

    def _merge_raw(self, mine, theirs):
        """Deterministic record union: identical contents dedupe; two
        hosts that (exceptionally) traced different records for one key
        converge on the same winner regardless of merge order, chosen
        by canonical-JSON ordering — never by who merged first."""
        if mine is None:
            return theirs, 1
        if mine == theirs:
            return mine, 0
        keep_mine = (json.dumps(mine, sort_keys=True)
                     >= json.dumps(theirs, sort_keys=True))
        return (mine, 0) if keep_mine else (theirs, 1)

    def _note_corrupt(self) -> None:
        with self._lock:
            self.stats.corrupt += 1

    def _on_merge(self, key: StoreKey, n_new: int) -> None:
        with self._lock:
            self.stats.merged += n_new

    # -- load / save --------------------------------------------------------
    def get(self, key: StoreKey) -> Optional[ProfileRecord]:
        """Record for ``key``, or None. Corrupted files are skipped."""
        raw = self.get_raw(key)  # corrupt counted by the shared load path
        if raw is not None:
            try:
                rec = record_from_json(raw)
            except (ValueError, KeyError, TypeError):
                self._note_corrupt()
                rec = None
            if rec is not None:
                with self._lock:
                    self.stats.hits += 1
                return rec
        with self._lock:
            self.stats.misses += 1
        return None

    def put(self, key: StoreKey, rec: ProfileRecord) -> str:
        """Atomically persist ``rec`` under ``key``; returns the file path."""
        path = self.put_raw(key, record_to_json(rec))
        with self._lock:
            self.stats.writes += 1
        return path

    # -- introspection ------------------------------------------------------
    def info(self) -> Dict[str, int]:
        return {"store_entries": len(self), **self.stats.as_dict()}
