"""Persistent cross-process trace store for the prediction server.

The trace cache in ``PredictionService`` dies with the process, so every
scheduler restart re-pays the jaxpr trace for every admission query it
has ever answered. ``TraceStore`` persists traced ``ProfileRecord``s
(including NSM edges) to disk, content-addressed by the same
``(config fingerprint, batch, seq)`` key the in-memory cache uses, so a
fresh process warm-starts from prior traces: load-on-miss, atomic
write-on-trace.

Layout: one JSON file per key under ``root/``, named
``<fingerprint>_b<batch>_s<seq>.json``. Each file carries a schema
version and echoes its own key; loads that fail to parse, carry a
foreign schema version, or disagree with their filename's key are
*skipped* (counted, never fatal) — a corrupted or stale file costs one
re-trace, not a crash. Writes go through a same-directory temp file and
``os.replace`` so concurrent processes never observe a torn record.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading
import time
from typing import Dict, Iterator, List, Optional, Tuple

from repro.core.features import ProfileRecord, record_from_json, record_to_json

StoreKey = Tuple[str, int, int]  # (config fingerprint, batch, seq)

SCHEMA_VERSION = 1


def atomic_write_json(root: str, path: str, payload: Dict) -> None:
    """Same-directory temp file + ``os.replace``: concurrent readers see
    the old file or the new one, never a torn record. Shared by every
    durable store in ``repro.serve`` (traces, feedback) so the write
    discipline is fixed in exactly one place."""
    fd, tmp = tempfile.mkstemp(dir=root, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, path)  # atomic on POSIX
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


@dataclasses.dataclass
class StoreStats:
    hits: int = 0        # get() served a record from disk
    misses: int = 0      # get() found no file
    writes: int = 0      # put() persisted a record
    corrupt: int = 0     # files skipped: unparseable / wrong version / bad key

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class TraceStore:
    """Durable ``(fingerprint, batch, seq) -> ProfileRecord`` map on disk."""

    def __init__(self, root: str):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)
        self.stats = StoreStats()
        self._lock = threading.Lock()

    # -- key/file mapping ---------------------------------------------------
    @staticmethod
    def filename(key: StoreKey) -> str:
        fp, batch, seq = key
        return f"{fp}_b{int(batch)}_s{int(seq)}.json"

    def path_for(self, key: StoreKey) -> str:
        return os.path.join(self.root, self.filename(key))

    @staticmethod
    def _key_from_payload(payload: Dict) -> StoreKey:
        fp, batch, seq = payload["key"]
        return (str(fp), int(batch), int(seq))

    # -- load / save --------------------------------------------------------
    def get(self, key: StoreKey) -> Optional[ProfileRecord]:
        """Record for ``key``, or None. Corrupted files are skipped."""
        path = self.path_for(key)
        if not os.path.exists(path):
            with self._lock:
                self.stats.misses += 1
            return None
        try:
            with open(path) as f:
                payload = json.load(f)
            if payload.get("version") != SCHEMA_VERSION:
                raise ValueError(f"schema version {payload.get('version')!r}")
            if self._key_from_payload(payload) != key:
                raise ValueError("stored key disagrees with filename")
            rec = record_from_json(payload["record"])
        except (OSError, ValueError, KeyError, TypeError) as e:
            # json.JSONDecodeError is a ValueError; a bad record dict raises
            # KeyError/TypeError in record_from_json. All are one re-trace.
            with self._lock:
                self.stats.corrupt += 1
                self.stats.misses += 1
            self._last_error = f"{type(e).__name__}: {e}"
            return None
        with self._lock:
            self.stats.hits += 1
        return rec

    def put(self, key: StoreKey, rec: ProfileRecord) -> str:
        """Atomically persist ``rec`` under ``key``; returns the file path."""
        path = self.path_for(key)
        payload = {"version": SCHEMA_VERSION,
                   "key": [key[0], int(key[1]), int(key[2])],
                   "record": record_to_json(rec)}
        atomic_write_json(self.root, path, payload)
        with self._lock:
            self.stats.writes += 1
        return path

    # -- inventory ----------------------------------------------------------
    def _files(self) -> List[str]:
        try:
            names = os.listdir(self.root)
        except OSError:
            return []
        return sorted(n for n in names if n.endswith(".json"))

    def __len__(self) -> int:
        return len(self._files())

    def keys(self) -> Iterator[StoreKey]:
        """Keys of every loadable record (corrupted files skipped)."""
        for name in self._files():
            try:
                with open(os.path.join(self.root, name)) as f:
                    payload = json.load(f)
                if payload.get("version") != SCHEMA_VERSION:
                    continue
                yield self._key_from_payload(payload)
            except (OSError, ValueError, KeyError, TypeError):
                continue

    def clear(self) -> int:
        """Delete every stored record; returns how many files were removed."""
        n = 0
        for name in self._files():
            try:
                os.unlink(os.path.join(self.root, name))
                n += 1
            except OSError:
                pass
        return n

    def compact(self, max_age_s: Optional[float] = None,
                max_entries: Optional[int] = None) -> Dict[str, int]:
        """Garbage-collect the store: stale schemas, TTL, entry cap.

        Drops (1) files carrying a foreign schema generation or that no
        longer parse — they can never be served, only re-skipped on
        every ``get`` — (2) files older than ``max_age_s`` (by mtime;
        the TTL), and (3) the oldest files beyond ``max_entries``
        (newest survive). Deletion is plain ``unlink``: a concurrent
        reader either opened the file first (and reads the old record)
        or misses and re-traces — never a torn read. Returns removal
        counts by reason plus the surviving entry count.
        """
        now = time.time()
        valid: List[tuple] = []  # (mtime, name) of loadable current-schema
        removed = {"stale_schema": 0, "expired": 0, "over_cap": 0}

        def _unlink(name: str, reason: str) -> None:
            try:
                os.unlink(os.path.join(self.root, name))
                removed[reason] += 1
            except OSError:
                pass  # a concurrent compact/clear got there first

        for name in self._files():
            path = os.path.join(self.root, name)
            try:
                mtime = os.path.getmtime(path)
                with open(path) as f:
                    payload = json.load(f)
                if payload.get("version") != SCHEMA_VERSION:
                    raise ValueError("foreign schema")
                self._key_from_payload(payload)
                record_from_json(payload["record"])  # must be servable:
                # a parseable file whose record cannot load would be
                # re-skipped by every get() forever — exactly what
                # compaction exists to drop
            except (OSError, ValueError, KeyError, TypeError):
                _unlink(name, "stale_schema")
                continue
            if max_age_s is not None and now - mtime > max_age_s:
                _unlink(name, "expired")
                continue
            valid.append((mtime, name))
        if max_entries is not None and len(valid) > max_entries:
            valid.sort()  # oldest first
            doomed, valid = valid[:len(valid) - max_entries], \
                valid[len(valid) - max_entries:]
            for _, name in doomed:
                _unlink(name, "over_cap")
        return {**removed, "removed": sum(removed.values()),
                "kept": len(valid)}

    def info(self) -> Dict[str, int]:
        return {"store_entries": len(self), **self.stats.as_dict()}
