"""Persistent cross-process trace store for the prediction server.

The trace cache in ``PredictionService`` dies with the process, so every
scheduler restart re-pays the jaxpr trace for every admission query it
has ever answered. ``TraceStore`` persists traced ``ProfileRecord``s
(including NSM edges) to disk, content-addressed by the same
``(config fingerprint, batch, seq)`` key the in-memory cache uses, so a
fresh process warm-starts from prior traces: load-on-miss, atomic
write-on-trace.

Layout: one JSON file per key under ``root/``, named
``<fingerprint>_b<batch>_s<seq>.json``. Each file carries a schema
version and echoes its own key; loads that fail to parse, carry a
foreign schema version, or disagree with their filename's key are
*skipped* (counted, never fatal) — a corrupted or stale file costs one
re-trace, not a crash. Writes go through a same-directory temp file and
``os.replace`` so concurrent processes never observe a torn record.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading
from typing import Dict, Iterator, List, Optional, Tuple

from repro.core.features import ProfileRecord, record_from_json, record_to_json

StoreKey = Tuple[str, int, int]  # (config fingerprint, batch, seq)

SCHEMA_VERSION = 1


@dataclasses.dataclass
class StoreStats:
    hits: int = 0        # get() served a record from disk
    misses: int = 0      # get() found no file
    writes: int = 0      # put() persisted a record
    corrupt: int = 0     # files skipped: unparseable / wrong version / bad key

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class TraceStore:
    """Durable ``(fingerprint, batch, seq) -> ProfileRecord`` map on disk."""

    def __init__(self, root: str):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)
        self.stats = StoreStats()
        self._lock = threading.Lock()

    # -- key/file mapping ---------------------------------------------------
    @staticmethod
    def filename(key: StoreKey) -> str:
        fp, batch, seq = key
        return f"{fp}_b{int(batch)}_s{int(seq)}.json"

    def path_for(self, key: StoreKey) -> str:
        return os.path.join(self.root, self.filename(key))

    @staticmethod
    def _key_from_payload(payload: Dict) -> StoreKey:
        fp, batch, seq = payload["key"]
        return (str(fp), int(batch), int(seq))

    # -- load / save --------------------------------------------------------
    def get(self, key: StoreKey) -> Optional[ProfileRecord]:
        """Record for ``key``, or None. Corrupted files are skipped."""
        path = self.path_for(key)
        if not os.path.exists(path):
            with self._lock:
                self.stats.misses += 1
            return None
        try:
            with open(path) as f:
                payload = json.load(f)
            if payload.get("version") != SCHEMA_VERSION:
                raise ValueError(f"schema version {payload.get('version')!r}")
            if self._key_from_payload(payload) != key:
                raise ValueError("stored key disagrees with filename")
            rec = record_from_json(payload["record"])
        except (OSError, ValueError, KeyError, TypeError) as e:
            # json.JSONDecodeError is a ValueError; a bad record dict raises
            # KeyError/TypeError in record_from_json. All are one re-trace.
            with self._lock:
                self.stats.corrupt += 1
                self.stats.misses += 1
            self._last_error = f"{type(e).__name__}: {e}"
            return None
        with self._lock:
            self.stats.hits += 1
        return rec

    def put(self, key: StoreKey, rec: ProfileRecord) -> str:
        """Atomically persist ``rec`` under ``key``; returns the file path."""
        path = self.path_for(key)
        payload = {"version": SCHEMA_VERSION,
                   "key": [key[0], int(key[1]), int(key[2])],
                   "record": record_to_json(rec)}
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, path)  # atomic on POSIX: readers see old or new
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        with self._lock:
            self.stats.writes += 1
        return path

    # -- inventory ----------------------------------------------------------
    def _files(self) -> List[str]:
        try:
            names = os.listdir(self.root)
        except OSError:
            return []
        return sorted(n for n in names if n.endswith(".json"))

    def __len__(self) -> int:
        return len(self._files())

    def keys(self) -> Iterator[StoreKey]:
        """Keys of every loadable record (corrupted files skipped)."""
        for name in self._files():
            try:
                with open(os.path.join(self.root, name)) as f:
                    payload = json.load(f)
                if payload.get("version") != SCHEMA_VERSION:
                    continue
                yield self._key_from_payload(payload)
            except (OSError, ValueError, KeyError, TypeError):
                continue

    def clear(self) -> int:
        """Delete every stored record; returns how many files were removed."""
        n = 0
        for name in self._files():
            try:
                os.unlink(os.path.join(self.root, name))
                n += 1
            except OSError:
                pass
        return n

    def info(self) -> Dict[str, int]:
        return {"store_entries": len(self), **self.stats.as_dict()}
