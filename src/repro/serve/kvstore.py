"""Durable key->value store engines behind one commutative merge contract.

``TraceStore`` (PR 2) and ``FeedbackStore`` (PR 3) grew the same
persistence discipline independently: a schema version stamped into
every record, corrupt/foreign data skipped (counted, never fatal), and
atomic writes so concurrent readers never observe a torn record. This
module owns that discipline in one place, split into two layers:

**The contract** (``KVStoreBase``) — everything the serving fabric is
built on, independent of physical layout:

  * **``merge`` / ``_merge_one``** — order-independent union: the
    subclass's ``_merge_raw`` must be commutative and idempotent, which
    makes any sequence of cross-host merges converge to one fixed
    point — the primitive the multi-host fabric (``repro.serve.cluster``)
    is built on. ``merge(other, keys=...)`` restricts the union to a
    key slice.
  * **``extract`` / ``split``** — key-predicate slice handoff: a shard
    can read (``extract``) or *move* (``split``) exactly one set of
    keys into another store, through the same ``_merge_raw`` contract,
    so live resharding inherits merge's convergence and corrupt-skip
    guarantees instead of reinventing a copy path.
  * **value hooks** — ``VALUE_FIELD`` names the payload slot,
    ``_check_raw`` validates a loaded value, ``_servable`` optionally
    deep-validates at compact time, ``_merge_raw`` unions two values,
    ``_note_corrupt``/``_on_merge``/``_on_split`` observe events.

**The engines** — two interchangeable physical layouts:

  * ``JsonFileStore`` — one JSON file per key, same-directory temp +
    ``os.replace`` writes. Simple, debuggable, and fine at 10^3 keys;
    at 10^6 keys the per-key open/stat/rename traffic dominates every
    cold start, merge, and reshard.
  * ``SegmentLogStore`` — an append-only segment log: records append
    to an active segment that seals at a size threshold, an in-memory
    ``key -> (segment, offset)`` index rebuilds on open by scanning
    segments newest-first, and compaction rewrites live records into
    fresh (higher-numbered) segments before atomically retiring the old
    ones. Corrupt-skip semantics move from per-file to per-record: each
    record carries a CRC32; a torn tail record (a crash mid-append) is
    truncated, never fatal, and a corrupt mid-segment record skips only
    itself (the scanner resyncs on the next record magic). Because
    compaction's fresh segments outnumber the old ones, a crash at ANY
    point leaves a directory that reopens to the same live contents —
    the newest-first scan dedupes.

Both engines serve the same contract, proven by the differential + crash
harness in ``tests/test_store_engines.py``. ``store_backend`` /
``STORE_BACKENDS`` resolve a backend by name (``REPRO_STORE_BACKEND``
env var selects the fleet-wide default).
"""

from __future__ import annotations

import json
import os
import struct
import tempfile
import threading
import time
import zlib
from typing import (Callable, Dict, Iterable, Iterator, List, Optional,
                    Tuple)

StoreKey = Tuple[str, int, int]  # (config fingerprint, batch, seq)

# ONE schema generation for every store subclass. Bumping this
# invalidates (skips, then compacts away) every on-disk record of every
# store at once — traces and feedback can never drift onto different
# version ladders again.
SCHEMA_VERSION = 1


def atomic_write_json(root: str, path: str, payload: Dict) -> None:
    """Same-directory temp file + ``os.replace``: concurrent readers see
    the old file or the new one, never a torn record. Shared by every
    durable store in ``repro.serve`` (traces, feedback) so the write
    discipline is fixed in exactly one place."""
    fd, tmp = tempfile.mkstemp(dir=root, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, path)  # atomic on POSIX
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


class KVStoreBase:
    """The store contract: value semantics + merge/extract/split, with
    the physical layout delegated to engine primitives.

    Engines implement ``get_raw`` / ``put_raw`` / ``_delete_key`` /
    ``iter_raw`` / ``__len__`` / ``clear`` / ``compact`` /
    ``_purge_unloadable``; everything the serving fabric calls
    (``merge``, ``extract``, ``split``, ``keys``, ``raw_snapshot``)
    is defined here once, so the two engines cannot drift apart.
    """

    FILE_PREFIX = ""        # e.g. "fb_" keeps feedback files greppable
    VALUE_FIELD = "value"   # payload slot the subclass's value lives in
    schema_version = SCHEMA_VERSION  # shared: see module docstring

    def __init__(self, root: str):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)
        # reentrant: read-modify-write paths hold it across loads that
        # may themselves take it to count a corrupt record
        self._lock = threading.RLock()

    # -- key mapping ---------------------------------------------------------
    def filename(self, key: StoreKey) -> str:
        """Canonical name for ``key`` — the JSON engine's physical file
        name, and BOTH engines' iteration sort key (so ``keys()`` order
        is byte-identical across backends)."""
        fp, batch, seq = key
        return f"{self.FILE_PREFIX}{fp}_b{int(batch)}_s{int(seq)}.json"

    @staticmethod
    def _key_from_payload(payload: Dict) -> StoreKey:
        fp, batch, seq = payload["key"]
        return (str(fp), int(batch), int(seq))

    # -- subclass hooks (value semantics) ------------------------------------
    def _check_raw(self, raw):
        """Validate a loaded value; raise to mark the record corrupt."""
        return raw

    def _servable(self, raw) -> None:
        """Deep validation at compact time (e.g. the record must load).

        A record that parses but whose value can never be served would
        be re-skipped by every read forever — compaction drops it."""

    def _merge_raw(self, mine: Optional[Dict], theirs: Dict):
        """Union two values -> ``(merged, n_new)``.

        MUST be commutative and idempotent: any merge order across any
        number of stores converges to the same contents."""
        raise NotImplementedError

    def _note_corrupt(self) -> None:
        """Called once per skipped record/file, on every read path."""

    def _on_merge(self, key: StoreKey, n_new: int) -> None:
        """Called after ``merge`` imported ``n_new`` units for ``key``."""

    def _on_split(self, n_removed: int) -> None:
        """Called after ``split`` removed ``n_removed`` keys."""

    # -- engine primitives ---------------------------------------------------
    def get_raw(self, key: StoreKey) -> Optional[Dict]:
        """Validated value for ``key``, or None (corrupt counted)."""
        raise NotImplementedError

    def put_raw(self, key: StoreKey, raw) -> str:
        """Atomically persist ``raw`` under ``key``; returns the path
        the record landed in."""
        raise NotImplementedError

    def _delete_key(self, key: StoreKey) -> bool:
        """Remove ``key`` from this store; True if something was removed."""
        raise NotImplementedError

    def iter_raw(self) -> Iterator[Tuple[StoreKey, Dict]]:
        """(key, value) for every loadable key, in ``filename`` order."""
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError

    def clear(self) -> int:
        """Delete every stored key; returns how many were removed."""
        raise NotImplementedError

    def compact(self, max_age_s: Optional[float] = None,
                max_entries: Optional[int] = None) -> Dict[str, int]:
        """Garbage-collect: stale schemas, TTL, entry cap (newest kept)."""
        raise NotImplementedError

    def _purge_unloadable(self) -> int:
        """Drop every record that can no longer be loaded; returns how
        many were dropped (subclass compactors count these)."""
        raise NotImplementedError

    def _reclaim(self) -> None:
        """Engine-specific space reclaim after a subclass pruned values
        in place (no-op for file-per-key; segment rewrite for the log)."""

    # -- inventory -----------------------------------------------------------
    def keys(self) -> Iterator[StoreKey]:
        """Keys of every loadable record (corrupted ones skipped)."""
        for key, _ in self.iter_raw():
            yield key

    def raw_snapshot(self) -> Dict[StoreKey, Dict]:
        """Canonical content view (equality checks across stores)."""
        return dict(self.iter_raw())

    # -- merge ---------------------------------------------------------------
    def merge(self, other: "KVStoreBase",
              keys: Optional[Iterable[StoreKey]] = None) -> int:
        """Union another store's contents into this one.

        Delegates the per-key union to ``_merge_raw``; because that hook
        is commutative and idempotent, ``a.merge(b); a.merge(c)`` yields
        the same contents in any order — the property federated
        multi-host aggregation relies on. ``keys`` restricts the union
        to a slice (unloadable members are skipped, like every read).
        Returns how many units (records / observations) were new to
        this store. (``split`` is the *move* counterpart.)
        """
        if keys is None:
            items: Iterable = other.iter_raw()
        else:
            items = ((k, other.get_raw(k)) for k in keys)
        imported = 0
        for key, theirs in items:
            if theirs is None:
                continue
            imported += self._merge_one(key, theirs)
        return imported

    def _merge_one(self, key: StoreKey, theirs) -> int:
        """Union one foreign value into this store (merge contract)."""
        with self._lock:
            mine = self.get_raw(key)
            merged, n_new = self._merge_raw(mine, theirs)
            if n_new:
                self.put_raw(key, merged)
                self._on_merge(key, n_new)
        return n_new

    # -- slice handoff (live resharding) ------------------------------------
    def extract(self, keys: Iterable[StoreKey]) -> Dict[StoreKey, Dict]:
        """Validated values for exactly ``keys`` (unloadable ones skipped).

        Read-only companion to ``split``: corrupt/foreign records in the
        slice are counted via ``_note_corrupt`` and omitted, never
        raised — the same skip semantics as every other read path.
        """
        out: Dict[StoreKey, Dict] = {}
        for key in keys:
            raw = self.get_raw(key)
            if raw is not None:
                out[key] = raw
        return out

    def split(self, keys: Iterable[StoreKey],
              into: "KVStoreBase") -> Dict[str, int]:
        """Move exactly ``keys`` from this store into ``into``.

        Each key's value is handed off through ``into``'s merge contract
        (so a destination that raced ahead and already holds a value for
        the key converges exactly as a cross-host merge would), then the
        local record is removed — the handoff is copy-then-delete, never
        a window with zero owners on disk. Keys whose local record is
        missing or unloadable are skipped (counted via
        ``_note_corrupt`` by the shared load path) and *left in place*:
        a corrupt record is dead to every reader anyway and ``compact``
        reclaims it; migration never raises because of one.

        Returns ``{"moved": keys removed here, "units": units new to
        the destination, "skipped": keys with no loadable record}``.

        The read→merge→delete sequence for each key holds ``_lock``: a
        concurrent ``put_raw``/``_merge_one`` landing a *newer* value in
        that window would otherwise be deleted unseen. Holding our lock
        while taking ``into``'s (inside ``_merge_one``) nests two store
        locks src→dest; that nesting is deadlock-free because resharding
        runs splits from a single thread (the one-reshard-at-a-time
        guard) and nothing splits in the opposite direction concurrently.
        """
        moved = units = skipped = 0
        for key in keys:
            with self._lock:
                raw = self.get_raw(key)
                if raw is None:
                    skipped += 1
                    continue
                units += into._merge_one(key, raw)
                if self._delete_key(key):
                    moved += 1
        if moved:
            self._on_split(moved)
        return {"moved": moved, "units": units, "skipped": skipped}


class JsonFileStore(KVStoreBase):
    """File-per-key engine: one JSON file per ``StoreKey``."""

    def path_for(self, key: StoreKey) -> str:
        return os.path.join(self.root, self.filename(key))

    def _files(self) -> List[str]:
        try:
            names = os.listdir(self.root)
        except OSError:
            return []
        return sorted(n for n in names
                      if n.startswith(self.FILE_PREFIX)
                      and n.endswith(".json"))

    def _scan_files(self) -> List[Tuple[str, float]]:
        """ONE ``scandir`` pass over the store: sorted ``(name, mtime)``.

        The mtimes ride along from the directory scan itself (cached on
        the ``DirEntry``), so compaction's TTL and entry-cap paths never
        issue a per-file ``os.stat`` — at 10^5 keys the old
        stat-per-file loop dominated every ``compact`` call.
        """
        out: List[Tuple[str, float]] = []
        try:
            with os.scandir(self.root) as it:
                for e in it:
                    name = e.name
                    if not (name.startswith(self.FILE_PREFIX)
                            and name.endswith(".json")):
                        continue
                    try:
                        out.append((name, e.stat().st_mtime))
                    except OSError:
                        pass  # vanished under us: nothing to do
        except OSError:
            return []
        return sorted(out)

    # -- load / save --------------------------------------------------------
    def _load_payload(self, path: str) -> Optional[Dict]:
        """Parsed, validated payload for one key file, or None.

        Skips (counting via ``_note_corrupt``) anything unparseable, on
        a foreign schema version, carrying a malformed value, or whose
        embedded key does not name the very file it was found under —
        the SAME semantics on every read path (get / keys / iter_raw /
        merge / compact), so a renamed or misplaced file is dead
        everywhere, not just to ``get``, and ``compact`` reclaims it.
        A file that simply does not exist is a clean miss, not corrupt.
        """
        try:
            with open(path) as f:
                payload = json.load(f)
            if payload.get("version") != self.schema_version:
                raise ValueError(f"schema version {payload.get('version')!r}")
            payload["key"] = self._key_from_payload(payload)
            if self.filename(payload["key"]) != os.path.basename(path):
                raise ValueError("stored key disagrees with filename")
            payload[self.VALUE_FIELD] = self._check_raw(
                payload.get(self.VALUE_FIELD))
            return payload
        except FileNotFoundError:
            return None
        except (OSError, ValueError, KeyError, TypeError):
            # json.JSONDecodeError is a ValueError; malformed values
            # raise KeyError/TypeError. All are one skipped file.
            self._note_corrupt()
            return None

    def get_raw(self, key: StoreKey) -> Optional[Dict]:
        payload = self._load_payload(self.path_for(key))
        return None if payload is None else payload[self.VALUE_FIELD]

    def put_raw(self, key: StoreKey, raw) -> str:
        """Atomically persist ``raw`` under ``key``; returns the path.

        Serialized under ``_lock`` so a write can never land inside
        another thread's read→merge→delete window (``split`` holds the
        lock across that whole sequence; an unserialized writer there
        would have its value silently unlinked during migration).
        """
        path = self.path_for(key)
        payload = {"version": self.schema_version,
                   "key": [key[0], int(key[1]), int(key[2])],
                   self.VALUE_FIELD: raw}
        with self._lock:
            atomic_write_json(self.root, path, payload)
        return path

    def _delete_key(self, key: StoreKey) -> bool:
        try:
            os.unlink(self.path_for(key))
            return True
        except OSError:
            return False  # a concurrent compact/clear got there first

    # -- inventory ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self._files())

    def iter_raw(self) -> Iterator[Tuple[StoreKey, Dict]]:
        for name in self._files():
            payload = self._load_payload(os.path.join(self.root, name))
            if payload is not None:
                yield payload["key"], payload[self.VALUE_FIELD]

    def clear(self) -> int:
        n = 0
        for name in self._files():
            try:
                os.unlink(os.path.join(self.root, name))
                n += 1
            except OSError:
                pass
        return n

    # -- compaction ---------------------------------------------------------
    def _purge_unloadable(self) -> int:
        """Unlink every file that no longer loads; returns the count."""
        n = 0
        for name in self._files():
            path = os.path.join(self.root, name)
            with self._lock:
                if self._load_payload(path) is None:
                    try:
                        os.unlink(path)
                        n += 1
                    except OSError:
                        pass
        return n

    def compact(self, max_age_s: Optional[float] = None,
                max_entries: Optional[int] = None) -> Dict[str, int]:
        """Garbage-collect the store: stale schemas, TTL, entry cap.

        Drops (1) files carrying a foreign schema generation, that no
        longer parse, or whose value fails ``_servable`` — they can
        never be served, only re-skipped on every read — (2) files
        older than ``max_age_s`` (by mtime; the TTL), and (3) the
        oldest files beyond ``max_entries`` (newest survive). Deletion
        is plain ``unlink``: a concurrent reader either opened the file
        first (and reads the old record) or misses — never a torn read.
        The whole call runs off ONE directory scan (``_scan_files``):
        the TTL/entry-cap paths reuse the scan's cached mtimes instead
        of re-``stat``-ing every file. Returns removal counts by reason
        plus the surviving count.
        """
        now = time.time()
        valid: List[tuple] = []  # (mtime, name) of loadable current-schema
        removed = {"stale_schema": 0, "expired": 0, "over_cap": 0}

        def _unlink(name: str, reason: str) -> None:
            try:
                os.unlink(os.path.join(self.root, name))
                removed[reason] += 1
            except OSError:
                pass  # a concurrent compact/clear got there first

        for name, mtime in self._scan_files():
            path = os.path.join(self.root, name)
            payload = self._load_payload(path)
            if payload is None:
                _unlink(name, "stale_schema")  # vanished files no-op
                continue
            try:
                self._servable(payload[self.VALUE_FIELD])
            except Exception:
                _unlink(name, "stale_schema")
                continue
            if max_age_s is not None and now - mtime > max_age_s:
                _unlink(name, "expired")
                continue
            valid.append((mtime, name))
        if max_entries is not None and len(valid) > max_entries:
            valid.sort()  # oldest first
            doomed, valid = valid[:len(valid) - max_entries], \
                valid[len(valid) - max_entries:]
            for _, name in doomed:
                _unlink(name, "over_cap")
        return {**removed, "removed": sum(removed.values()),
                "kept": len(valid)}


# -- segment log engine -------------------------------------------------------

_SEG_MAGIC = b"\xabKV1"                # record framing sentinel (resync point)
_SEG_HEADER = struct.Struct("<II")     # payload length, CRC32(payload)
_SEG_HDR_LEN = len(_SEG_MAGIC) + _SEG_HEADER.size


class SimulatedCrash(BaseException):
    """Raised by crash-point hooks in fault-injection tests.

    Deliberately NOT an ``Exception``: nothing in the store may catch
    it, so a test crash unwinds the exact instant the hook fires —
    exactly like a ``kill -9`` at that point in the protocol.
    """


class SegmentLogStore(KVStoreBase):
    """Append-only segment-log engine behind the same store contract.

    Physical layout: ``<PREFIX>seg-<NNNNNNNN>.log`` files. Records
    append to the highest-numbered (active) segment, which seals once it
    crosses ``segment_bytes`` — sealing just starts the next segment, so
    sealed segments are immutable. A record is::

        MAGIC(4) | payload_len(4, LE) | crc32(payload)(4, LE) | payload

    where the payload is ``<header JSON>\\n<value JSON>``: the header
    carries the shared schema version, the key, the append timestamp
    (the TTL axis; file mtime is meaningless in a log), and
    ``deleted: true`` for tombstones (which carry no value part).
    Deletion appends a tombstone, so the delete itself survives a crash
    and an older segment can never resurrect the key.

    Open rebuilds the in-memory ``key -> (segment, offset)`` index by
    scanning segments newest-first: the first (newest) record seen per
    key wins, tombstones kill the key, and within one segment the later
    record overrides the earlier. Corrupt-skip is per-record: a torn
    tail in the newest segment (crash mid-append) is truncated —
    unacknowledged by construction, never fatal — while a corrupt
    mid-segment record is skipped alone (the scanner resyncs on the next
    MAGIC) and counted via ``_note_corrupt``.

    ``compact`` rewrites live records into fresh segments numbered
    *above* the current active one, then retires (unlinks) every old
    segment. A crash anywhere in that window leaves old + new segments
    side by side; the newest-first scan dedupes, so reopening loses
    nothing and a retried compact converges.

    ``_crash_hook`` is the fault-injection seam: when set, it is called
    with a site name at every protocol step boundary (``append_mid``,
    ``append_durable``, ``seal``, ``compact_rewrite``,
    ``compact_retire``) and may raise :class:`SimulatedCrash` to
    simulate dying right there — the crash-point tests in
    ``tests/test_store_engines.py`` drive every site.
    """

    SEGMENT_BYTES = 4 << 20  # seal threshold for the active segment

    def __init__(self, root: str, segment_bytes: Optional[int] = None,
                 fsync: bool = False):
        super().__init__(root)
        self.segment_bytes = int(segment_bytes or self.SEGMENT_BYTES)
        self.fsync = bool(fsync)
        self._clock: Callable[[], float] = time.time  # test seam (TTL axis)
        self._crash_hook: Optional[Callable[[str], None]] = None
        # key -> (seg_name, seg_no, payload_offset, payload_len, ts);
        # built lazily so subclass __init__ (stats objects the corrupt
        # counter writes into) completes before the first scan runs
        self._index: Optional[Dict[StoreKey, tuple]] = None
        self._active_no = 0
        self._active_f = None
        self._active_size = 0
        # file-order record table of the ACTIVE segment, maintained
        # incrementally by the append path; persisted as the segment's
        # hint file the moment it seals
        self._active_records: List[tuple] = []
        self._dir_mtime = -2      # freshness fingerprint (_ensure_fresh)
        self.torn_truncated = 0   # tail records truncated at open
        self.sealed_segments = 0  # segments sealed by this instance

    # -- crash seam ----------------------------------------------------------
    def _fire_crash(self, site: str) -> None:
        hook = self._crash_hook
        if hook is not None:
            hook(site)

    # -- segment file mapping -----------------------------------------------
    def path_for(self, key: StoreKey) -> str:
        """Physical file currently holding ``key``'s record — the
        companion to ``JsonFileStore.path_for``, for layout
        introspection and fault injection. Here that is the containing
        *segment* (the active segment for unknown keys): mutating it
        touches every record in that segment, not just ``key``'s."""
        with self._lock:
            self._ensure_fresh()
            entry = self._index.get(key)
            no = self._active_no if entry is None else entry[1]
        return self._seg_path(no)

    def _seg_name(self, no: int) -> str:
        return f"{self.FILE_PREFIX}seg-{int(no):08d}.log"

    def _seg_path(self, no: int) -> str:
        return os.path.join(self.root, self._seg_name(no))

    def _seg_files(self) -> List[Tuple[int, str]]:
        """``(number, name)`` for every segment of THIS store's prefix,
        oldest first."""
        prefix = f"{self.FILE_PREFIX}seg-"
        out: List[Tuple[int, str]] = []
        try:
            names = os.listdir(self.root)
        except OSError:
            return []
        for name in names:
            if not (name.startswith(prefix) and name.endswith(".log")):
                continue
            digits = name[len(prefix):-len(".log")]
            if digits.isdigit():
                out.append((int(digits), name))
        return sorted(out)

    def _files(self) -> List[str]:
        """Segment file names, oldest first (layout introspection)."""
        return [name for _, name in self._seg_files()]

    # -- hint files (sealed-segment record tables) ---------------------------
    # When a segment seals (or compaction finishes writing one), its
    # record table is persisted next to it as ``<segment>.idx`` so a
    # later open loads the tiny table instead of re-scanning megabytes
    # of record bytes. Hints are pure caches: they are written
    # atomically, validated against the segment's exact byte size, and
    # ANY doubt (missing, unparseable, foreign version, size mismatch —
    # e.g. a writer this instance never saw) falls back to the full
    # CRC scan. Losing a hint can only cost time, never data.
    def _hint_path(self, no: int) -> str:
        return self._seg_path(no) + ".idx"

    def _write_hint(self, no: int, size: int, records: List[tuple]) -> None:
        payload = {"version": self.schema_version, "size": int(size),
                   "records": [[list(key), off, length, ts, bool(deleted)]
                               for key, off, length, ts, deleted in records]}
        try:
            atomic_write_json(self.root, self._hint_path(no), payload)
        except OSError:
            pass  # a missing hint only costs the next open a rescan

    def _load_hint(self, no: int) -> Optional[List[tuple]]:
        try:
            with open(self._hint_path(no)) as f:
                obj = json.load(f)
            if obj.get("version") != self.schema_version:
                return None
            if int(obj["size"]) != os.path.getsize(self._seg_path(no)):
                return None  # stale: someone wrote past the seal
            out = []
            for (fp, batch, seq), off, length, ts, deleted in obj["records"]:
                out.append(((str(fp), int(batch), int(seq)), int(off),
                            int(length), float(ts), bool(deleted)))
            return out
        except (OSError, ValueError, KeyError, TypeError):
            return None

    # -- record codec --------------------------------------------------------
    # A record payload is ``<header JSON>\n<value JSON>`` (no value part
    # for tombstones). The header carries only version/key/ts/deleted,
    # so the open-time index scan parses a few dozen bytes per record
    # regardless of value size — cold start stays O(keys), not O(bytes
    # of values). ``json.dumps`` emits no raw newlines (ensure_ascii
    # escapes everything), so the first ``\n`` always splits correctly.
    def _encode(self, key: StoreKey, raw=None, deleted: bool = False,
                ts: Optional[float] = None) -> Tuple[bytes, float]:
        when = float(self._clock() if ts is None else ts)
        header: Dict = {"version": self.schema_version,
                        "key": [key[0], int(key[1]), int(key[2])],
                        "ts": when}
        if deleted:
            header["deleted"] = True
            return json.dumps(header, sort_keys=True).encode("utf-8"), when
        return (json.dumps(header, sort_keys=True).encode("utf-8") + b"\n"
                + json.dumps(raw, sort_keys=True).encode("utf-8")), when

    @staticmethod
    def _split_payload(blob: bytes) -> Tuple[bytes, Optional[bytes]]:
        nl = blob.find(b"\n")
        if nl == -1:
            return blob, None
        return blob[:nl], blob[nl + 1:]

    def _decode_blob(self, blob: bytes, key: StoreKey):
        """Validated value from one record payload; raises when the
        record is foreign-versioned, malformed, a tombstone, or embeds a
        key that disagrees with the index — the same skip semantics the
        JSON engine applies per file, here applied per record."""
        head, value = self._split_payload(blob)
        obj = json.loads(head.decode("utf-8"))
        if obj.get("version") != self.schema_version:
            raise ValueError(f"schema version {obj.get('version')!r}")
        if self._key_from_payload(obj) != key:
            raise ValueError("stored key disagrees with index")
        if obj.get("deleted"):
            raise ValueError("tombstone record")
        if value is None:
            raise ValueError("record carries no value")
        return self._check_raw(json.loads(value.decode("utf-8")))

    # -- open / index rebuild ------------------------------------------------
    def _ensure_open(self) -> None:
        if self._index is None:
            self._open()

    def _ensure_fresh(self) -> None:
        """Rescan if ANOTHER process changed the directory under us.

        The index is process-local; the JSON engine picks up foreign
        writes for free by re-listing the directory on every read, so
        the contract requires the same here (the RPC frontend keeps
        local handles over directories its child processes write). Two
        ``stat`` calls — directory mtime catches created/retired
        segments, active-segment size catches appends (our own appends
        keep ``_active_size`` exact, so they never trigger a rescan) —
        instead of a full re-list per read.
        """
        if self._index is None:
            self._open()
            return
        try:
            dir_mtime = os.stat(self.root).st_mtime_ns
        except OSError:
            dir_mtime = -1
        if dir_mtime != self._dir_mtime:
            self._reopen()
            self._open()
            return
        try:
            size = os.path.getsize(self._seg_path(self._active_no))
        except OSError:
            size = -1
        if size != self._active_size:
            self._reopen()
            self._open()

    def _stat_dir(self) -> int:
        try:
            return os.stat(self.root).st_mtime_ns
        except OSError:
            return -1

    def _reopen(self) -> None:
        """Drop the index and handles; the next access rescans disk."""
        with self._lock:
            if self._active_f is not None:
                try:
                    self._active_f.close()
                except OSError:
                    pass
            self._active_f = None
            self._index = None

    def _open(self) -> None:
        """Rebuild the index by scanning segments newest-first."""
        index: Dict[StoreKey, tuple] = {}
        seen: set = set()
        files = self._seg_files()
        active_records: List[tuple] = []
        for no, name in reversed(files):
            path = os.path.join(self.root, name)
            newest = no == files[-1][0]
            if not newest:
                # sealed segments are immutable: a validated hint file
                # replaces the byte scan entirely
                records = self._load_hint(no)
                if records is None:
                    records, _, _ = self._scan_segment(path)
            else:
                records, good_end, torn = self._scan_segment(path)
                if torn:
                    # only the segment that was active at the crash can
                    # carry a legitimately torn (unacknowledged) tail
                    try:
                        with open(path, "r+b") as f:
                            f.truncate(good_end)
                        self.torn_truncated += 1
                    except OSError:
                        pass
                active_records = list(records)
            # within one segment the LAST record per key wins...
            last: Dict[StoreKey, tuple] = {}
            for rec in records:
                last[rec[0]] = rec
            # ...and across segments the NEWEST segment wins; a
            # tombstone anywhere newer kills every older record
            for key, (_, off, length, ts, deleted) in last.items():
                if key in seen:
                    continue
                seen.add(key)
                if not deleted:
                    index[key] = (name, no, off, length, ts)
        self._index = index
        if files:
            self._active_no = files[-1][0]
        else:
            self._active_no = 1
        path = self._seg_path(self._active_no)
        self._active_f = open(path, "ab")
        self._active_size = os.path.getsize(path)
        self._active_records = active_records
        self._dir_mtime = self._stat_dir()

    def _scan_segment(self, path: str):
        """Walk one segment's records: ``(records, good_end, torn)``.

        ``records`` is ``(key, payload_off, payload_len, ts, deleted)``
        in file order; ``good_end`` is the byte offset after the last
        structurally complete record (the truncation point for a torn
        tail); ``torn`` reports an incomplete record at EOF. A corrupt
        record *followed by more data* skips only itself: the scanner
        resyncs on the next MAGIC and counts it via ``_note_corrupt``.
        """
        try:
            with open(path, "rb") as f:
                data = f.read()
        except OSError:
            return [], 0, False
        records: List[tuple] = []
        size = len(data)
        mv = memoryview(data)  # CRC/header reads without per-record copies
        pos = good_end = 0
        torn = False
        while pos < size:
            if size - pos < _SEG_HDR_LEN:
                torn = True  # partial header at EOF
                break
            if data[pos:pos + 4] != _SEG_MAGIC:
                nxt = data.find(_SEG_MAGIC, pos + 1)
                if nxt == -1:
                    torn = True  # trailing garbage, no later record
                    break
                self._note_corrupt()  # mid-segment junk: skip only it
                pos = nxt
                continue
            length, crc = _SEG_HEADER.unpack_from(data, pos + 4)
            start = pos + _SEG_HDR_LEN
            end = start + length
            if end > size:
                nxt = data.find(_SEG_MAGIC, pos + 4)
                if nxt == -1:
                    torn = True  # record ran off EOF: torn tail
                    break
                self._note_corrupt()  # bad length mid-segment: resync
                pos = nxt
                continue
            if zlib.crc32(mv[start:end]) != crc:
                self._note_corrupt()
                nxt = data.find(_SEG_MAGIC, pos + 4)
                if nxt == -1:
                    break  # corrupt final record: dead, but acked bytes
                pos = nxt  # stay — compact reclaims them
                continue
            nl = data.find(b"\n", start, end)
            head_end = end if nl == -1 else nl
            try:
                # header-only parse: scan cost is independent of value size
                obj = json.loads(data[start:head_end].decode("utf-8"))
                if obj.get("version") != self.schema_version:
                    raise ValueError("foreign schema version")
                key = self._key_from_payload(obj)
                ts = float(obj.get("ts", 0.0))
                deleted = bool(obj.get("deleted"))
            except (ValueError, KeyError, TypeError, UnicodeDecodeError):
                self._note_corrupt()
                pos = good_end = end  # framing intact: skip one record
                continue
            records.append((key, start, length, ts, deleted))
            pos = good_end = end
        return records, good_end, torn

    # -- append path ---------------------------------------------------------
    def _append_blob(self, blob: bytes) -> int:
        """Append one framed record; returns the payload offset.

        The write is split in two flushes around the ``append_mid``
        crash site so a simulated crash leaves a genuinely torn record
        on disk — exactly what a real mid-``write`` kill produces.
        """
        rec = (_SEG_MAGIC + _SEG_HEADER.pack(len(blob), zlib.crc32(blob))
               + blob)
        f = self._active_f
        offset = self._active_size
        half = max(1, len(rec) // 2)
        f.write(rec[:half])
        f.flush()
        self._fire_crash("append_mid")
        f.write(rec[half:])
        f.flush()
        if self.fsync:
            os.fsync(f.fileno())
        self._active_size += len(rec)
        return offset + _SEG_HDR_LEN

    def _seal(self) -> None:
        """Seal the active segment: start the next one.

        Sealed segments are immutable from here on; the ``seal`` crash
        site sits after the new segment exists on disk but before the
        writer state swaps to it — a crash there reopens cleanly (the
        empty newest segment scans as empty and the old active keeps
        its records).
        """
        f = self._active_f
        f.flush()
        if self.fsync:
            os.fsync(f.fileno())
        # the segment is now immutable: persist its record table so the
        # next open loads the hint instead of re-scanning the bytes
        self._write_hint(self._active_no, self._active_size,
                         self._active_records)
        nxt_no = self._active_no + 1
        nxt_f = open(self._seg_path(nxt_no), "ab")
        try:
            self._fire_crash("seal")
        except BaseException:
            nxt_f.close()
            raise
        f.close()
        self._active_f, self._active_no = nxt_f, nxt_no
        self._active_size = 0
        self._active_records = []
        self.sealed_segments += 1
        self._dir_mtime = self._stat_dir()

    def put_raw(self, key: StoreKey, raw) -> str:
        with self._lock:
            self._ensure_fresh()
            blob, ts = self._encode(key, raw=raw)
            off = self._append_blob(blob)
            # record is durable; index not yet updated (= not yet acked)
            self._fire_crash("append_durable")
            self._index[key] = (self._seg_name(self._active_no),
                                self._active_no, off, len(blob), ts)
            self._active_records.append((key, off, len(blob), ts, False))
            path = self._seg_path(self._active_no)
            if self._active_size >= self.segment_bytes:
                self._seal()
        return path

    def _delete_key(self, key: StoreKey) -> bool:
        with self._lock:
            self._ensure_fresh()
            if key not in self._index:
                return False
            blob, ts = self._encode(key, deleted=True)
            off = self._append_blob(blob)
            self._fire_crash("append_durable")
            del self._index[key]
            self._active_records.append((key, off, len(blob), ts, True))
            if self._active_size >= self.segment_bytes:
                self._seal()
        return True

    # -- read path -----------------------------------------------------------
    def _read_blob(self, entry: tuple) -> Optional[bytes]:
        name, _no, off, length, _ts = entry
        try:
            with open(os.path.join(self.root, name), "rb") as f:
                f.seek(off)
                blob = f.read(length)
        except OSError:
            return None
        return blob if len(blob) == length else None

    def get_raw(self, key: StoreKey) -> Optional[Dict]:
        with self._lock:
            self._ensure_fresh()
            for attempt in (0, 1):
                entry = self._index.get(key)
                if entry is None:
                    return None
                blob = self._read_blob(entry)
                if blob is None:
                    if attempt == 0:
                        # segment retired under us (another instance's
                        # compaction): rescan once, then give up
                        self._reopen()
                        self._ensure_open()
                        continue
                    return None
                try:
                    return self._decode_blob(blob, key)
                except (ValueError, KeyError, TypeError,
                        UnicodeDecodeError):
                    self._note_corrupt()
                    self._index.pop(key, None)  # dead: compact reclaims
                    return None
        return None

    def iter_raw(self) -> Iterator[Tuple[StoreKey, Dict]]:
        """(key, value) in ``filename`` order — byte-identical iteration
        order to the JSON engine. Each segment's bytes are read ONCE
        (the single-scan discipline), not once per record."""
        with self._lock:
            self._ensure_fresh()
            items = sorted(self._index.items(),
                           key=lambda kv: self.filename(kv[0]))
        cache: Dict[str, bytes] = {}
        for key, entry in items:
            name, _no, off, length, _ts = entry
            data = cache.get(name)
            if data is None:
                try:
                    with open(os.path.join(self.root, name), "rb") as f:
                        data = f.read()
                except OSError:
                    data = b""
                cache[name] = data
            blob = data[off:off + length]
            if len(blob) != length:
                raw = self.get_raw(key)  # retired mid-iteration: re-resolve
            else:
                try:
                    raw = self._decode_blob(blob, key)
                except (ValueError, KeyError, TypeError,
                        UnicodeDecodeError):
                    self._note_corrupt()
                    raw = None
            if raw is not None:
                yield key, raw

    # -- inventory -----------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            self._ensure_fresh()
            return len(self._index)

    def clear(self) -> int:
        with self._lock:
            self._ensure_fresh()
            n = len(self._index)
            if self._active_f is not None:
                try:
                    self._active_f.close()
                except OSError:
                    pass
            for no, name in self._seg_files():
                for victim in (os.path.join(self.root, name),
                               self._hint_path(no)):
                    try:
                        os.unlink(victim)
                    except OSError:
                        pass
            self._index = {}
            self._active_no += 1  # fresh segment: never reuse a number
            path = self._seg_path(self._active_no)
            self._active_f = open(path, "ab")
            self._active_size = 0
            self._active_records = []
            self._dir_mtime = self._stat_dir()
        return n

    # -- compaction ----------------------------------------------------------
    def _purge_unloadable(self) -> int:
        """Drop every indexed record that no longer validates (CRC,
        schema, value check); returns the count. Physical reclaim
        happens at the next rewrite (``_reclaim``/``compact``)."""
        with self._lock:
            self._ensure_open()
            n = 0
            for key in list(self._index):
                if self.get_raw(key) is None:  # drops + counts corrupt
                    n += 1
            return n

    def _reclaim(self) -> None:
        # subclasses override compact() with value-level pruning (e.g.
        # FeedbackStore); name the engine's compactor explicitly so the
        # rewrite that reclaims dead bytes still runs
        SegmentLogStore.compact(self)

    def compact(self, max_age_s: Optional[float] = None,
                max_entries: Optional[int] = None) -> Dict[str, int]:
        """Rewrite live records into fresh segments, retire the old.

        Same policy surface as the JSON engine: drops records that no
        longer validate or fail ``_servable`` (``stale_schema``),
        records older than ``max_age_s`` by their append timestamp
        (``expired``), and the oldest beyond ``max_entries`` — newest
        always survive (``over_cap``). Survivors are re-encoded with
        their ORIGINAL timestamps (age survives compaction) into
        segments numbered above the active one, then every pre-existing
        segment is unlinked. The ``compact_rewrite`` /
        ``compact_retire`` crash sites bracket the rewrite: a crash
        before retire leaves old + new segments side by side, and the
        newest-first open scan dedupes — nothing live is ever lost.
        Each old segment's bytes are read once (no per-record opens).
        """
        with self._lock:
            self._ensure_fresh()
            now = self._clock()
            removed = {"stale_schema": 0, "expired": 0, "over_cap": 0}
            old_files = self._seg_files()
            cache: Dict[str, bytes] = {}
            live: List[tuple] = []  # (ts, seg_no, off, key, raw)
            for key, entry in sorted(self._index.items(),
                                     key=lambda kv: self.filename(kv[0])):
                name, no, off, length, ts = entry
                data = cache.get(name)
                if data is None:
                    try:
                        with open(os.path.join(self.root, name), "rb") as f:
                            data = f.read()
                    except OSError:
                        data = b""
                    cache[name] = data
                blob = data[off:off + length]
                try:
                    if len(blob) != length:
                        raise ValueError("record out of bounds")
                    raw = self._decode_blob(blob, key)
                    self._servable(raw)
                except Exception:
                    self._note_corrupt()
                    removed["stale_schema"] += 1
                    continue
                if max_age_s is not None and now - ts > max_age_s:
                    removed["expired"] += 1
                    continue
                live.append((ts, no, off, key, raw))
            if max_entries is not None and len(live) > max_entries:
                live.sort()  # append-time order; offsets break ts ties
                removed["over_cap"] += len(live) - max_entries
                live = live[len(live) - max_entries:]
            # rewrite survivors into fresh segments ABOVE the active one
            old_active = self._active_f
            no = self._active_no + 1
            f = open(self._seg_path(no), "ab")
            size = 0
            new_index: Dict[StoreKey, tuple] = {}
            seg_records: List[tuple] = []
            for ts, _old_no, _off, key, raw in sorted(
                    live, key=lambda e: self.filename(e[3])):
                blob, _ = self._encode(key, raw=raw, ts=ts)
                rec = (_SEG_MAGIC
                       + _SEG_HEADER.pack(len(blob), zlib.crc32(blob))
                       + blob)
                f.write(rec)
                new_index[key] = (self._seg_name(no), no,
                                  size + _SEG_HDR_LEN, len(blob), ts)
                seg_records.append((key, size + _SEG_HDR_LEN, len(blob),
                                    ts, False))
                size += len(rec)
                if size >= self.segment_bytes:
                    f.flush()
                    if self.fsync:
                        os.fsync(f.fileno())
                    f.close()
                    # this rewrite segment is sealed: hint it like any
                    # other immutable segment
                    self._write_hint(no, size, seg_records)
                    self._fire_crash("compact_rewrite")
                    no += 1
                    f = open(self._seg_path(no), "ab")
                    size = 0
                    seg_records = []
            f.flush()
            if self.fsync:
                os.fsync(f.fileno())
            # new segments are durable; old ones still on disk — a crash
            # here reopens to the same live contents (newest wins)
            try:
                self._fire_crash("compact_retire")
            except BaseException:
                f.close()
                raise
            if old_active is not None:
                try:
                    old_active.close()
                except OSError:
                    pass
            for old_no, name in old_files:
                for victim in (os.path.join(self.root, name),
                               self._hint_path(old_no)):
                    try:
                        os.unlink(victim)
                    except OSError:
                        pass
            self._index = new_index
            self._active_f, self._active_no, self._active_size = f, no, size
            self._active_records = seg_records
            self._dir_mtime = self._stat_dir()
            return {**removed, "removed": sum(removed.values()),
                    "kept": len(live)}


# -- backend registry ---------------------------------------------------------

STORE_BACKENDS = {"json": JsonFileStore, "segment": SegmentLogStore}


def store_backend(name: Optional[str] = None) -> str:
    """Resolve a backend name: explicit arg > ``REPRO_STORE_BACKEND``
    env var > ``"json"`` (the historical layout). Raises on unknown
    names so a typo'd env var fails loudly at store construction, not
    silently at first read."""
    resolved = (name or os.environ.get("REPRO_STORE_BACKEND") or
                "json").strip().lower()
    if resolved not in STORE_BACKENDS:
        raise ValueError(f"unknown store backend {resolved!r} "
                         f"(expected one of {sorted(STORE_BACKENDS)})")
    return resolved
