"""Shared JSON-file store base for every durable map in ``repro.serve``.

``TraceStore`` (PR 2) and ``FeedbackStore`` (PR 3) grew the same
persistence discipline independently: one JSON file per
``(config fingerprint, batch, seq)`` key, a schema version stamped into
every payload, corrupt/foreign files skipped (counted, never fatal), and
same-directory temp + ``os.replace`` writes so concurrent readers never
observe a torn record. They also diverged in the details — separate
schema-version constants, different key-vs-filename checks, different
corrupt-counting paths — exactly the drift a shared base exists to stop.

``JsonFileStore`` owns the whole discipline in one place:

  * **key <-> file mapping** — ``<PREFIX><fp>_b<batch>_s<seq>.json``.
  * **atomic writes** — ``atomic_write_json`` (temp + ``os.replace``).
  * **versioned schema** — ONE ``SCHEMA_VERSION`` shared by every
    subclass; loads that carry a foreign version, fail to parse, echo a
    key that disagrees with their filename, or fail the subclass's
    value check are skipped and counted via ``_note_corrupt`` — the
    same semantics on every read path (get / keys / compact / merge).
  * **``compact``** — stale-schema GC + mtime TTL + entry cap (newest
    files survive); subclasses with intra-file structure (feedback
    observations) override with finer-grained pruning.
  * **``merge``** — order-independent union: the subclass's
    ``_merge_raw`` must be commutative and idempotent, which makes any
    sequence of cross-host merges converge to one fixed point — the
    primitive the multi-host fabric (``repro.serve.cluster``) is built
    on.
  * **``extract`` / ``split``** — key-predicate slice handoff: a shard
    can read (``extract``) or *move* (``split``) exactly one set of
    keys into another store, through the same ``_merge_raw`` contract,
    so live resharding inherits merge's convergence and corrupt-skip
    guarantees instead of reinventing a copy path.

Subclasses define the value: ``VALUE_FIELD`` names the payload slot
(kept distinct per store so pre-refactor files still load),
``_check_raw`` validates a loaded value, ``_servable`` optionally
deep-validates at compact time, and ``_merge_raw`` unions two values.
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

StoreKey = Tuple[str, int, int]  # (config fingerprint, batch, seq)

# ONE schema generation for every JsonFileStore subclass. Bumping this
# invalidates (skips, then compacts away) every on-disk record of every
# store at once — traces and feedback can never drift onto different
# version ladders again.
SCHEMA_VERSION = 1


def atomic_write_json(root: str, path: str, payload: Dict) -> None:
    """Same-directory temp file + ``os.replace``: concurrent readers see
    the old file or the new one, never a torn record. Shared by every
    durable store in ``repro.serve`` (traces, feedback) so the write
    discipline is fixed in exactly one place."""
    fd, tmp = tempfile.mkstemp(dir=root, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, path)  # atomic on POSIX
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


class JsonFileStore:
    """Durable ``StoreKey -> value`` map: one JSON file per key."""

    FILE_PREFIX = ""        # e.g. "fb_" keeps feedback files greppable
    VALUE_FIELD = "value"   # payload slot the subclass's value lives in
    schema_version = SCHEMA_VERSION  # shared: see module docstring

    def __init__(self, root: str):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)
        # reentrant: read-modify-write paths hold it across loads that
        # may themselves take it to count a corrupt file
        self._lock = threading.RLock()

    # -- key/file mapping ---------------------------------------------------
    def filename(self, key: StoreKey) -> str:
        fp, batch, seq = key
        return f"{self.FILE_PREFIX}{fp}_b{int(batch)}_s{int(seq)}.json"

    def path_for(self, key: StoreKey) -> str:
        return os.path.join(self.root, self.filename(key))

    @staticmethod
    def _key_from_payload(payload: Dict) -> StoreKey:
        fp, batch, seq = payload["key"]
        return (str(fp), int(batch), int(seq))

    def _files(self) -> List[str]:
        try:
            names = os.listdir(self.root)
        except OSError:
            return []
        return sorted(n for n in names
                      if n.startswith(self.FILE_PREFIX)
                      and n.endswith(".json"))

    # -- subclass hooks -----------------------------------------------------
    def _check_raw(self, raw):
        """Validate a loaded value; raise to mark the file corrupt."""
        return raw

    def _servable(self, raw) -> None:
        """Deep validation at compact time (e.g. the record must load).

        A file that parses but whose value can never be served would be
        re-skipped by every read forever — compaction drops it."""

    def _merge_raw(self, mine: Optional[Dict], theirs: Dict):
        """Union two values -> ``(merged, n_new)``.

        MUST be commutative and idempotent: any merge order across any
        number of stores converges to the same contents."""
        raise NotImplementedError

    def _note_corrupt(self) -> None:
        """Called once per skipped file/value, on every read path."""

    def _on_merge(self, key: StoreKey, n_new: int) -> None:
        """Called after ``merge`` imported ``n_new`` units for ``key``."""

    def _on_split(self, n_removed: int) -> None:
        """Called after ``split`` removed ``n_removed`` key files."""

    # -- load / save --------------------------------------------------------
    def _load_payload(self, path: str) -> Optional[Dict]:
        """Parsed, validated payload for one key file, or None.

        Skips (counting via ``_note_corrupt``) anything unparseable, on
        a foreign schema version, carrying a malformed value, or whose
        embedded key does not name the very file it was found under —
        the SAME semantics on every read path (get / keys / iter_raw /
        merge / compact), so a renamed or misplaced file is dead
        everywhere, not just to ``get``, and ``compact`` reclaims it.
        """
        if not os.path.exists(path):
            return None
        try:
            with open(path) as f:
                payload = json.load(f)
            if payload.get("version") != self.schema_version:
                raise ValueError(f"schema version {payload.get('version')!r}")
            payload["key"] = self._key_from_payload(payload)
            if self.filename(payload["key"]) != os.path.basename(path):
                raise ValueError("stored key disagrees with filename")
            payload[self.VALUE_FIELD] = self._check_raw(
                payload.get(self.VALUE_FIELD))
            return payload
        except (OSError, ValueError, KeyError, TypeError):
            # json.JSONDecodeError is a ValueError; malformed values
            # raise KeyError/TypeError. All are one skipped file.
            self._note_corrupt()
            return None

    def get_raw(self, key: StoreKey) -> Optional[Dict]:
        """Validated value for ``key``, or None (corrupt counted)."""
        payload = self._load_payload(self.path_for(key))
        return None if payload is None else payload[self.VALUE_FIELD]

    def put_raw(self, key: StoreKey, raw) -> str:
        """Atomically persist ``raw`` under ``key``; returns the path.

        Serialized under ``_lock`` so a write can never land inside
        another thread's read→merge→delete window (``split`` holds the
        lock across that whole sequence; an unserialized writer there
        would have its value silently unlinked during migration).
        """
        path = self.path_for(key)
        payload = {"version": self.schema_version,
                   "key": [key[0], int(key[1]), int(key[2])],
                   self.VALUE_FIELD: raw}
        with self._lock:
            atomic_write_json(self.root, path, payload)
        return path

    # -- inventory ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self._files())

    def keys(self) -> Iterator[StoreKey]:
        """Keys of every loadable file (corrupted files skipped)."""
        for key, _ in self.iter_raw():
            yield key

    def iter_raw(self) -> Iterator[Tuple[StoreKey, Dict]]:
        """(key, value) for every loadable key file."""
        for name in self._files():
            payload = self._load_payload(os.path.join(self.root, name))
            if payload is not None:
                yield payload["key"], payload[self.VALUE_FIELD]

    def raw_snapshot(self) -> Dict[StoreKey, Dict]:
        """Canonical content view (equality checks across stores)."""
        return dict(self.iter_raw())

    def clear(self) -> int:
        """Delete every stored file; returns how many were removed."""
        n = 0
        for name in self._files():
            try:
                os.unlink(os.path.join(self.root, name))
                n += 1
            except OSError:
                pass
        return n

    # -- merge --------------------------------------------------------------
    def merge(self, other: "JsonFileStore") -> int:
        """Union another store's contents into this one.

        Delegates the per-key union to ``_merge_raw``; because that hook
        is commutative and idempotent, ``a.merge(b); a.merge(c)`` yields
        the same contents in any order — the property federated
        multi-host aggregation relies on. Returns how many units
        (records / observations) were new to this store. (``split`` is
        the slice-restricted counterpart: it loads exactly its keys via
        ``get_raw`` instead of scanning the whole directory.)
        """
        imported = 0
        for key, theirs in other.iter_raw():
            imported += self._merge_one(key, theirs)
        return imported

    def _merge_one(self, key: StoreKey, theirs) -> int:
        """Union one foreign value into this store (merge contract)."""
        with self._lock:
            mine = self.get_raw(key)
            merged, n_new = self._merge_raw(mine, theirs)
            if n_new:
                self.put_raw(key, merged)
                self._on_merge(key, n_new)
        return n_new

    # -- slice handoff (live resharding) ------------------------------------
    def extract(self, keys: Iterable[StoreKey]) -> Dict[StoreKey, Dict]:
        """Validated values for exactly ``keys`` (unloadable ones skipped).

        Read-only companion to ``split``: corrupt/foreign files in the
        slice are counted via ``_note_corrupt`` and omitted, never
        raised — the same skip semantics as every other read path.
        """
        out: Dict[StoreKey, Dict] = {}
        for key in keys:
            raw = self.get_raw(key)
            if raw is not None:
                out[key] = raw
        return out

    def split(self, keys: Iterable[StoreKey],
              into: "JsonFileStore") -> Dict[str, int]:
        """Move exactly ``keys`` from this store into ``into``.

        Each key's value is handed off through ``into``'s merge contract
        (so a destination that raced ahead and already holds a value for
        the key converges exactly as a cross-host merge would), then the
        local file is removed — the handoff is copy-then-delete, never a
        window with zero owners on disk. Keys whose local file is
        missing or unloadable are skipped (counted via
        ``_note_corrupt`` by the shared load path) and *left in place*:
        a corrupt file is dead to every reader anyway and ``compact``
        reclaims it; migration never raises because of one.

        Returns ``{"moved": files removed here, "units": units new to
        the destination, "skipped": keys with no loadable file}``.

        The read→merge→unlink sequence for each key holds ``_lock``: a
        concurrent ``put_raw``/``_merge_one`` landing a *newer* value in
        that window would otherwise be deleted unseen. Holding our lock
        while taking ``into``'s (inside ``_merge_one``) nests two store
        locks src→dest; that nesting is deadlock-free because resharding
        runs splits from a single thread (the one-reshard-at-a-time
        guard) and nothing splits in the opposite direction concurrently.
        """
        moved = units = skipped = 0
        for key in keys:
            with self._lock:
                raw = self.get_raw(key)
                if raw is None:
                    skipped += 1
                    continue
                units += into._merge_one(key, raw)
                try:
                    os.unlink(self.path_for(key))
                    moved += 1
                except OSError:
                    pass  # a concurrent compact/clear got there first
        if moved:
            self._on_split(moved)
        return {"moved": moved, "units": units, "skipped": skipped}

    # -- compaction ---------------------------------------------------------
    def compact(self, max_age_s: Optional[float] = None,
                max_entries: Optional[int] = None) -> Dict[str, int]:
        """Garbage-collect the store: stale schemas, TTL, entry cap.

        Drops (1) files carrying a foreign schema generation, that no
        longer parse, or whose value fails ``_servable`` — they can
        never be served, only re-skipped on every read — (2) files
        older than ``max_age_s`` (by mtime; the TTL), and (3) the
        oldest files beyond ``max_entries`` (newest survive). Deletion
        is plain ``unlink``: a concurrent reader either opened the file
        first (and reads the old record) or misses — never a torn read.
        Returns removal counts by reason plus the surviving count.
        """
        now = time.time()
        valid: List[tuple] = []  # (mtime, name) of loadable current-schema
        removed = {"stale_schema": 0, "expired": 0, "over_cap": 0}

        def _unlink(name: str, reason: str) -> None:
            try:
                os.unlink(os.path.join(self.root, name))
                removed[reason] += 1
            except OSError:
                pass  # a concurrent compact/clear got there first

        for name in self._files():
            path = os.path.join(self.root, name)
            try:
                mtime = os.path.getmtime(path)
            except OSError:
                continue  # vanished under us: nothing to do
            payload = self._load_payload(path)
            if payload is None:
                _unlink(name, "stale_schema")
                continue
            try:
                self._servable(payload[self.VALUE_FIELD])
            except Exception:
                _unlink(name, "stale_schema")
                continue
            if max_age_s is not None and now - mtime > max_age_s:
                _unlink(name, "expired")
                continue
            valid.append((mtime, name))
        if max_entries is not None and len(valid) > max_entries:
            valid.sort()  # oldest first
            doomed, valid = valid[:len(valid) - max_entries], \
                valid[len(valid) - max_entries:]
            for _, name in doomed:
                _unlink(name, "over_cap")
        return {**removed, "removed": sum(removed.values()),
                "kept": len(valid)}
