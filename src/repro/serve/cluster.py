"""Multi-host serving fabric: sharded gateway replicas behind one frontend.

A single ``AbacusServer`` is one worker loop, one trace-cache budget,
and one feedback stream — the datacenter setting the paper targets
(admission control for whole fleets, §4.3) needs N of them. This module
is the fleet seam:

  * ``HashRing`` — consistent hashing over replica names. Points are
    SHA-256 derived, so routing is a pure function of the key string:
    stable across processes, hash seeds (``PYTHONHASHSEED``), and
    restarts — the property that makes a replica's trace-store slice
    *own* its keys.
  * ``GatewayReplica`` — an ``AbacusServer`` over its own
    ``PredictionService`` slice: a fingerprint-sharded ``TraceStore``
    directory, its own ``FeedbackStore``, its own micro-batch worker.
    Every estimate it resolves is stamped with ``replica`` so
    (tick, generation) pairs are attributable fleet-wide.
  * ``ClusterFrontend`` — routes each query to the replica that owns
    its config fingerprint (computed ONCE here and forwarded via
    ``Query.fp``), fans a wave of submissions out so every replica's
    worker ticks concurrently on its partition, aggregates ``stats()``
    fleet-wide, and broadcasts model generations. Membership is LIVE:
    ``add_replica``/``remove_replica``/``resize`` run a drain ->
    migrate -> cutover protocol that quiesces only the replicas losing
    keyspace (``HashRing.diff``), hands their ``TraceStore``/
    ``FeedbackStore`` slices to the new owners through the commutative
    ``JsonFileStore.split``/``merge`` contract, swaps the ring
    atomically, and replays queries that raced the cutover — a fleet
    grows and shrinks with traffic without losing a trace, an
    observation, or an in-flight Future.
  * ``GenerationPublisher`` — the sink a central ``OnlineRefitter``
    publishes through: every replica receives each ``ModelGeneration``
    and applies it at its own tick boundary (``AbacusServer``'s
    between-ticks guarantee), so no replica ever serves two
    generations within one micro-batch. The refitter itself consumes a
    *federated* merge of all per-replica ``FeedbackStore``s
    (``OnlineRefitter(sources=...)``) and resolves feedback keys
    against the owning shard's traces (``ShardedTraces``).

Sharding by config fingerprint (not by full key) keeps every shape of
one model on one replica, so that replica's trace/prediction caches see
all the locality. The fleet-level win on one box is aggregate cache
capacity — each replica only holds 1/N of the working set — and the
seam is transport-agnostic: replicas are in-process here, but nothing
in the frontend assumes it (the later RPC step swaps the replica list
for remote stubs).
"""

from __future__ import annotations

import bisect
import dataclasses
import hashlib
import os
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs import events
from repro.obs.metrics import (CounterDict, MetricsRegistry, merge_snapshots,
                               render_prometheus)
from repro.obs.tracing import SpanSink, make_span, new_context
from repro.serve.feedback_store import FeedbackStore, make_feedback_store
from repro.serve.prediction_service import (PredictionService, Query,
                                            config_fingerprint, trace_query)
from repro.serve.refit import OnlineRefitter
from repro.serve.server import (AbacusServer, DeadlineExceeded, QuotaExceeded,
                                ServerStats, _results_by_deadline)
from repro.serve.trace_store import TraceStore, make_trace_store


class ReplicaUnavailable(RuntimeError):
    """A replica cannot be reached: dead connection, timed-out call, or
    a send that failed mid-flight. Retryable — the frontend re-routes
    the query to the next ring owner (the query is idempotent)."""


class ReplicaNotRunning(RuntimeError):
    """The remote gateway rejected the call because its worker is not
    running (drain window of a reshard, or stopped). Retryable through
    the post-cutover ring."""


def _first_wins(fut: Future, result=None, error=None) -> None:
    """Resolve ``fut`` if nobody beat us to it (hedged duplicates race)."""
    try:
        if error is not None:
            fut.set_exception(error)
        else:
            fut.set_result(result)
    except Exception:
        pass  # already resolved (or cancelled) by the other attempt


def _relay(src: Future, out: Future) -> None:
    """Propagate one attempt's outcome into the caller's Future."""
    if src.cancelled() or out.done():
        return
    err = src.exception()
    if err is None:
        _first_wins(out, result=src.result())
    else:
        _first_wins(out, error=err)


class HashRing:
    """Consistent-hash ring over replica names.

    ``vnodes`` virtual points per replica smooth the key distribution;
    all points are SHA-256 derived so ``route`` is a pure function of
    its argument — two processes (or two hash seeds) always agree on
    which replica owns a fingerprint. Adding or removing one replica
    moves only ~1/N of the keyspace (the consistent-hashing property
    the later resharding step relies on).
    """

    SPAN = 1 << 64  # hash space: first 8 bytes of SHA-256

    def __init__(self, names: Sequence[str], vnodes: int = 64):
        if not names:
            raise ValueError("HashRing needs at least one replica name")
        if len(set(names)) != len(names):
            raise ValueError("replica names must be unique")
        self.names = [str(n) for n in names]
        self.vnodes = int(vnodes)
        points: List[Tuple[int, str]] = []
        for name in self.names:
            for v in range(self.vnodes):
                points.append((self._point(f"{name}#{v}"), name))
        points.sort()
        self._hashes = [h for h, _ in points]
        self._names = [n for _, n in points]

    @staticmethod
    def _point(label: str) -> int:
        # never the builtin hash(): it is salted per process
        return int.from_bytes(
            hashlib.sha256(label.encode()).digest()[:8], "big")

    def route(self, key: str) -> str:
        """Owning replica name for ``key`` (clockwise successor)."""
        idx = bisect.bisect_right(self._hashes, self._point(str(key)))
        return self._names[idx % len(self._names)]

    def successors(self, key: str) -> List[str]:
        """EVERY replica name in clockwise order from ``key``'s point.

        ``successors(k)[0] == route(k)``; the rest are the fallback
        order the frontend hedges/retries through when an owner is slow
        or dead — the same order a ring *without* the owner would route
        to, so a hedge lands exactly where an exclusion reshard will put
        the key's slice.
        """
        idx = bisect.bisect_right(self._hashes, self._point(str(key)))
        out: List[str] = []
        seen: set = set()
        total = len(self.names)
        for i in range(len(self._names)):
            name = self._names[(idx + i) % len(self._names)]
            if name not in seen:
                seen.add(name)
                out.append(name)
                if len(out) == total:
                    break
        return out

    def _owner_after(self, point: int) -> str:
        """Owner of the arc just clockwise of ``point``."""
        idx = bisect.bisect_right(self._hashes, point)
        return self._names[idx % len(self._names)]

    def table(self, keys: Sequence[str]) -> Dict[str, str]:
        """key -> owner for a batch of keys (debug / stability tests)."""
        return {k: self.route(k) for k in keys}

    @staticmethod
    def diff(old: "HashRing", new: "HashRing") -> "RingDiff":
        """Exact ownership delta between two rings (see ``RingDiff``)."""
        return RingDiff(old, new)


class RingDiff:
    """Ownership delta between two ``HashRing`` memberships.

    Computed by sweeping the union of both rings' vnode points: every
    arc between consecutive points has one owner per ring, so the set
    of arcs whose owner changed IS the moved keyspace — exact in
    measure, no key sampling. ``sources`` are the replicas that lose
    keyspace (the ones a reshard must quiesce), ``dests`` the ones that
    gain it, and ``moved_fraction`` the fraction of the hash space that
    changes hands (~1/N for one replica added to N, the
    consistent-hashing bound; 1.0 would be a naive full rehash).

    ``moves(keys)`` classifies concrete keys by re-routing each through
    both rings — the per-key delta migration acts on.
    """

    def __init__(self, old: HashRing, new: HashRing):
        self.old, self.new = old, new
        self.added = [n for n in new.names if n not in old.names]
        self.removed = [n for n in old.names if n not in new.names]
        self.sources: set = set()
        self.dests: set = set()
        points = sorted(set(old._hashes) | set(new._hashes))
        moved = 0
        for i, point in enumerate(points):
            nxt = points[(i + 1) % len(points)]
            length = (nxt - point) % HashRing.SPAN or HashRing.SPAN
            was, now = old._owner_after(point), new._owner_after(point)
            if was != now:
                moved += length
                self.sources.add(was)
                self.dests.add(now)
        self.moved_fraction = moved / HashRing.SPAN

    def moves(self, keys: Sequence[str]) -> Dict[str, Tuple[str, str]]:
        """key -> (old owner, new owner) for keys whose owner changed."""
        out: Dict[str, Tuple[str, str]] = {}
        for k in keys:
            was, now = self.old.route(k), self.new.route(k)
            if was != now:
                out[k] = (was, now)
        return out

    def kept(self, keys: Sequence[str]) -> List[str]:
        """Keys whose owner is identical under both rings."""
        return [k for k in keys if self.old.route(k) == self.new.route(k)]


class GatewayReplica(AbacusServer):
    """One shard of the fleet: an ``AbacusServer`` over its own slice.

    The replica owns a ``PredictionService`` built around its
    fingerprint-sharded ``TraceStore`` slice and (optionally) its own
    ``FeedbackStore``; everything else — micro-batch worker, tick
    boundaries, generation adoption — is inherited unchanged, which is
    exactly the point: the fleet is N unmodified gateways plus routing.
    """

    def __init__(self, name: str, abacus, *, store: Optional[TraceStore] = None,
                 feedback: Optional[FeedbackStore] = None,
                 tracer=trace_query, service_kw: Optional[Dict] = None,
                 **server_kw):
        self.name = str(name)
        service = PredictionService(abacus, store=store, tracer=tracer,
                                    **dict(service_kw or {}))
        super().__init__(service, feedback=feedback, **server_kw)
        self.est_tags = {"replica": self.name}


class GenerationPublisher:
    """Broadcast ``ModelGeneration``s from a central refitter fleet-wide.

    Registered as the refitter's sink; each replica applies the
    generation at its own tick boundary (the ``AbacusServer``
    guarantee), so a publish under load never mixes generations within
    any replica's micro-batch. A failing replica is counted, never
    allowed to swallow the generation for the others.

    Membership is mutable (``set_replicas``: live resharding adds and
    removes gateways under load); each broadcast iterates over a
    snapshot of the list taken at publish time, so a membership change
    mid-``publish_generation`` can neither skip a replica of the
    snapshot nor corrupt the success accounting — the joining replica
    simply catches the *next* generation (resharding seeds it with the
    current one before it serves).
    """

    def __init__(self, replicas: Sequence[AbacusServer]):
        self.replicas = list(replicas)
        self.published = 0          # generations broadcast
        self.deliveries = 0         # per-replica deliveries that succeeded
        self.failures = 0           # per-replica deliveries that raised
        self.last_generation: Optional[int] = None
        self._lock = threading.Lock()

    def set_replicas(self, replicas: Sequence[AbacusServer]) -> None:
        """Swap the broadcast membership (in-flight publishes keep
        the snapshot they started with)."""
        with self._lock:
            self.replicas = list(replicas)

    def publish_generation(self, gen) -> bool:
        with self._lock:
            replicas = list(self.replicas)  # snapshot: membership may move
        ok = 0
        for replica in replicas:
            try:
                replica.publish_generation(gen)
                ok += 1
            except Exception:
                with self._lock:
                    self.failures += 1
        with self._lock:
            self.published += 1
            self.deliveries += ok
            self.last_generation = int(gen.number)
        return ok == len(replicas)

    def info(self) -> Dict:
        with self._lock:
            return {"replicas": len(self.replicas),
                    "published": self.published,
                    "deliveries": self.deliveries,
                    "failures": self.failures,
                    "last_generation": self.last_generation}


class ShardedTraces:
    """``.get(key)`` router over the fleet's trace slices.

    The central refitter resolves feedback keys to traced records; in a
    sharded fleet the record lives on the owning replica — its memory
    cache first, then its persistent slice.
    """

    def __init__(self, frontend: "ClusterFrontend"):
        self.frontend = frontend

    def get(self, key):
        replica = self.frontend.replica_for(key[0])
        rec = replica.service.cached_record(key)
        if rec is None and replica.service.store is not None:
            rec = replica.service.store.get(key)
        return rec


def merge_calibration(metrics: Sequence[Dict]) -> Dict:
    """Fleet-wide calibration from per-replica ``CalibrationWindow``s.

    MRE/drift are per-completion means, so the fleet view is the
    count-weighted mean of the replica windows (exact, not an
    approximation, as long as every completion sits in exactly one
    replica's window). ``by_generation`` merges the same way.
    """
    def _merge(rows: List[Dict]) -> Dict:
        rows = [r for r in rows if r and r.get("count")]
        n = sum(r["count"] for r in rows)
        if not n:
            return {"count": 0, "time_mre": None, "mem_mre": None,
                    "time_drift": None, "mem_drift": None}
        out = {"count": n}
        for field in ("time_mre", "mem_mre", "time_drift", "mem_drift"):
            out[field] = sum(r[field] * r["count"] for r in rows) / n
        return out

    fleet = _merge(list(metrics))
    by_gen: Dict = {}
    for m in metrics:
        for gen, grp in (m or {}).get("by_generation", {}).items():
            by_gen.setdefault(gen, []).append(grp)
    fleet["by_generation"] = {
        gen: _merge(grps)
        for gen, grps in sorted(by_gen.items(),
                                key=lambda e: (-1 if e[0] is None else e[0]))}
    return fleet


class ClusterFrontend:
    """Consistent-hash router over N ``GatewayReplica``s.

    Construction either builds a homogeneous fleet (``abacus`` +
    ``n_replicas``, with per-replica ``TraceStore``/``FeedbackStore``
    slices under ``trace_root``/``feedback_root``) or wraps
    pre-built ``replicas``. The frontend mirrors the ``AbacusServer``
    client API (``submit``/``submit_many``/``predict_one``/
    ``predict_many``/``observe``/``stats``) so existing consumers —
    ``AdmissionController``, ``dryrun --predict`` — can point at a
    fleet unchanged.
    """

    def __init__(self, abacus=None, n_replicas: int = 4, *,
                 trace_root: Optional[str] = None,
                 feedback_root: Optional[str] = None,
                 tracer=trace_query, vnodes: int = 64,
                 service_kw: Optional[Dict] = None,
                 replicas: Optional[Sequence[GatewayReplica]] = None,
                 reshard_timeout: float = 30.0,
                 hedge_after_s: Optional[float] = None,
                 auto_exclude: bool = True,
                 max_retries: int = 3,
                 store_backend: Optional[str] = None,
                 **server_kw):
        # construction recipe, kept so live resharding can mint replicas
        self._abacus = abacus
        self._trace_root = trace_root
        self._feedback_root = feedback_root
        # physical store layout for every slice this fleet mints (per-
        # replica trace/feedback stores AND the central feedback store):
        # None defers to REPRO_STORE_BACKEND / "json" at build time
        self._store_backend = store_backend
        self._tracer = tracer
        self._vnodes = int(vnodes)
        self._service_kw = service_kw
        self._server_kw = server_kw
        if replicas is not None:
            self.replicas = list(replicas)
        else:
            if abacus is None:
                raise ValueError("pass a fitted abacus or explicit replicas")
            self.replicas = [self._build_replica(f"r{i}")
                             for i in range(int(n_replicas))]
        if not self.replicas:
            raise ValueError("ClusterFrontend needs at least one replica")
        self._by_name = {r.name: r for r in self.replicas}
        self.ring = HashRing([r.name for r in self.replicas], vnodes=vnodes)
        # routing state (ring/membership) swaps atomically under one
        # lock at reshard cutover; submits that raced a cutover park on
        # the condition and replay once the epoch moves.
        self._route_lock = threading.RLock()
        self._cutover = threading.Condition(self._route_lock)
        self._epoch = 0
        self._resharding = False
        self._draining: set = set()   # replica names quiesced mid-reshard
        self._started = False
        self.reshard_timeout = float(reshard_timeout)
        # frontend-local registry: the reshard/hedge/retry counters live
        # here (CounterDict keeps the dict mutation surface, so
        # `reshard_stats["hedges"] += 1` and `dict(reshard_stats)` are
        # unchanged); metrics_snapshot() merges it with every replica's.
        self.metrics = MetricsRegistry()
        self.span_sink = SpanSink()
        self.reshard_stats = CounterDict(
            self.metrics, "fleet_",
            ("reshards", "keys_moved", "units_moved", "keys_skipped",
             "keys_replayed", "cutover_ticks", "hedges", "hedge_failures",
             "retries", "exclusions"))
        # replicas excluded or removed take their ServerStats with them;
        # this ledger banks every additive counter a leaver had
        # contributed at departure, so fleet + retired is the all-time
        # truth (`stats()["retired"]`, `fleet_retired_*_total`) even
        # after kills and downsizes. max_batch is a high-water mark, not
        # additive, so it stays out.
        self.retired_stats = CounterDict(
            self.metrics, "fleet_retired_",
            tuple(c for c in ServerStats.COUNTERS if c != "max_batch"))
        # overload ledgers live in their own CounterDicts: the
        # reshard_stats/retired_stats key sets are a frozen wire shape
        # (PR 7), so new series go in beside them, never inside them.
        # `replay_expired` counts parked queries whose deadline passed
        # before a cutover replay (expired work never hits the new ring);
        # `retired_overload` banks a leaver's shed/expired/quota counters
        # the same way retired_stats banks its ServerStats.
        self.overload_stats = CounterDict(self.metrics, "fleet_",
                                          ("replay_expired",))
        self.retired_overload = CounterDict(
            self.metrics, "fleet_retired_",
            ("shed", "expired", "quota_rejected"))
        self.metrics.register_callback(
            lambda: {"fleet_replicas": len(self.replicas)})
        # failure handling for transport-backed replicas (repro.serve.rpc):
        # hedge_after_s duplicates a slow query to the next ring owner,
        # max_retries bounds re-routes of failed submits, auto_exclude
        # reshards a heartbeat-dead replica out of the fleet. In-process
        # replicas don't advertise ``supports_hedge`` and are untouched.
        self.hedge_after_s = hedge_after_s
        self.auto_exclude = bool(auto_exclude)
        self.max_retries = int(max_retries)
        for r in self.replicas:
            self._wire_failure_handling(r)
        # central (federated) feedback store: the refitter's input
        self.feedback = (make_feedback_store(
            os.path.join(feedback_root, "central"),
            backend=self._store_backend) if feedback_root else None)
        self.refitter: Optional[OnlineRefitter] = None
        self.publisher: Optional[GenerationPublisher] = None

    def _build_replica(self, name: str) -> GatewayReplica:
        """Mint one homogeneous replica from the construction recipe."""
        if self._abacus is None:
            raise ValueError(
                "this frontend wraps pre-built replicas; pass a "
                "GatewayReplica object instead of a bare name")
        store = (make_trace_store(os.path.join(self._trace_root, name),
                                  backend=self._store_backend)
                 if self._trace_root else None)
        feedback = (make_feedback_store(
            os.path.join(self._feedback_root, name),
            backend=self._store_backend) if self._feedback_root else None)
        return GatewayReplica(name, self._abacus, store=store,
                              feedback=feedback, tracer=self._tracer,
                              service_kw=self._service_kw, **self._server_kw)

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "ClusterFrontend":
        with self._route_lock:
            self._started = True
            replicas = list(self.replicas)
        for r in replicas:
            r.start()
        return self

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        with self._route_lock:
            self._started = False
            replicas = list(self.replicas)
        for r in replicas:
            r.stop(timeout)

    def __enter__(self) -> "ClusterFrontend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def running(self) -> bool:
        return all(r.running for r in self.replicas)

    # -- routing ------------------------------------------------------------
    def replica_for(self, fingerprint: str) -> GatewayReplica:
        with self._route_lock:
            return self._by_name[self.ring.route(fingerprint)]

    def route(self, cfg) -> Tuple[str, GatewayReplica]:
        """(fingerprint, owning replica) for one config."""
        fp = config_fingerprint(cfg)
        return fp, self.replica_for(fp)

    def _await_cutover(self, epoch: int, deadline: float) -> None:
        """Park until the routing epoch moves past ``epoch`` (replay).

        Called with ``_route_lock`` held (the condition shares it, so
        waiting releases the lock). A query that raced a reshard —
        routed to a replica whose worker is quiesced — waits here for
        the cutover and is then re-routed through the NEW ring:
        ``Query.fp`` is already computed, so the replay is one dict
        lookup, not a re-hash.
        """
        # a failed/aborted reshard also wakes us (_resharding drops):
        # the retry then surfaces the replica's real error instead of
        # parking forever on a cutover that will never come.
        if not self._cutover.wait_for(
                lambda: self._epoch != epoch or not self._resharding,
                timeout=deadline - time.monotonic()):
            raise RuntimeError("reshard cutover did not complete within "
                               f"{self.reshard_timeout}s; query not replayed")

    # -- client API ---------------------------------------------------------
    def submit(self, cfg, batch: int, seq: int, trace: bool = False, *,
               tenant: str = "", deadline: Optional[float] = None) -> Future:
        """Route one query to its shard; fingerprint computed ONCE here.

        ``trace=True`` opts the query into per-stage span recording: a
        trace context rides the query (across the RPC boundary for
        remote replicas), every stage stamps spans with one trace id,
        and ``trace_spans(fut.trace_id)`` returns the assembled trace
        once the future resolves. ``tenant``/``deadline`` ride the query
        to the owning replica's admission ladder (quota, shed, EDF)."""
        fp = config_fingerprint(cfg)
        tc = new_context() if trace else None
        t0 = time.perf_counter() if trace else 0.0
        fut = self._submit_query(Query(cfg, int(batch), int(seq),
                                       fp=fp, tc=tc, tenant=tenant,
                                       deadline=deadline))
        if tc is not None:
            # the root span: frontend accepted + routed the query
            self.span_sink.record(make_span(
                tc["trace"], "submit", time.perf_counter() - t0,
                span_id=tc["span"], ts=time.time(), fp=fp))
            fut.trace_id = tc["trace"]
        return fut

    def _pick_owner(self, fp: str, avoid: frozenset):
        """Owning replica for ``fp``, skipping avoided and dead members.

        With nothing to avoid and no dead replicas this IS ``route``
        (``successors[0]`` is the primary owner); the fallback order is
        the hedge/retry order."""
        for name in self.ring.successors(fp):
            replica = self._by_name.get(name)
            if replica is None or name in avoid:
                continue
            if getattr(replica, "dead", False):
                continue
            return replica
        return None

    def _expired_future(self, q: Query) -> Future:
        """Failed Future for a parked query whose deadline passed before
        its cutover replay: expired work is never replayed onto the new
        ring, and the expiry is counted in ``fleet_replay_expired_total``
        (it never reached a replica, so no server counter moves)."""
        with self._route_lock:
            self.overload_stats["replay_expired"] += 1
        fut: Future = Future()
        fut.set_running_or_notify_cancel()
        fut.set_exception(DeadlineExceeded(
            f"deadline passed before replay of {q.fp!r} onto the new ring",
            where="frontend"))
        return fut

    def _submit_query(self, q: Query, avoid: frozenset = frozenset(),
                      attempts: Optional[int] = None,
                      replay: bool = False) -> Future:
        """Submit one routed query; transport-backed owners get a
        guarded Future (retry on replica death, optional hedging)."""
        attempts = self.max_retries if attempts is None else attempts
        deadline = time.monotonic() + self.reshard_timeout
        parked = False
        while True:
            # first-pass submits always reach the owning replica (the
            # server's tick expires dead work with exact accounting);
            # only a REPLAY — post-cutover wake or a retry re-route —
            # checks the deadline here, so an expired query is never
            # replayed onto the new ring.
            if ((parked or replay) and q.deadline is not None
                    and time.monotonic() >= q.deadline):
                return self._expired_future(q)
            with self._route_lock:
                epoch = self._epoch
                replica = self._pick_owner(q.fp, avoid)
                if replica is None:
                    raise ReplicaUnavailable(
                        f"no live replica owns {q.fp!r} "
                        f"(avoided={sorted(avoid)})")
                kw = {}
                if q.tenant:
                    kw["tenant"] = q.tenant
                if q.deadline is not None:
                    kw["deadline"] = q.deadline
                try:
                    if q.tc is None:
                        fut = replica.submit(q.cfg, q.batch, q.seq,
                                             fp=q.fp, **kw)
                    else:
                        fut = replica.submit(q.cfg, q.batch, q.seq,
                                             fp=q.fp, tc=q.tc, **kw)
                except ReplicaUnavailable:
                    # owner died between the dead-check and the send:
                    # fall through to its ring successor immediately
                    avoid = avoid | {replica.name}
                    continue
                except (QuotaExceeded, DeadlineExceeded):
                    # RuntimeError subclasses, but NOT cutover races:
                    # quota/deadline rejections surface to the caller
                    # instead of parking for a replay
                    raise
                except RuntimeError:
                    if not self._resharding:
                        raise  # genuinely stopped, not a racing cutover
                    self._await_cutover(epoch, deadline)
                    parked = True
                    continue
                if parked:  # counted once per query, not per wakeup
                    self.reshard_stats["keys_replayed"] += 1
                if q.tc is not None:
                    name = "replay" if parked else "route"
                    self.span_sink.record(make_span(
                        q.tc["trace"], name, 0.0, parent=q.tc["span"],
                        replica=replica.name, epoch=epoch))
                    fut.add_done_callback(self._harvest_spans)
                if getattr(replica, "supports_hedge", False):
                    return self._guard(q, fut, replica.name, attempts)
                return fut

    def _guard(self, q: Query, fut: Future, owner: str,
               attempts: int) -> Future:
        """Wrap a transport-backed Future with failure handling.

        The caller's Future resolves from whichever attempt finishes
        first (duplicate results are dropped — queries are idempotent
        and replicas agree byte-for-byte). A retryable failure
        (connection death, timeout, a drain-window rejection) re-routes
        the query; ``hedge_after_s`` additionally duplicates a *slow*
        query to the next ring owner without waiting for a failure.
        """
        out: Future = Future()
        out.set_running_or_notify_cancel()
        timer: List = [None]

        def settle(src: Future) -> None:
            if timer[0] is not None:
                timer[0].cancel()
            if out.done():
                return
            if src.cancelled():
                return
            err = src.exception()
            if err is None:
                _first_wins(out, result=src.result())
            elif isinstance(err, (ReplicaUnavailable, ReplicaNotRunning)) \
                    and attempts > 0:
                # re-route on a fresh thread: this callback may run on
                # the dying replica's reader thread, and the retry can
                # need to park for an exclusion cutover.
                threading.Thread(
                    target=self._retry, args=(q, out, {owner}, attempts - 1),
                    name="cluster-retry", daemon=True).start()
            else:
                _first_wins(out, error=err)

        if self.hedge_after_s is not None:
            t = threading.Timer(self.hedge_after_s, self._hedge,
                                args=(q, out, owner))
            t.daemon = True
            timer[0] = t
            t.start()
        fut.add_done_callback(settle)
        return out

    def _retry(self, q: Query, out: Future, avoid: set,
               attempts: int) -> None:
        """Re-route a failed query; parks for a cutover mid-reshard.

        If a reshard (often the exclusion of the replica that just
        failed) is in flight, wait for its cutover and trust the NEW
        ring — the post-cutover owner holds the migrated slice, so the
        replay costs zero re-traces. Otherwise route around the failure
        via the ring's successor order right away.
        """
        try:
            with self._route_lock:
                if self._resharding:
                    try:
                        self._await_cutover(
                            self._epoch,
                            time.monotonic() + self.reshard_timeout)
                        avoid = set()  # the new ring is authoritative
                    except RuntimeError:
                        pass  # cutover never came: fall back to avoidance
                self.reshard_stats["retries"] += 1
            if q.tc is not None:
                self.span_sink.record(make_span(
                    q.tc["trace"], "retry", 0.0, parent=q.tc["span"],
                    avoided=sorted(avoid)))
            inner = self._submit_query(q, avoid=frozenset(avoid),
                                       attempts=attempts, replay=True)
        except Exception as e:
            _first_wins(out, error=e)
            return
        inner.add_done_callback(lambda f: _relay(f, out))

    def _hedge(self, q: Query, out: Future, primary: str) -> None:
        """Duplicate a slow query to the next ring owner (first wins).

        The hedge counter moves only AFTER the duplicate submit
        succeeded: a hedge whose submission raises (every successor
        excluded, say) never reached another replica, and counting it
        as issued made ``hedges`` overstate real duplicates. Failed
        attempts are tallied separately under ``hedge_failures``.
        """
        if out.done():
            return
        try:
            inner = self._submit_query(q, avoid=frozenset({primary}),
                                       attempts=0)
        except Exception:
            # the primary may still answer; never fail out here
            with self._route_lock:
                self.reshard_stats["hedge_failures"] += 1
            return
        with self._route_lock:
            self.reshard_stats["hedges"] += 1
        if q.tc is not None:
            self.span_sink.record(make_span(
                q.tc["trace"], "hedge", 0.0, parent=q.tc["span"],
                primary=primary))
        inner.add_done_callback(lambda f: _relay(f, out))

    def submit_many(self, queries: Sequence) -> List[Future]:
        """Fan a wave out: one enqueue (-> one tick wake) per replica.

        Futures come back in input order; each replica's worker
        coalesces its whole partition into one concurrent micro-batch.
        A partition routed to a replica that a concurrent reshard
        quiesced parks until the cutover, then replays through the new
        ring — every submitted query resolves to exactly one Future.
        """
        qs = [q if isinstance(q, Query) else Query(*q) for q in queries]
        qs = [q if q.fp is not None
              else dataclasses.replace(q, fp=config_fingerprint(q.cfg))
              for q in qs]
        futs: List[Optional[Future]] = [None] * len(qs)
        pending = list(range(len(qs)))
        parked: set = set()        # queries that raced a cutover, deduped
        singles: List[int] = []    # rerouted one-by-one around a dead owner
        deadline = time.monotonic() + self.reshard_timeout
        while pending:
            # parked entries woken by a cutover are REPLAYS: expire the
            # ones whose deadline already passed instead of replaying
            # them onto the new ring (they also leave `parked`, keeping
            # keys_replayed exact).
            if parked:
                now = time.monotonic()
                live = []
                for i in pending:
                    if (i in parked and qs[i].deadline is not None
                            and qs[i].deadline <= now):
                        parked.discard(i)
                        futs[i] = self._expired_future(qs[i])
                    else:
                        live.append(i)
                pending = live
                if not pending:
                    with self._route_lock:
                        if parked:
                            self.reshard_stats["keys_replayed"] += len(parked)
                    break
            with self._route_lock:
                epoch = self._epoch
                parts: Dict[str, List[int]] = {}
                for i in pending:
                    parts.setdefault(self.ring.route(qs[i].fp), []).append(i)
                raced: List[int] = []
                for name, idxs in parts.items():
                    replica = self._by_name[name]
                    try:
                        for i, fut in zip(idxs, replica
                                          .submit_many([qs[i] for i in idxs])):
                            if qs[i].tc is not None:
                                fut.add_done_callback(self._harvest_spans)
                            futs[i] = (self._guard(qs[i], fut, name,
                                                   self.max_retries)
                                       if getattr(replica, "supports_hedge",
                                                  False) else fut)
                    except ReplicaUnavailable:
                        # dead owner: re-route those queries individually
                        # (outside this lock) through the successor order
                        singles.extend(idxs)
                    except RuntimeError:
                        if not self._resharding:
                            raise
                        raced.extend(idxs)
                pending = raced
                if pending:
                    parked.update(pending)
                    self._await_cutover(epoch, deadline)
                elif parked:  # counted once per query, not per wakeup
                    self.reshard_stats["keys_replayed"] += len(parked)
        for i in singles:
            futs[i] = self._submit_query(qs[i])
        return futs  # type: ignore[return-value]

    def _harvest_spans(self, fut: Future) -> None:
        """Collect server-side spans shipped back inside a traced
        estimate (``est["_trace"]``) into the frontend's sink — for a
        remote replica these crossed the process boundary, so the sink
        ends up holding one coherent cross-process trace."""
        try:
            if fut.cancelled() or fut.exception() is not None:
                return
            est = fut.result()
        except Exception:
            return
        if isinstance(est, dict):
            # pop, not get: the shipping envelope is transport detail,
            # not part of the estimate callers see. Done-callbacks run
            # before result() wakes waiters, so callers never observe
            # the key either way.
            spans = est.pop("_trace", None)
            if spans:
                self.span_sink.extend(spans)

    def trace_spans(self, trace_id: str) -> List[Dict]:
        """Every span harvested for one trace id (frontend + replicas),
        ordered by start timestamp."""
        return self.span_sink.for_trace(trace_id)

    def predict_one(self, cfg, batch: int, seq: int,
                    timeout: Optional[float] = None) -> Dict:
        return self.submit(cfg, batch, seq).result(timeout)

    def predict_many(self, queries: Sequence,
                     timeout: Optional[float] = None) -> List[Dict]:
        # one SHARED deadline across the wave (not timeout-per-future,
        # which compounds to N x timeout worst case)
        return _results_by_deadline(self.submit_many(queries), timeout)

    # -- live resharding ----------------------------------------------------
    def add_replica(self, replica) -> Dict:
        """Grow the fleet by one gateway, migrating its slice to it live.

        ``replica`` is a bare name (a homogeneous replica is minted from
        the construction recipe) or a pre-built ``GatewayReplica``. The
        joiner adopts the fleet's current ``ModelGeneration`` before it
        serves a single query. Returns the migration summary.
        """
        prebuilt: Dict[str, GatewayReplica] = {}
        if isinstance(replica, GatewayReplica):
            prebuilt[replica.name] = replica
            name = replica.name
        else:
            name = str(replica)

        def plan(old_names):
            if name in old_names:
                raise ValueError(f"replica {name!r} already in the fleet")
            return old_names + [name]

        return self._reshard(plan, prebuilt)

    def remove_replica(self, name: str) -> Dict:
        """Shrink the fleet by one gateway: drain it, migrate its
        ``TraceStore``/``FeedbackStore`` slices to the new owners, cut
        the ring over. Every query queued on it resolves (the drain
        serves them); queries racing the cutover replay to new owners.
        """
        name = str(name)

        def plan(old_names):
            if name not in old_names:
                raise ValueError(f"no replica named {name!r}")
            if len(old_names) == 1:
                raise ValueError("cannot remove the last replica")
            return [n for n in old_names if n != name]

        return self._reshard(plan)

    # -- failure handling (transport-backed replicas) ------------------------
    def _wire_failure_handling(self, replica) -> None:
        """Attach the dead-replica callback to a transport-backed member."""
        if getattr(replica, "supports_hedge", False) \
                and hasattr(replica, "on_dead"):
            replica.on_dead = self._on_replica_dead

    def _on_replica_dead(self, replica) -> None:
        """Heartbeat verdict: a member stopped answering.

        Runs on the dead replica's heartbeat (or reader) thread, so the
        exclusion reshard is handed to its own thread — the protocol
        drains, migrates, and must never run on a transport thread.
        """
        if not self.auto_exclude:
            return
        threading.Thread(target=self._exclude_dead, args=(replica.name,),
                         name=f"exclude-{replica.name}", daemon=True).start()

    def _exclude_dead(self, name: str, retries: int = 50) -> None:
        for _ in range(retries):
            try:
                self.exclude_replica(name)
                return
            except ValueError:
                return  # already excluded (or fleet-of-one: nothing to do)
            except RuntimeError:
                time.sleep(0.2)  # another reshard holds the guard: retry

    def exclude_replica(self, name: str) -> Dict:
        """Reshard a DEAD replica out of the fleet (the crash path).

        Unlike ``remove_replica`` there is nothing to drain — the
        process is gone and its worker with it. Its authoritative state
        is its on-disk ``TraceStore``/``FeedbackStore`` slice (the
        gateway writes through at trace time), which the ordinary
        migrate step hands to the ring successors exactly as the PR 5
        crash-restart path does: warm keys are rebuilt from disk, zero
        re-traces. In-flight queries against the dead member fail fast
        (``ReplicaUnavailable``) and re-route via hedge/retry.
        """
        name = str(name)

        def plan(old_names):
            if name not in old_names:
                raise ValueError(f"no replica named {name!r}")
            if len(old_names) == 1:
                raise ValueError("cannot exclude the last replica")
            return [n for n in old_names if n != name]

        doomed = self._by_name.get(name)
        summary = self._reshard(plan)
        with self._route_lock:
            self.reshard_stats["exclusions"] += 1
        events.emit("exclusion", replica=name,
                    keys_moved=summary.get("keys_moved", 0),
                    members=summary.get("to", []))
        if doomed is not None and hasattr(doomed, "close"):
            try:
                doomed.close()
            except Exception:
                pass
        return summary

    def resize(self, n_replicas: int) -> Dict:
        """Reshard the fleet to ``n_replicas`` gateways in ONE protocol
        pass (one drain, one migration, one cutover — not N single-step
        reshards). Growth mints ``r<i>`` replicas from the construction
        recipe; shrink retires the most recently added gateways.
        """
        n = int(n_replicas)
        if n < 1:
            raise ValueError("a fleet needs at least one replica")

        def plan(old_names):
            if n <= len(old_names):
                return old_names[:n]
            names, i = list(old_names), 0
            while len(names) < n:
                if f"r{i}" not in names:
                    names.append(f"r{i}")
                i += 1
            return names

        return self._reshard(plan)

    def _current_generation(self):
        """(abacus, generation) snapshot of the newest LIVE replica.

        Dead members still report a (cached) generation but can no
        longer serve a snapshot — never pick one while a survivor
        exists."""
        live = [r for r in self.replicas if not getattr(r, "dead", False)]
        newest = max(live or self.replicas,
                     key=lambda r: r.service.generation)
        return newest.service.snapshot()

    @staticmethod
    def _slices(replica: GatewayReplica):
        """The migratable stores of one replica, tagged by kind."""
        return (("trace", replica.service.store),
                ("feedback", replica.feedback))

    def _reshard(self, plan,
                 prebuilt: Optional[Dict[str, GatewayReplica]] = None) -> Dict:
        """Drain -> migrate -> cutover to the membership ``plan`` names.

        ``plan(old_names) -> new_names`` runs AFTER the single-reshard
        guard is taken, so concurrent admin calls always compute (and
        validate) against the membership they will actually change —
        never a stale snapshot an overlapping reshard just replaced.

        1. **drain**: quiesce ONLY the affected replicas — the ones the
           ring diff says lose keyspace (``RingDiff.sources``) plus the
           leavers — by stopping their workers (queued queries are
           served before the worker exits, so every in-flight Future
           resolves). Unaffected replicas keep ticking throughout. A
           replica still draining past the timeout ABORTS the reshard
           (migrating under a live writer would orphan its last ticks'
           keys); the abort restarts whatever quiesced, and a retry
           succeeds once the stuck worker exits.
        2. **migrate**: each quiesced replica hands exactly its moved
           ``TraceStore``/``FeedbackStore`` keys to their new owners via
           ``JsonFileStore.split`` (the commutative merge contract — a
           destination that cold-traced a moved key mid-migration
           converges, never conflicts). Key sets are computed AFTER the
           drain, so records written by the final ticks migrate too. A
           migration failure (e.g. disk full) restarts the drained
           survivors on the OLD ring and re-raises; retrying the same
           reshard completes the handoff (split/merge converge).
        3. **cutover**: atomically swap ring + membership, restart the
           quiesced survivors, start the joiners (already seeded with
           the fleet's current generation), bump the routing epoch, and
           wake every parked query for replay. Publisher and refitter
           membership follow.
        """
        with self._route_lock:
            if self._resharding:
                raise RuntimeError("a reshard is already in progress")
            self._resharding = True
            old_names = [r.name for r in self.replicas]
        drained: List[GatewayReplica] = []
        try:
            names = [str(n) for n in plan(old_names)]
            if not names:
                raise ValueError("a fleet needs at least one replica")
            summary = {"from": old_names, "to": names, "keys_moved": 0,
                       "units_moved": 0, "keys_skipped": 0,
                       "cutover_ticks": 0, "trace_keys_moved": 0,
                       "feedback_keys_moved": 0}
            new_ring = HashRing(names, vnodes=self._vnodes)
            diff = HashRing.diff(self.ring, new_ring)
            summary["moved_fraction_bound"] = diff.moved_fraction
            joiners = {n: (prebuilt or {}).get(n) or self._build_replica(n)
                       for n in names if n not in self._by_name}
            # joiners adopt the fleet's CURRENT generation before serving
            # (lazily: an exclusion has no joiners and possibly no live
            # replica to snapshot from until the cutover)
            if joiners:
                abacus, generation = self._current_generation()
                for rep in joiners.values():
                    if generation > rep.service.generation:
                        rep.service.adopt(abacus, generation)
            # 1) drain the affected replicas (keyspace losers + leavers)
            affected = [self._by_name[n] for n in old_names
                        if n in diff.sources or n not in names]
            with self._route_lock:
                self._draining = {r.name for r in affected}
            ticks_before = sum(r.stats.ticks for r in affected)
            drained = [r for r in affected if r.running]
            for r in drained:
                r.stop(timeout=self.reshard_timeout)
            # verify EVERY affected worker is gone (including one still
            # draining from a previously aborted reshard): migration
            # must never run concurrently with a live writer.
            stuck = [r.name for r in affected if r.draining]
            if stuck:
                raise RuntimeError(
                    f"replicas {stuck} did not drain within "
                    f"{self.reshard_timeout}s; reshard aborted (retry "
                    "once their in-flight micro-batches finish)")
            summary["cutover_ticks"] = (sum(r.stats.ticks for r in affected)
                                        - ticks_before)
            # leavers are quiesced (or dead) now, so their counters are
            # final: snapshot them here, bank them only after the
            # cutover commits (an aborted reshard keeps its leavers, and
            # banking early would double-count them on retry)
            retiring = {r.name: {c: int(getattr(r.stats, c, 0) or 0)
                                 for c in self.retired_stats}
                        for r in affected if r.name not in names}
            # same banking for the overload ledger: a leaver's shed/
            # expired/quota counters are final once quiesced (a dead
            # remote falls back to its cached snapshot; no counters at
            # all banks zeros).
            retiring_overload: Dict[str, Dict] = {}
            for r in affected:
                if r.name in names:
                    continue
                fn = getattr(r, "overload_counters", None)
                if fn is None:
                    continue
                try:
                    retiring_overload[r.name] = dict(fn())
                except Exception:
                    retiring_overload[r.name] = {}
            # 2) migrate: hand exactly the moved slices to the new owners
            owners = {**self._by_name, **joiners}
            for src in affected:
                for which, src_store in self._slices(src):
                    if src_store is None:
                        continue
                    by_dest: Dict[str, List] = {}
                    for key in src_store.keys():
                        owner = new_ring.route(key[0])
                        if owner != src.name:
                            by_dest.setdefault(owner, []).append(key)
                    for owner, keys in sorted(by_dest.items()):
                        dest_store = dict(
                            self._slices(owners[owner]))[which]
                        if dest_store is None:
                            summary["keys_skipped"] += len(keys)
                            continue
                        res = src_store.split(keys, dest_store)
                        summary["keys_moved"] += res["moved"]
                        summary[f"{which}_keys_moved"] += res["moved"]
                        summary["units_moved"] += res["units"]
                        summary["keys_skipped"] += res["skipped"]
            # 3) cutover: swap the ring atomically, wake parked queries
            self._cutover_swap(names, new_ring, joiners)
        except BaseException:
            # any failure before the cutover leaves the OLD ring in
            # place: the quiesced survivors must serve again, or their
            # shards would reject every query until a manual restart.
            if self._started:
                for r in drained:
                    try:
                        r.start()
                    except RuntimeError:
                        pass  # still draining: it finishes on its own
            raise
        finally:
            with self._route_lock:
                self._resharding = False
                self._draining = set()
                self._cutover.notify_all()  # never strand a parked query
        for k in ("keys_moved", "units_moved", "keys_skipped",
                  "cutover_ticks"):
            self.reshard_stats[k] += summary[k]
        self.reshard_stats["reshards"] += 1
        for counters in retiring.values():
            for c, v in counters.items():
                self.retired_stats[c] += v
        for counters in retiring_overload.values():
            for c, v in counters.items():
                if c in self.retired_overload:  # cached dicts may carry
                    self.retired_overload[c] += int(v or 0)  # e.g. "stale"
        summary["retired"] = sorted(retiring)
        events.emit("reshard", members_from=summary["from"],
                    members_to=summary["to"],
                    keys_moved=summary["keys_moved"],
                    keys_skipped=summary["keys_skipped"],
                    cutover_ticks=summary["cutover_ticks"])
        return summary

    def _cutover_swap(self, names: Sequence[str], new_ring: HashRing,
                      joiners: Dict[str, GatewayReplica]) -> None:
        """Atomic membership + ring swap; restarts quiesced gateways.

        Everything a router can observe — ``replicas``, ``_by_name``,
        ``ring``, the running state of every member — changes under ONE
        ``_route_lock`` hold, then the epoch bump releases every query
        parked on the cutover condition to re-route through the new
        ring. (Separated from ``_reshard`` so crash tests can fail the
        protocol precisely between migrate and cutover.)
        """
        for rep in joiners.values():
            self._wire_failure_handling(rep)
        with self._route_lock:
            self.replicas = [joiners.get(n) or self._by_name[n]
                             for n in names]
            self._by_name = {r.name: r for r in self.replicas}
            self.ring = new_ring
            self._draining = set()
            if self._started:
                for r in self.replicas:
                    if not r.running:
                        r.start()
            self._epoch += 1
            self._cutover.notify_all()
        if self.publisher is not None:
            self.publisher.set_replicas(self.replicas)
        if self.refitter is not None:
            self.refitter.set_sources(
                [r.feedback for r in self.replicas
                 if r.feedback is not None])

    # -- feedback loop ------------------------------------------------------
    def observe(self, cfg, batch: int, seq: int, time_s: float,
                mem_bytes: float, **kw) -> None:
        """Report a completion to the replica that owns the config.

        The observation lands in the owning replica's ``FeedbackStore``
        slice (and its calibration window); the central refitter pulls
        it on its next federated sync. ``notify()`` keeps that sync
        prompt without the frontend doing any merging inline. An
        observation racing a reshard of its owner parks until the
        cutover and lands in the NEW owner's slice. The file write
        itself happens OUTSIDE the routing lock (submits never stall
        behind disk I/O); if the written replica was *removed* from
        the fleet mid-write — its slice already handed off — the
        observation is re-delivered to the current owner (a surviving
        member's slice stays a refitter source, so only removal needs
        the retry; the rare duplicate this can add is benign, lost
        feedback would not be).
        """
        fp = kw.pop("fp", None) or config_fingerprint(cfg)
        deadline = time.monotonic() + self.reshard_timeout
        redeliveries = 0
        avoid: set = set()
        while True:
            with self._route_lock:
                name = self.ring.route(fp)
                if name in self._draining:
                    self._await_cutover(self._epoch, deadline)
                    continue                  # parked; re-route fresh
                replica = self._by_name[name]
                if name in avoid or getattr(replica, "dead", False):
                    picked = self._pick_owner(fp, frozenset(avoid))
                    if picked is None:
                        raise ReplicaUnavailable(
                            f"no live replica to observe {fp!r}")
                    replica = picked
            try:
                replica.observe(cfg, batch, seq, time_s, mem_bytes,
                                fp=fp, **kw)
            except ReplicaUnavailable:
                # owner died mid-call: its slice survives on disk and the
                # exclusion reshard will hand it over — deliver to the
                # ring successor now (feedback merges are idempotent).
                avoid.add(replica.name)
                if len(avoid) >= len(self.replicas):
                    raise
                continue
            with self._route_lock:
                if (self._by_name.get(replica.name) is replica
                        or redeliveries >= 3):
                    break                     # still a member: durable
            redeliveries += 1
        if self.refitter is not None:
            self.refitter.notify()

    def sync_feedback(self) -> int:
        """Merge every replica's feedback slice into the central store."""
        if self.feedback is None:
            raise ValueError("no central feedback store "
                             "(construct with feedback_root=...)")
        return sum(self.feedback.merge(r.feedback) for r in self.replicas
                   if r.feedback is not None)

    # -- model generations --------------------------------------------------
    def publish_generation(self, gen) -> bool:
        """Broadcast a generation to every replica (tick-boundary applied)."""
        if self.publisher is None:
            self.publisher = GenerationPublisher(self.replicas)
        return self.publisher.publish_generation(gen)

    def attach_refitter(self, refitter: OnlineRefitter) -> OnlineRefitter:
        """Wire a central refitter into the fleet's publish path."""
        self.publisher = self.publisher or GenerationPublisher(self.replicas)
        refitter.add_sink(self.publisher)
        self.refitter = refitter
        return refitter

    def make_refitter(self, seed_records=None, **kw) -> OnlineRefitter:
        """Central ``OnlineRefitter`` over the fleet.

        Consumes the federated merge of every replica's
        ``FeedbackStore`` (``sources=``), resolves feedback keys
        against the owning shard's traces, and publishes each new
        generation to every replica via ``GenerationPublisher``.
        """
        if self.feedback is None:
            raise ValueError("central refit needs feedback_root=...")
        refitter = OnlineRefitter(
            self.replicas[0].service, self.feedback,
            seed_records=seed_records, traces=ShardedTraces(self),
            sources=[r.feedback for r in self.replicas
                     if r.feedback is not None], **kw)
        return self.attach_refitter(refitter)

    # -- introspection ------------------------------------------------------
    def server_info(self) -> Dict:
        per = {r.name: r.server_info() for r in self.replicas}
        fleet = self._sum_counters(per)
        fleet["queued"] = sum(p.get("queued", 0) for p in per.values())
        return {"replicas": len(self.replicas), "running": self.running,
                "fleet": fleet, "per_replica": per}

    @staticmethod
    def _sum_counters(per: Dict[str, Dict]) -> Dict:
        counters = ServerStats.COUNTERS
        fleet = {c: sum(p.get(c, 0) for p in per.values()) for c in counters}
        # max_batch is a high-water mark, not additive
        fleet["max_batch"] = max((p.get("max_batch", 0)
                                  for p in per.values()), default=0)
        return fleet

    def stats(self) -> Dict:
        """Fleet-wide view: summed counters, merged calibration, refit,
        and the lifetime resharding/migration counters.

        ``stale_replicas`` lists members whose contribution is a cached
        fallback (a dead ``RemoteReplica`` serving its last-known
        counters, stamped ``{"stale": true, ...}``) — the fleet sums
        include those cached numbers, so consumers can tell live truth
        from a dead member's last words."""
        per = {r.name: r.stats() for r in self.replicas}
        fleet = self._sum_counters(per)
        out = {
            "replicas": len(self.replicas),
            "fleet": fleet,
            "reshard": dict(self.reshard_stats),
            "retired": dict(self.retired_stats),
            "generations": sorted({r.service.generation
                                   for r in self.replicas}),
            "calibration": merge_calibration(
                [p.get("calibration", {}) for p in per.values()]),
            "per_replica": per,
            "stale_replicas": sorted(name for name, p in per.items()
                                     if p.get("stale")),
            # NEW key (stats() compat): all-time overload accounting is
            # fleet (live members) + retired (banked leavers) + frontend
            # (replay expiries that never reached a replica).
            "overload": {
                "fleet": {k: sum(int((p.get("overload") or {}).get(k, 0)
                                     or 0) for p in per.values())
                          for k in ("shed", "expired", "quota_rejected")},
                "retired": dict(self.retired_overload),
                "frontend": dict(self.overload_stats),
            },
        }
        if self.refitter is not None:
            out["refit"] = self.refitter.info()
        if self.publisher is not None:
            out["publisher"] = self.publisher.info()
        if self.feedback is not None:
            out["feedback"] = self.feedback.info()
        return out

    def metrics_snapshot(self) -> Dict:
        """Fleet-merged registry snapshot: the frontend's own counters
        plus every reachable replica's (counters sum, gauges max,
        histogram buckets add — replica order cannot change the
        result). Unreachable members are skipped and counted in the
        ``fleet_unreachable`` gauge."""
        snaps = [self.metrics.snapshot()]
        unreachable = 0
        for r in list(self.replicas):
            fn = getattr(r, "metrics_snapshot", None)
            if fn is None:
                continue
            try:
                snaps.append(fn())
            except Exception:
                unreachable += 1
        merged = merge_snapshots(snaps)
        merged["fleet_unreachable"] = {"type": "gauge",
                                       "value": unreachable}
        return merged

    def metrics_text(self) -> str:
        """Prometheus text exposition of :meth:`metrics_snapshot`."""
        return render_prometheus(self.metrics_snapshot(),
                                 namespace=self.metrics.namespace)
