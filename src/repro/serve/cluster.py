"""Multi-host serving fabric: sharded gateway replicas behind one frontend.

A single ``AbacusServer`` is one worker loop, one trace-cache budget,
and one feedback stream — the datacenter setting the paper targets
(admission control for whole fleets, §4.3) needs N of them. This module
is the fleet seam:

  * ``HashRing`` — consistent hashing over replica names. Points are
    SHA-256 derived, so routing is a pure function of the key string:
    stable across processes, hash seeds (``PYTHONHASHSEED``), and
    restarts — the property that makes a replica's trace-store slice
    *own* its keys.
  * ``GatewayReplica`` — an ``AbacusServer`` over its own
    ``PredictionService`` slice: a fingerprint-sharded ``TraceStore``
    directory, its own ``FeedbackStore``, its own micro-batch worker.
    Every estimate it resolves is stamped with ``replica`` so
    (tick, generation) pairs are attributable fleet-wide.
  * ``ClusterFrontend`` — routes each query to the replica that owns
    its config fingerprint (computed ONCE here and forwarded via
    ``Query.fp``), fans a wave of submissions out so every replica's
    worker ticks concurrently on its partition, aggregates ``stats()``
    fleet-wide, and broadcasts model generations.
  * ``GenerationPublisher`` — the sink a central ``OnlineRefitter``
    publishes through: every replica receives each ``ModelGeneration``
    and applies it at its own tick boundary (``AbacusServer``'s
    between-ticks guarantee), so no replica ever serves two
    generations within one micro-batch. The refitter itself consumes a
    *federated* merge of all per-replica ``FeedbackStore``s
    (``OnlineRefitter(sources=...)``) and resolves feedback keys
    against the owning shard's traces (``ShardedTraces``).

Sharding by config fingerprint (not by full key) keeps every shape of
one model on one replica, so that replica's trace/prediction caches see
all the locality. The fleet-level win on one box is aggregate cache
capacity — each replica only holds 1/N of the working set — and the
seam is transport-agnostic: replicas are in-process here, but nothing
in the frontend assumes it (the later RPC step swaps the replica list
for remote stubs).
"""

from __future__ import annotations

import bisect
import dataclasses
import hashlib
import os
import threading
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serve.feedback_store import FeedbackStore
from repro.serve.prediction_service import (PredictionService, Query,
                                            config_fingerprint, trace_query)
from repro.serve.refit import OnlineRefitter
from repro.serve.server import AbacusServer, ServerStats
from repro.serve.trace_store import TraceStore


class HashRing:
    """Consistent-hash ring over replica names.

    ``vnodes`` virtual points per replica smooth the key distribution;
    all points are SHA-256 derived so ``route`` is a pure function of
    its argument — two processes (or two hash seeds) always agree on
    which replica owns a fingerprint. Adding or removing one replica
    moves only ~1/N of the keyspace (the consistent-hashing property
    the later resharding step relies on).
    """

    def __init__(self, names: Sequence[str], vnodes: int = 64):
        if not names:
            raise ValueError("HashRing needs at least one replica name")
        if len(set(names)) != len(names):
            raise ValueError("replica names must be unique")
        self.vnodes = int(vnodes)
        points: List[Tuple[int, str]] = []
        for name in names:
            for v in range(self.vnodes):
                points.append((self._point(f"{name}#{v}"), name))
        points.sort()
        self._hashes = [h for h, _ in points]
        self._names = [n for _, n in points]

    @staticmethod
    def _point(label: str) -> int:
        # never the builtin hash(): it is salted per process
        return int.from_bytes(
            hashlib.sha256(label.encode()).digest()[:8], "big")

    def route(self, key: str) -> str:
        """Owning replica name for ``key`` (clockwise successor)."""
        idx = bisect.bisect_right(self._hashes, self._point(str(key)))
        return self._names[idx % len(self._names)]

    def table(self, keys: Sequence[str]) -> Dict[str, str]:
        """key -> owner for a batch of keys (debug / stability tests)."""
        return {k: self.route(k) for k in keys}


class GatewayReplica(AbacusServer):
    """One shard of the fleet: an ``AbacusServer`` over its own slice.

    The replica owns a ``PredictionService`` built around its
    fingerprint-sharded ``TraceStore`` slice and (optionally) its own
    ``FeedbackStore``; everything else — micro-batch worker, tick
    boundaries, generation adoption — is inherited unchanged, which is
    exactly the point: the fleet is N unmodified gateways plus routing.
    """

    def __init__(self, name: str, abacus, *, store: Optional[TraceStore] = None,
                 feedback: Optional[FeedbackStore] = None,
                 tracer=trace_query, service_kw: Optional[Dict] = None,
                 **server_kw):
        self.name = str(name)
        service = PredictionService(abacus, store=store, tracer=tracer,
                                    **dict(service_kw or {}))
        super().__init__(service, feedback=feedback, **server_kw)
        self.est_tags = {"replica": self.name}


class GenerationPublisher:
    """Broadcast ``ModelGeneration``s from a central refitter fleet-wide.

    Registered as the refitter's sink; each replica applies the
    generation at its own tick boundary (the ``AbacusServer``
    guarantee), so a publish under load never mixes generations within
    any replica's micro-batch. A failing replica is counted, never
    allowed to swallow the generation for the others.
    """

    def __init__(self, replicas: Sequence[AbacusServer]):
        self.replicas = list(replicas)
        self.published = 0          # generations broadcast
        self.deliveries = 0         # per-replica deliveries that succeeded
        self.failures = 0           # per-replica deliveries that raised
        self.last_generation: Optional[int] = None
        self._lock = threading.Lock()

    def publish_generation(self, gen) -> bool:
        ok = 0
        for replica in self.replicas:
            try:
                replica.publish_generation(gen)
                ok += 1
            except Exception:
                with self._lock:
                    self.failures += 1
        with self._lock:
            self.published += 1
            self.deliveries += ok
            self.last_generation = int(gen.number)
        return ok == len(self.replicas)

    def info(self) -> Dict:
        with self._lock:
            return {"replicas": len(self.replicas),
                    "published": self.published,
                    "deliveries": self.deliveries,
                    "failures": self.failures,
                    "last_generation": self.last_generation}


class ShardedTraces:
    """``.get(key)`` router over the fleet's trace slices.

    The central refitter resolves feedback keys to traced records; in a
    sharded fleet the record lives on the owning replica — its memory
    cache first, then its persistent slice.
    """

    def __init__(self, frontend: "ClusterFrontend"):
        self.frontend = frontend

    def get(self, key):
        replica = self.frontend.replica_for(key[0])
        rec = replica.service.cached_record(key)
        if rec is None and replica.service.store is not None:
            rec = replica.service.store.get(key)
        return rec


def merge_calibration(metrics: Sequence[Dict]) -> Dict:
    """Fleet-wide calibration from per-replica ``CalibrationWindow``s.

    MRE/drift are per-completion means, so the fleet view is the
    count-weighted mean of the replica windows (exact, not an
    approximation, as long as every completion sits in exactly one
    replica's window). ``by_generation`` merges the same way.
    """
    def _merge(rows: List[Dict]) -> Dict:
        rows = [r for r in rows if r and r.get("count")]
        n = sum(r["count"] for r in rows)
        if not n:
            return {"count": 0, "time_mre": None, "mem_mre": None,
                    "time_drift": None, "mem_drift": None}
        out = {"count": n}
        for field in ("time_mre", "mem_mre", "time_drift", "mem_drift"):
            out[field] = sum(r[field] * r["count"] for r in rows) / n
        return out

    fleet = _merge(list(metrics))
    by_gen: Dict = {}
    for m in metrics:
        for gen, grp in (m or {}).get("by_generation", {}).items():
            by_gen.setdefault(gen, []).append(grp)
    fleet["by_generation"] = {
        gen: _merge(grps)
        for gen, grps in sorted(by_gen.items(),
                                key=lambda e: (-1 if e[0] is None else e[0]))}
    return fleet


class ClusterFrontend:
    """Consistent-hash router over N ``GatewayReplica``s.

    Construction either builds a homogeneous fleet (``abacus`` +
    ``n_replicas``, with per-replica ``TraceStore``/``FeedbackStore``
    slices under ``trace_root``/``feedback_root``) or wraps
    pre-built ``replicas``. The frontend mirrors the ``AbacusServer``
    client API (``submit``/``submit_many``/``predict_one``/
    ``predict_many``/``observe``/``stats``) so existing consumers —
    ``AdmissionController``, ``dryrun --predict`` — can point at a
    fleet unchanged.
    """

    def __init__(self, abacus=None, n_replicas: int = 4, *,
                 trace_root: Optional[str] = None,
                 feedback_root: Optional[str] = None,
                 tracer=trace_query, vnodes: int = 64,
                 service_kw: Optional[Dict] = None,
                 replicas: Optional[Sequence[GatewayReplica]] = None,
                 **server_kw):
        if replicas is not None:
            self.replicas = list(replicas)
        else:
            if abacus is None:
                raise ValueError("pass a fitted abacus or explicit replicas")
            self.replicas = []
            for i in range(int(n_replicas)):
                name = f"r{i}"
                store = (TraceStore(os.path.join(trace_root, name))
                         if trace_root else None)
                feedback = (FeedbackStore(os.path.join(feedback_root, name))
                            if feedback_root else None)
                self.replicas.append(GatewayReplica(
                    name, abacus, store=store, feedback=feedback,
                    tracer=tracer, service_kw=service_kw, **server_kw))
        if not self.replicas:
            raise ValueError("ClusterFrontend needs at least one replica")
        self._by_name = {r.name: r for r in self.replicas}
        self.ring = HashRing([r.name for r in self.replicas], vnodes=vnodes)
        # central (federated) feedback store: the refitter's input
        self.feedback = (FeedbackStore(os.path.join(feedback_root, "central"))
                         if feedback_root else None)
        self.refitter: Optional[OnlineRefitter] = None
        self.publisher: Optional[GenerationPublisher] = None

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "ClusterFrontend":
        for r in self.replicas:
            r.start()
        return self

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        for r in self.replicas:
            r.stop(timeout)

    def __enter__(self) -> "ClusterFrontend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def running(self) -> bool:
        return all(r.running for r in self.replicas)

    # -- routing ------------------------------------------------------------
    def replica_for(self, fingerprint: str) -> GatewayReplica:
        return self._by_name[self.ring.route(fingerprint)]

    def route(self, cfg) -> Tuple[str, GatewayReplica]:
        """(fingerprint, owning replica) for one config."""
        fp = config_fingerprint(cfg)
        return fp, self.replica_for(fp)

    # -- client API ---------------------------------------------------------
    def submit(self, cfg, batch: int, seq: int) -> Future:
        """Route one query to its shard; fingerprint computed ONCE here."""
        fp, replica = self.route(cfg)
        return replica.submit(cfg, batch, seq, fp=fp)

    def submit_many(self, queries: Sequence) -> List[Future]:
        """Fan a wave out: one enqueue (-> one tick wake) per replica.

        Futures come back in input order; each replica's worker
        coalesces its whole partition into one concurrent micro-batch.
        """
        qs = [q if isinstance(q, Query) else Query(*q) for q in queries]
        qs = [q if q.fp is not None
              else dataclasses.replace(q, fp=config_fingerprint(q.cfg))
              for q in qs]
        futs: List[Optional[Future]] = [None] * len(qs)
        parts: Dict[str, Tuple[List[int], List[Query]]] = {}
        for i, q in enumerate(qs):
            idxs, part = parts.setdefault(self.ring.route(q.fp), ([], []))
            idxs.append(i)
            part.append(q)
        for name, (idxs, part) in parts.items():
            for i, fut in zip(idxs, self._by_name[name].submit_many(part)):
                futs[i] = fut
        return futs  # type: ignore[return-value]

    def predict_one(self, cfg, batch: int, seq: int,
                    timeout: Optional[float] = None) -> Dict:
        return self.submit(cfg, batch, seq).result(timeout)

    def predict_many(self, queries: Sequence,
                     timeout: Optional[float] = None) -> List[Dict]:
        return [f.result(timeout) for f in self.submit_many(queries)]

    # -- feedback loop ------------------------------------------------------
    def observe(self, cfg, batch: int, seq: int, time_s: float,
                mem_bytes: float, **kw) -> None:
        """Report a completion to the replica that owns the config.

        The observation lands in the owning replica's ``FeedbackStore``
        slice (and its calibration window); the central refitter pulls
        it on its next federated sync. ``notify()`` keeps that sync
        prompt without the frontend doing any merging inline.
        """
        fp = kw.pop("fp", None) or config_fingerprint(cfg)
        self.replica_for(fp).observe(cfg, batch, seq, time_s, mem_bytes,
                                     fp=fp, **kw)
        if self.refitter is not None:
            self.refitter.notify()

    def sync_feedback(self) -> int:
        """Merge every replica's feedback slice into the central store."""
        if self.feedback is None:
            raise ValueError("no central feedback store "
                             "(construct with feedback_root=...)")
        return sum(self.feedback.merge(r.feedback) for r in self.replicas
                   if r.feedback is not None)

    # -- model generations --------------------------------------------------
    def publish_generation(self, gen) -> bool:
        """Broadcast a generation to every replica (tick-boundary applied)."""
        if self.publisher is None:
            self.publisher = GenerationPublisher(self.replicas)
        return self.publisher.publish_generation(gen)

    def attach_refitter(self, refitter: OnlineRefitter) -> OnlineRefitter:
        """Wire a central refitter into the fleet's publish path."""
        self.publisher = self.publisher or GenerationPublisher(self.replicas)
        refitter.add_sink(self.publisher)
        self.refitter = refitter
        return refitter

    def make_refitter(self, seed_records=None, **kw) -> OnlineRefitter:
        """Central ``OnlineRefitter`` over the fleet.

        Consumes the federated merge of every replica's
        ``FeedbackStore`` (``sources=``), resolves feedback keys
        against the owning shard's traces, and publishes each new
        generation to every replica via ``GenerationPublisher``.
        """
        if self.feedback is None:
            raise ValueError("central refit needs feedback_root=...")
        refitter = OnlineRefitter(
            self.replicas[0].service, self.feedback,
            seed_records=seed_records, traces=ShardedTraces(self),
            sources=[r.feedback for r in self.replicas
                     if r.feedback is not None], **kw)
        return self.attach_refitter(refitter)

    # -- introspection ------------------------------------------------------
    def server_info(self) -> Dict:
        per = {r.name: r.server_info() for r in self.replicas}
        fleet = self._sum_counters(per)
        fleet["queued"] = sum(p.get("queued", 0) for p in per.values())
        return {"replicas": len(self.replicas), "running": self.running,
                "fleet": fleet, "per_replica": per}

    @staticmethod
    def _sum_counters(per: Dict[str, Dict]) -> Dict:
        counters = [f.name for f in dataclasses.fields(ServerStats)]
        fleet = {c: sum(p.get(c, 0) for p in per.values()) for c in counters}
        # max_batch is a high-water mark, not additive
        fleet["max_batch"] = max((p.get("max_batch", 0)
                                  for p in per.values()), default=0)
        return fleet

    def stats(self) -> Dict:
        """Fleet-wide view: summed counters, merged calibration, refit."""
        per = {r.name: r.stats() for r in self.replicas}
        fleet = self._sum_counters(per)
        out = {
            "replicas": len(self.replicas),
            "fleet": fleet,
            "generations": sorted({r.service.generation
                                   for r in self.replicas}),
            "calibration": merge_calibration(
                [p.get("calibration", {}) for p in per.values()]),
            "per_replica": per,
        }
        if self.refitter is not None:
            out["refit"] = self.refitter.info()
        if self.publisher is not None:
            out["publisher"] = self.publisher.info()
        if self.feedback is not None:
            out["feedback"] = self.feedback.info()
        return out
