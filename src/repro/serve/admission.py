"""Admission bridge: streaming queries -> incremental GA placement (§4.3).

``PredictionService.schedule`` answers the paper's one-shot question —
place N known jobs on an empty cluster. A scheduler front door sees a
*stream*: queries arrive in waves while earlier admissions are still
running, so each wave must be placed against the cluster's **current**
load, not a blank slate.

``AdmissionController`` keeps that rolling state — per-machine committed
busy time and HBM reserved by resident jobs — and turns each wave of
queries into an incremental placement: estimates come from the
micro-batched ``AbacusServer`` (or a bare ``PredictionService``), jobs
whose predicted memory cannot fit any machine's *residual* HBM are
rejected up front, and the rest are placed by ``repro.core.scheduler``
with the committed load as the optimization baseline (``base_time`` /
``reserved_mem``). ``complete(job_id)`` releases a finished job's
reservation so later waves see the freed capacity.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.scheduler import Machine, jobs_from_estimates, schedule_jobs
from repro.serve.prediction_service import Query


@dataclasses.dataclass(frozen=True)
class Verdict:
    """One query's admission outcome."""
    job_id: str
    model: str
    admitted: bool
    machine: Optional[str]      # None iff rejected
    time_s: float
    mem_bytes: float
    reason: str = ""            # non-empty iff rejected

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


class AdmissionController:
    """Rolling cluster state + incremental placement for query streams.

    ``predictor`` is anything with ``predict_many(queries) -> [est]`` —
    an ``AbacusServer`` (micro-batched, the production path) or a
    ``PredictionService`` (synchronous). Thread-safe: concurrent
    ``admit``/``complete`` calls serialize on one lock so reservations
    never double-commit a machine's HBM.
    """

    # plans that return a per-job assignment; "random" returns trial
    # makespans (a baseline statistic), which admission cannot commit
    ASSIGNING_PLANS = ("ga", "optimal")

    #: completed-job summaries retained for idempotent re-reports
    COMPLETED_CACHE = 1024

    def __init__(self, predictor, machines: Sequence[Machine],
                 plan: str = "ga", time_scale: float = 1.0,
                 mem_pad: float = 0.0, metrics=None,
                 tenant_calibration=None, **plan_kw):
        if plan not in self.ASSIGNING_PLANS:
            raise ValueError(
                f"plan {plan!r} does not produce an assignment; "
                f"choose from {self.ASSIGNING_PLANS}")
        # admission outcomes on the shared registry (the predictor's, if
        # it has one): exposed alongside the serving metrics so operator
        # dashboards see admit/reject rates next to query latency.
        from repro.obs.metrics import MetricsRegistry
        self.metrics = (metrics if metrics is not None
                        else getattr(predictor, "metrics", None)
                        or MetricsRegistry())
        self._c_admitted = self.metrics.counter("admission_admitted_total")
        self._c_rejected = self.metrics.counter("admission_rejected_total")
        self._c_completions = self.metrics.counter(
            "admission_completions_total")
        self._h_wave = self.metrics.histogram(
            "admission_wave_seconds",
            help="wall time to place one wave of queries")
        self.predictor = predictor
        self.machines = list(machines)
        self.plan = plan
        self.time_scale = float(time_scale)
        self.mem_pad = float(mem_pad)
        self.plan_kw = dict(plan_kw)
        self._busy = np.zeros(len(self.machines))      # committed time
        self._reserved = np.zeros(len(self.machines))  # committed HBM
        # job_id -> (m_idx, Job, Query, estimate): the query/estimate pair
        # is kept so a completion report can feed the measured outcome —
        # joined with what we *predicted* — back into the refit loop.
        self._resident: Dict[str, tuple] = {}
        # job_id -> completion summary: duplicate report_completion calls
        # (a retried caller) get the cached summary instead of a
        # double-release; bounded so long-lived controllers don't leak.
        self._completed: "OrderedDict[str, Dict]" = OrderedDict()
        # per-tenant drift source for reservation inflation: explicit, or
        # borrowed from the predictor (the AbacusServer gateway owns one)
        self.tenant_calibration = (
            tenant_calibration if tenant_calibration is not None
            else getattr(predictor, "tenant_calibration", None))
        self._ids = itertools.count()
        self._lock = threading.Lock()

    # -- admission ----------------------------------------------------------
    def admit(self, queries: Sequence) -> List[Verdict]:
        """Place one wave of queries against current cluster state."""
        qs = [q if isinstance(q, Query) else Query(*q) for q in queries]
        if not qs:
            return []
        t_wave = time.perf_counter()
        ests = self.predictor.predict_many(qs)
        names = [f"{e['model']}#{next(self._ids)}" for e in ests]
        times = [e["time_s"] for e in ests]
        mems = [e["memory_bytes"] for e in ests]
        if self.tenant_calibration is not None:
            # inflate reservations by each tenant's own observed drift:
            # a tenant whose jobs run hotter than predicted reserves
            # proportionally more, instead of starving its neighbours.
            for i, q in enumerate(qs):
                tenant = getattr(q, "tenant", "")
                if not tenant:
                    continue
                times[i] *= self.tenant_calibration.inflation(tenant, "time")
                mems[i] *= self.tenant_calibration.inflation(tenant, "mem")
        jobs = jobs_from_estimates(
            names, times, mems,
            time_scale=self.time_scale, mem_pad=self.mem_pad)
        with self._lock:
            # reject jobs no machine can host at current residual HBM —
            # the placement plans treat them as globally infeasible.
            placeable, verdicts = [], [None] * len(jobs)
            for i, job in enumerate(jobs):
                residual = [m.hbm_bytes - self._reserved[k]
                            for k, m in enumerate(self.machines)]
                if job.mem_bytes <= max(residual):
                    placeable.append(i)
                else:
                    verdicts[i] = Verdict(
                        job_id=job.name, model=ests[i]["model"],
                        admitted=False, machine=None,
                        time_s=jobs[i].time_s, mem_bytes=job.mem_bytes,
                        reason=f"needs {job.mem_bytes:.3g} B; max residual "
                               f"HBM {max(residual):.3g} B")
            if placeable:
                sub = [jobs[i] for i in placeable]
                _, assign = schedule_jobs(
                    sub, self.machines, plan=self.plan,
                    base_time=self._busy.copy(),
                    reserved_mem=self._reserved.copy(), **self.plan_kw)
                for i, a in zip(placeable, assign):
                    a = int(a)
                    # guard: a stochastic plan (GA) can hand back an
                    # assignment violating residual HBM, and commits
                    # earlier in this wave shrink it further — repair
                    # onto the least-busy machine that can still host
                    # the job, or reject if none remains.
                    job = jobs[i]
                    if (job.mem_bytes + self._reserved[a]
                            > self.machines[a].hbm_bytes):
                        ok = [k for k, mc in enumerate(self.machines)
                              if job.mem_bytes + self._reserved[k]
                              <= mc.hbm_bytes]
                        if not ok:
                            verdicts[i] = Verdict(
                                job_id=job.name, model=ests[i]["model"],
                                admitted=False, machine=None,
                                time_s=job.time_s, mem_bytes=job.mem_bytes,
                                reason="no residual HBM after earlier "
                                       "placements in this wave")
                            continue
                        a = min(ok, key=lambda k: self._busy[k])
                    m = self.machines[a]
                    self._busy[a] += job.time_s / m.speed
                    self._reserved[a] += job.mem_bytes
                    self._resident[job.name] = (a, job, qs[i], ests[i])
                    verdicts[i] = Verdict(
                        job_id=job.name, model=ests[i]["model"],
                        admitted=True, machine=m.name,
                        time_s=job.time_s, mem_bytes=job.mem_bytes)
            n_adm = sum(1 for v in verdicts if v.admitted)
            self._c_admitted.inc(n_adm)
            self._c_rejected.inc(len(verdicts) - n_adm)
        self._h_wave.observe(time.perf_counter() - t_wave)
        return verdicts

    def complete(self, job_id: str) -> None:
        """Release a finished job's time/memory reservation (no feedback)."""
        self.report_completion(job_id)

    def report_completion(self, job_id: str,
                          time_s: Optional[float] = None,
                          mem_bytes: Optional[float] = None) -> Dict:
        """Finish a job: free its reservation AND stream its measured cost.

        Releasing the reservation is unconditional — the cluster state
        must return to baseline once every admitted job completes, with
        or without measurements. ``time_s``/``mem_bytes`` are measured
        in the *verdict* domain (what the caller was told to expect:
        predictor estimate x ``time_scale``, + ``mem_pad``); they are
        normalized back to the predictor's per-step domain before
        feeding the loop, so calibration and refit targets stay
        commensurate with the ensembles' outputs. When the predictor
        exposes ``observe`` (the ``AbacusServer`` gateway), the
        observation — joined with the prediction that admitted the job
        and the generation that made it — feeds the online refit loop.
        Returns a small completion summary (predicted vs measured, raw
        domain). Idempotent: a duplicate report (a retried caller whose
        first call already landed) returns the cached summary without
        releasing the reservation a second time; a job this controller
        never admitted still raises ``KeyError``.
        """
        with self._lock:
            if job_id not in self._resident:
                cached = self._completed.get(job_id)
                if cached is not None:
                    return dict(cached)
                raise KeyError(f"unknown or already-completed job {job_id!r}")
            k, job, query, est = self._resident.pop(job_id)
            self._busy[k] = max(0.0, self._busy[k]
                                - job.time_s / self.machines[k].speed)
            self._reserved[k] = max(0.0, self._reserved[k] - job.mem_bytes)
            self._c_completions.inc()
            raw_t = (None if time_s is None
                     else float(time_s) / self.time_scale)
            raw_m = (None if mem_bytes is None
                     else max(0.0, float(mem_bytes) - self.mem_pad))
            summary = {"job_id": job_id, "machine": self.machines[k].name,
                       "predicted_time_s": est["time_s"],
                       "predicted_mem_bytes": est["memory_bytes"],
                       "measured_time_s": raw_t, "measured_mem_bytes": raw_m,
                       "generation": est.get("generation"), "observed": False}
            # cache the summary before dropping the lock: a concurrent
            # duplicate must either pop the reservation (it can't — we
            # just did) or find the cache populated.
            self._completed[job_id] = summary
            while len(self._completed) > self.COMPLETED_CACHE:
                self._completed.popitem(last=False)
        observe = getattr(self.predictor, "observe", None)
        # non-positive normalized measurements (e.g. measured mem below
        # mem_pad) carry no calibration signal and would poison the
        # window (inf MRE) and the refit targets (log(~0)): release the
        # reservation but do not observe.
        if (observe is not None and raw_t is not None and raw_m is not None
                and raw_t > 0.0 and raw_m > 0.0):
            kw = {}
            tenant = getattr(query, "tenant", "")
            if tenant:
                kw["tenant"] = tenant
            observe(query.cfg, query.batch, query.seq, raw_t, raw_m,
                    predicted_time_s=est["time_s"],
                    predicted_mem_bytes=est["memory_bytes"],
                    generation=est.get("generation"), job_id=job_id, **kw)
            summary["observed"] = True
        return dict(summary)

    # -- introspection ------------------------------------------------------
    def cluster_state(self) -> Dict:
        with self._lock:
            return {
                "machines": [
                    {"name": m.name,
                     "busy_s": float(self._busy[k]),
                     "reserved_bytes": float(self._reserved[k]),
                     "residual_bytes": float(m.hbm_bytes - self._reserved[k]),
                     "jobs": sorted(j for j, (a, *_) in
                                    self._resident.items() if a == k)}
                    for k, m in enumerate(self.machines)],
                "resident_jobs": len(self._resident),
                "makespan_s": float(self._busy.max()) if len(self._busy)
                              else 0.0,
            }
