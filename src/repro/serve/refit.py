"""Online refit: stream measured costs back into the DNNAbacus ensembles.

The serving stack admits jobs from predictions fit *offline*; once the
fleet drifts (new kernels, thermal throttling, contended hosts), those
predictions go stale and nothing corrects them. ``OnlineRefitter``
closes the loop:

  1. finished jobs report measured ``(time, mem)`` into a
     ``FeedbackStore`` (``AdmissionController.report_completion`` ->
     ``AbacusServer.observe``),
  2. when enough fresh feedback accrues (count or staleness threshold),
     the refitter joins each observation with its traced
     ``ProfileRecord`` (same ``(fingerprint, batch, seq)`` key, resolved
     from the service's memory cache or the persistent ``TraceStore``),
     overwrites the record's targets with the measured costs, and refits
     the ensembles on seed records + feedback via ``DNNAbacus.refit``
     (which reuses the currently selected model architectures instead of
     re-searching the full pool),
  3. the result is published as an immutable, monotonically numbered
     ``ModelGeneration``; sinks (``AbacusServer`` — which applies the
     swap *between* micro-batch ticks — or a bare ``PredictionService``)
     adopt it, invalidating their per-generation prediction caches
     while keeping every persisted trace.

The refitter can run as a background worker (``start``/``stop`` or the
context manager: a daemon thread wakes on ``notify()`` and on a
staleness timer) or be driven synchronously with ``refit_now()``.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence

from repro.core.features import ProfileRecord
from repro.obs import events
from repro.serve.feedback_store import FeedbackStore, StoreKey


@dataclasses.dataclass(frozen=True)
class ModelGeneration:
    """One immutable published predictor version."""
    number: int
    abacus: object = dataclasses.field(repr=False, compare=False)
    n_feedback: int = 0         # observations the refit consumed
    n_train_records: int = 0    # seed + feedback records it was fit on
    n_unresolved: int = 0       # observations skipped (no trace for key)
    source: str = "refit"       # "seed" for generation 0
    created_at: float = 0.0

    def summary(self) -> Dict:
        return {"number": self.number, "source": self.source,
                "n_feedback": self.n_feedback,
                "n_train_records": self.n_train_records,
                "n_unresolved": self.n_unresolved,
                "created_at": self.created_at}


class OnlineRefitter:
    """Threshold-triggered ensemble refit + generation publisher.

    ``service`` is the ``PredictionService`` whose predictor is being
    refit (its memory cache and backing ``TraceStore`` resolve feedback
    keys to traced records; it is also the default publish sink when no
    other sink registers). ``feedback`` is the ``FeedbackStore`` the
    completion reports land in.

    ``min_observations`` fresh observations — or any fresh observation
    older than ``max_staleness_s`` — arm ``should_refit``. Seed records
    keep the refit anchored on the offline profile set; with
    ``replace_seed`` (default) seed records whose
    ``(model, batch, input)`` identity collides with a feedback record
    are dropped, so measured costs *replace* stale profiles instead of
    fighting them, and ``feedback_repeat`` replicates feedback records
    to upweight fresh measurements against a large seed set.
    """

    def __init__(self, service, feedback: FeedbackStore,
                 seed_records: Optional[Sequence[ProfileRecord]] = None,
                 traces=None, min_observations: int = 8,
                 max_staleness_s: Optional[float] = None,
                 replace_seed: bool = True, feedback_repeat: int = 1,
                 min_train_records: int = 4, val_frac: float = 0.2,
                 obs_window: int = 32,
                 sources: Optional[Sequence[FeedbackStore]] = None):
        self.service = service
        self.feedback = feedback
        self.seed_records = list(seed_records or [])
        self.traces = traces  # optional extra source with .get(key)
        # federated inputs: per-replica FeedbackStores whose contents are
        # merged into `feedback` before every threshold check, so one
        # central refitter consumes the whole fleet's observations.
        # merge() is commutative+idempotent, so repeated syncs converge.
        self.sources = list(sources or [])
        self.synced = 0  # observations imported from sources so far
        # sum of source totals at the last sync: a full federated merge
        # re-parses every key file of every source, so routine
        # should_refit() polls skip it unless some source's O(1) cached
        # count moved since the last sync (see sync_sources)
        self._source_mark: Optional[int] = None
        self.min_observations = int(min_observations)
        self.max_staleness_s = max_staleness_s
        self.replace_seed = bool(replace_seed)
        self.feedback_repeat = max(1, int(feedback_repeat))
        self.min_train_records = int(min_train_records)
        self.val_frac = float(val_frac)
        # refit targets average only each key's newest obs_window
        # observations (by timestamp): when reality drifts AGAIN, fresh
        # measurements must displace the old regime instead of blending
        # with it forever.
        self.obs_window = max(1, int(obs_window))

        self.generation = ModelGeneration(
            number=int(getattr(service, "generation", 0)),
            abacus=service.abacus, source="seed",
            n_train_records=len(self.seed_records), created_at=time.time())
        self.refits = 0
        self.refit_failures = 0
        self.publish_failures = 0
        self.last_refit_s: Optional[float] = None

        # observations persisted by PRIOR processes count as fresh: the
        # documented "later refit pass" (e.g. over a dryrun-populated
        # store) must consume them, not silently skip to the watermark.
        self._consumed = 0
        self._fresh_since: Optional[float] = None
        self._kick = False  # latched notify(): never miss a pre-wait wakeup
        # total() at the last NO-PROGRESS attempt (all feedback
        # unresolvable / too little to fit): until the count moves or a
        # notify() arrives, should_refit() stays False so the staleness
        # poll cannot re-run a doomed full-store scan every interval.
        self._stuck_at: Optional[int] = None
        self._sinks: List[object] = []
        self._cond = threading.Condition()
        self._refit_lock = threading.Lock()  # one refit at a time
        self._worker: Optional[threading.Thread] = None
        self._running = False

    # -- sinks --------------------------------------------------------------
    def add_sink(self, sink) -> "OnlineRefitter":
        """Register a generation consumer (``publish_generation(gen)``)."""
        with self._cond:
            if sink not in self._sinks:
                self._sinks.append(sink)
        return self

    def _publish(self, gen: ModelGeneration) -> None:
        with self._cond:
            sinks = list(self._sinks)
        if not sinks:  # default: the service adopts directly
            self.service.adopt(gen.abacus, gen.number)
            return
        for sink in sinks:
            try:
                sink.publish_generation(gen)
            except Exception:
                # a failing sink (e.g. a future remote gateway) must not
                # swallow the generation for the others, and must be
                # visible in info() — not silently dropped.
                self.publish_failures += 1

    # -- triggering ---------------------------------------------------------
    def notify(self) -> None:
        """New feedback arrived: stamp staleness clock, wake the worker."""
        with self._cond:
            if self._fresh_since is None:
                self._fresh_since = time.time()
            self._kick = True
            self._stuck_at = None  # fresh signal: a retry may now progress
            self._cond.notify_all()

    def set_sources(self, sources: Sequence[FeedbackStore]) -> None:
        """Swap the federated source list (live fleet resharding).

        The change detector mark is reset so the next ``sync_sources``
        scans unconditionally — a replica that just joined may carry
        merged observations the old mark would wrongly skip.
        """
        with self._cond:
            self.sources = list(sources or [])
            self._source_mark = None

    def sync_sources(self, force: bool = False) -> int:
        """Federated merge: pull every source store into ``feedback``.

        Returns how many observations were new to the central store.
        Safe to call at any time from any thread (merge is idempotent);
        called automatically before each ``should_refit`` evaluation so
        fleet-wide feedback counts toward the refit thresholds.

        The merge itself re-parses every key file of every source, so
        it is gated on a cheap change detector: the sum of the sources'
        O(1) cached ``total()``s. Sources are the fleet's *in-process*
        replica slices (their counters track every local add), so an
        unchanged sum means nothing new to pull and the scan is
        skipped; ``force=True`` (the explicit ``refit_now(force=True)``
        path) always scans, which also picks up writes landed by other
        processes.
        """
        try:
            mark = sum(src.total() for src in self.sources)
        except Exception:
            mark = None  # a source can't even count: scan to find out
        with self._cond:
            if not force and mark is not None and mark == self._source_mark:
                return 0
        imported = 0
        for src in self.sources:
            try:
                imported += self.feedback.merge(src)
            except Exception:
                # a torn/unreadable source (e.g. a remote replica's
                # store mid-copy) must not take down the refit loop;
                # merge is retried on the next sync anyway.
                continue
        with self._cond:
            self._source_mark = mark
        if imported:
            self.synced += imported
        return imported

    def fresh_observations(self) -> int:
        return max(0, self.feedback.total() - self._consumed)

    def should_refit(self) -> bool:
        if self.sources:
            self.sync_sources()
        fresh = self.fresh_observations()
        if fresh <= 0:
            return False
        with self._cond:
            if self._stuck_at is not None \
                    and self.feedback.total() == self._stuck_at:
                return False  # last attempt made no progress; wait for news
        if fresh >= self.min_observations:
            return True
        if self.max_staleness_s is not None:
            with self._cond:
                since = self._fresh_since
            if since is None:  # feedback written without notify()
                since = self.feedback.oldest_ts()
            if since is not None:
                return time.time() - since >= self.max_staleness_s
        return False

    # -- record resolution ---------------------------------------------------
    def _resolve(self, key: StoreKey) -> Optional[ProfileRecord]:
        """Traced ProfileRecord for a feedback key, or None."""
        rec = self.service.cached_record(key)
        if rec is not None:
            return rec
        for source in (self.traces, getattr(self.service, "store", None)):
            if source is None:
                continue
            try:
                rec = source.get(key)
            except Exception:
                rec = None
            if rec is not None:
                return rec
        return None

    @staticmethod
    def _identity(rec: ProfileRecord):
        return (rec.model_name, rec.batch_size, rec.input_size)

    def training_records(self):
        """(records, n_feedback_consumed, n_unresolved) for the next refit."""
        fb_records, unresolved, consumed = [], 0, 0
        for key, observations in sorted(self.feedback.grouped().items()):
            consumed += len(observations)
            rec = self._resolve(key)
            if rec is None:
                unresolved += len(observations)
                continue
            window = observations[-self.obs_window:]  # newest (ts-sorted)
            t = sum(o.time_s for o in window) / len(window)
            m = sum(o.mem_bytes for o in window) / len(window)
            fb_records.append(dataclasses.replace(
                rec, time_s=float(t), mem_bytes=float(m)))
        seeds = self.seed_records
        if self.replace_seed and fb_records:
            stale = {self._identity(r) for r in fb_records}
            seeds = [r for r in seeds if self._identity(r) not in stale]
        records = list(seeds) + fb_records * self.feedback_repeat
        return records, consumed, unresolved

    # -- refit ---------------------------------------------------------------
    def refit_now(self, force: bool = False) -> Optional[ModelGeneration]:
        """Refit + publish one generation; None when below thresholds.

        ``force`` skips the count/staleness thresholds (still requires
        at least one resolvable feedback record).
        """
        with self._refit_lock:
            if force and self.sources:
                # should_refit (the guarded sync) is skipped on this
                # path: scan unconditionally so an explicit force also
                # sees observations landed by other processes
                self.sync_sources(force=True)
            if not force and not self.should_refit():
                return None
            records, consumed, unresolved = self.training_records()
            if (consumed == unresolved
                    or len(records) < self.min_train_records):
                with self._cond:  # no progress: park until the count moves
                    self._stuck_at = consumed
                return None  # nothing resolvable (or too little) to fit on
            t0 = time.perf_counter()
            try:
                abacus = self.generation.abacus.refit(
                    records, val_frac=self.val_frac)
            except Exception:
                self.refit_failures += 1
                events.emit("refit_failed", generation=self.generation.number)
                raise
            self.last_refit_s = time.perf_counter() - t0
            gen = ModelGeneration(
                number=self.generation.number + 1, abacus=abacus,
                n_feedback=consumed - unresolved,
                n_train_records=len(records), n_unresolved=unresolved,
                created_at=time.time())
            self.generation = gen
            self.refits += 1
            with self._cond:
                self._consumed = consumed
                self._fresh_since = None
        events.emit("refit", generation=gen.number,
                    n_feedback=gen.n_feedback,
                    n_train_records=gen.n_train_records,
                    duration_s=self.last_refit_s)
        self._publish(gen)
        return gen

    # -- background worker ---------------------------------------------------
    def start(self) -> "OnlineRefitter":
        with self._cond:
            if self._running:
                return self
            self._running = True
        self._worker = threading.Thread(target=self._loop,
                                        name="abacus-refit", daemon=True)
        self._worker.start()
        return self

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        with self._cond:
            self._running = False
            self._cond.notify_all()
        worker, self._worker = self._worker, None
        if worker is not None:
            worker.join(timeout)

    def __enter__(self) -> "OnlineRefitter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _loop(self) -> None:
        # staleness forces periodic re-checks even without notify()
        poll = (None if self.max_staleness_s is None
                else max(0.01, self.max_staleness_s / 4.0))
        while True:
            with self._cond:
                if not self._running:
                    return
                # every attempt is gated on a notify() (latched in _kick,
                # so a wakeup arriving before this wait is never lost) or
                # the staleness poll. A refit that makes no progress —
                # all feedback unresolvable, or a raising fit — therefore
                # parks here instead of busy-spinning full-store scans.
                if not self._kick:
                    self._cond.wait(timeout=poll)
                self._kick = False
                if not self._running:
                    return
            try:
                if self.should_refit():
                    self.refit_now()
            except Exception:
                pass  # counted in refit_failures; the worker must survive

    # -- introspection -------------------------------------------------------
    def info(self) -> Dict:
        return {"generation": self.generation.summary(),
                "refits": self.refits,
                "refit_failures": self.refit_failures,
                "publish_failures": self.publish_failures,
                "sources": len(self.sources),
                "synced": self.synced,
                "last_refit_s": self.last_refit_s,
                "fresh_observations": self.fresh_observations(),
                "min_observations": self.min_observations,
                "max_staleness_s": self.max_staleness_s,
                "running": self._running}
