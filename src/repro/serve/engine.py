"""Serving: prefill / decode step factories and cache shardings.

``decode_step`` is the unit the ``decode_*`` / ``long_*`` dry-run cells
lower: one new token against a KV (or SSD state) cache of the stated
length. The KV cache is sharded batch->data and cache_seq->model — the
flash-decoding split: each model shard attends over its sequence slice
and GSPMD combines the partial softmax statistics.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd


def make_prefill_step(model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model):
    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)
    return decode_step


def param_shardings(model, mesh: Mesh,
                    rules: Optional[shd.ShardingRules] = None,
                    fsdp_params: bool = False):
    rules = rules or shd.ShardingRules()
    axes = model.param_axes()
    shapes = model.init_shape()
    if fsdp_params:  # giant models: params sharded over data axes too
        from repro.distributed import zero as zero_lib
        axes = zero_lib.zero_axes(axes, shapes, mesh, rules)
        rules = zero_lib.zero_rules(rules)
    return shd.tree_shardings(mesh, axes, shapes, rules)


def cache_shardings(model, mesh: Mesh, batch: int, seq: int,
                    rules: Optional[shd.ShardingRules] = None):
    rules = rules or shd.ShardingRules()
    shapes, axes = model.cache_spec(batch, seq)
    return shd.tree_shardings(mesh, axes, shapes, rules), shapes


class DecodeEngine:
    """Minimal batched serving engine (examples / integration tests).

    Holds params + cache on device, runs greedy decode with per-request
    positions — the single-host stand-in for the continuous-batching
    frontend described in DESIGN.md.
    """

    def __init__(self, model, params, batch: int, max_seq: int, mesh=None):
        self.model = model
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.cache = model.init_cache(batch, max_seq)
        self.pos = jnp.zeros((batch,), jnp.int32)
        self._decode = jax.jit(make_decode_step(model), donate_argnums=(1,))

    def prefill(self, batch_inputs):
        logits, cache = jax.jit(make_prefill_step(self.model))(
            self.params, batch_inputs)
        self.cache = cache
        self.pos = jnp.full((self.batch,), batch_inputs["tokens"].shape[1],
                            jnp.int32)
        return jnp.argmax(logits[:, -1], axis=-1)

    def step(self, tokens):
        logits, self.cache = self._decode(self.params, self.cache,
                                          tokens[:, None], self.pos)
        self.pos = self.pos + 1
        return jnp.argmax(logits[:, -1], axis=-1)

    def generate(self, first_tokens, steps: int):
        toks = first_tokens
        out = [toks]
        for _ in range(steps):
            toks = self.step(toks)
            out.append(toks)
        return jnp.stack(out, axis=1)
