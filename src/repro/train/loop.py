"""Training loop: jit + shardings + checkpoint/restart + fault tolerance.

The loop is deliberately host-driven and restartable: all state lives in
the (atomic) checkpoint, the data stream is deterministic in step, and
the mesh shape may change between runs (elastic restart) because restores
re-shard. ``run()`` returns the metrics history for tests/benchmarks.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt_lib
from repro.data.pipeline import ShardedLoader, SyntheticLM
from repro.distributed import sharding as shd
from repro.ft.runtime import FTConfig, StepRunner
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib


@dataclasses.dataclass
class LoopConfig:
    steps: int = 20
    batch: int = 8
    seq: int = 128
    ckpt_every: int = 10
    log_every: int = 5
    ckpt_dir: Optional[str] = None
    seed: int = 0
    zero: bool = True
    accum: int = 1
    predicted_step_s: Optional[float] = None  # DNNAbacus admission/straggler


class Trainer:
    def __init__(self, model, opt_cfg: opt_lib.OptConfig, loop_cfg: LoopConfig,
                 mesh=None, rules: Optional[shd.ShardingRules] = None,
                 source=None):
        self.model = model
        self.opt_cfg = opt_cfg
        self.cfg = loop_cfg
        self.mesh = mesh
        self.rules = rules or shd.ShardingRules()
        self.metrics_log: List[Dict[str, Any]] = []
        self.source = source or SyntheticLM(
            model.cfg.vocab_size, loop_cfg.batch, loop_cfg.seq, loop_cfg.seed)

        step_fn = step_lib.make_train_step(model, opt_cfg, accum=loop_cfg.accum)
        if mesh is not None:
            self.state_sh = step_lib.state_shardings(
                model, opt_cfg, mesh, self.rules, zero=loop_cfg.zero)
            sample = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                self.source.batch_at(0))
            self.batch_sh = step_lib.batch_shardings(mesh, sample, self.rules)
            self.jstep = jax.jit(step_fn, in_shardings=(self.state_sh, self.batch_sh),
                                 donate_argnums=(0,))
        else:
            self.state_sh = None
            self.batch_sh = None
            self.jstep = jax.jit(step_fn, donate_argnums=(0,))
        self.runner = StepRunner(self.jstep, FTConfig(),
                                 predicted_step_s=loop_cfg.predicted_step_s)

    # -- state management -------------------------------------------------
    def init_state(self):
        state = step_lib.init_state(self.model, self.opt_cfg,
                                    jax.random.key(self.cfg.seed))
        if self.state_sh is not None:
            state = jax.tree.map(jax.device_put, state, self.state_sh)
        return state

    def restore_or_init(self):
        d = self.cfg.ckpt_dir
        if d:
            step = ckpt_lib.latest_step(d)
            if step is not None:
                like = step_lib.state_shapes(self.model, self.opt_cfg)
                state = ckpt_lib.restore(d, step, like, self.state_sh)
                return state, step
        return self.init_state(), 0

    # -- main loop ---------------------------------------------------------
    def run(self, steps: Optional[int] = None) -> List[Dict[str, Any]]:
        steps = steps if steps is not None else self.cfg.steps
        state, start = self.restore_or_init()
        loader = ShardedLoader(self.source, self.batch_sh, start_step=start)
        try:
            for i in range(start, steps):
                batch = next(loader)
                t0 = time.perf_counter()
                state, metrics = self.runner(state, batch)
                dt = time.perf_counter() - t0
                if i % self.cfg.log_every == 0 or i == steps - 1:
                    rec = {k: float(v) for k, v in metrics.items()}
                    rec.update(step=i, step_time_s=dt)
                    self.metrics_log.append(rec)
                if (self.cfg.ckpt_dir and self.cfg.ckpt_every
                        and (i + 1) % self.cfg.ckpt_every == 0):
                    ckpt_lib.save(self.cfg.ckpt_dir, i + 1, state)
            if self.cfg.ckpt_dir:
                ckpt_lib.save(self.cfg.ckpt_dir, steps, state)
        finally:
            loader.close()
        return self.metrics_log

    def write_log(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            for rec in self.metrics_log:
                f.write(json.dumps(rec) + "\n")
