"""AdamW with mixed-precision state, built for sharded pytrees.

Distributed-training memory tricks (all flag-controlled):
  - ``moment_dtype``: bf16 first/second moments (halves optimizer HBM);
  - ``keep_master``: fp32 master weights when compute params are bf16;
  - ZeRO-1 sharding of (m, v, master) is applied by the caller via
    ``repro.distributed.zero`` — this module is sharding-agnostic.

State layout (plain dict pytree, checkpoint-friendly):
  {"m": tree, "v": tree, "master": tree | None-like {}, "count": i32[]}
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"   # float32 | bfloat16
    keep_master: bool = True        # fp32 master when params are low-precision


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(1, cfg.decay_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def _mdt(cfg: OptConfig):
    return jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32


def init_opt_state(cfg: OptConfig, params) -> Any:
    mdt = _mdt(cfg)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    state = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }
    if cfg.keep_master and _needs_master(params):
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    else:
        state["master"] = {}
    return state


def opt_state_shapes(cfg: OptConfig, param_shapes) -> Any:
    """ShapeDtypeStruct tree of the optimizer state (for AOT lowering)."""
    mdt = _mdt(cfg)
    sds = lambda p, dt: jax.ShapeDtypeStruct(p.shape, dt)
    state = {
        "m": jax.tree.map(lambda p: sds(p, mdt), param_shapes),
        "v": jax.tree.map(lambda p: sds(p, mdt), param_shapes),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if cfg.keep_master and _needs_master(param_shapes):
        state["master"] = jax.tree.map(lambda p: sds(p, jnp.float32), param_shapes)
    else:
        state["master"] = {}
    return state


def _needs_master(params) -> bool:
    return any(leaf.dtype != jnp.float32 for leaf in jax.tree.leaves(params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: OptConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = schedule(cfg, state["count"])
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    mdt = _mdt(cfg)

    bc1 = 1 - cfg.beta1 ** count.astype(jnp.float32)
    bc2 = 1 - cfg.beta2 ** count.astype(jnp.float32)
    have_master = bool(state["master"])

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32) * cfg.beta1 + (1 - cfg.beta1) * g
        v32 = v.astype(jnp.float32) * cfg.beta2 + (1 - cfg.beta2) * g * g
        mh = m32 / bc1
        vh = v32 / bc2
        base = master.astype(jnp.float32) if master is not None else p.astype(jnp.float32)
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * base
        new_master = base - lr * step
        return new_master, m32.astype(mdt), v32.astype(mdt)

    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(state["m"])
    v_leaves = treedef.flatten_up_to(state["v"])
    master_leaves = (treedef.flatten_up_to(state["master"]) if have_master
                     else [None] * len(p_leaves))

    new_master, new_m, new_v, new_p = [], [], [], []
    for p, g, m, v, ms in zip(p_leaves, g_leaves, m_leaves, v_leaves, master_leaves):
        nm_master, nm, nv = upd(p, g, m, v, ms)
        new_m.append(nm)
        new_v.append(nv)
        new_p.append(nm_master.astype(p.dtype))
        new_master.append(nm_master)

    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = {
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "count": count,
        "master": (jax.tree.unflatten(treedef, new_master) if have_master else {}),
    }
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
