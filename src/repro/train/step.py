"""Jittable train / serve steps and their sharding trees.

``make_train_step(model, opt_cfg)`` returns ``step(state, batch)`` where
``state = {"params", "opt": {m, v, master, count}, "step"}``.

``state_shardings`` builds the NamedSharding tree: params follow the
model's logical axes; optimizer state follows the ZeRO-rewritten axes
(additionally sharded over the data axes); scalars are replicated.

Optional gradient accumulation runs microbatches under ``jax.lax.scan``
(grads averaged in fp32), trading activation memory for step latency.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.distributed import zero as zero_lib
from repro.train import optimizer as opt_lib


def make_train_step(model, opt_cfg: opt_lib.OptConfig, accum: int = 1):
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if accum <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        split = lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:])
        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            acc, _ = carry
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / accum, acc, grads)
            return (acc, loss), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), _ = jax.lax.scan(body, (zeros, jnp.zeros((), jnp.float32)),
                                        micro)
        return loss, {"loss": loss}, grads

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]):
        loss, metrics, grads = compute_grads(state["params"], batch)
        new_params, new_opt, opt_metrics = opt_lib.apply_updates(
            opt_cfg, state["params"], grads, state["opt"])
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def init_state(model, opt_cfg: opt_lib.OptConfig, key):
    params = model.init(key)
    return {"params": params,
            "opt": opt_lib.init_opt_state(opt_cfg, params),
            "step": jnp.zeros((), jnp.int32)}


def state_shapes(model, opt_cfg: opt_lib.OptConfig):
    params = model.init_shape()
    return {"params": params,
            "opt": opt_lib.opt_state_shapes(opt_cfg, params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def state_shardings(model, opt_cfg: opt_lib.OptConfig, mesh: Mesh,
                    rules: Optional[shd.ShardingRules] = None,
                    zero: bool = True, scheme: str = "sp",
                    fsdp_params: bool = False):
    rules = shd.scheme_rules(scheme, rules)
    axes = model.param_axes()
    shapes = model.init_shape()
    if scheme == "dp":
        axes = shd.fsdp_axes(axes, shapes, mesh)
    if fsdp_params:  # giant models: params also sharded over (pod, data)
        axes = zero_lib.zero_axes(axes, shapes, mesh, rules)
        rules = zero_lib.zero_rules(rules)
    p_sh = shd.tree_shardings(mesh, axes, shapes, rules)
    if zero:
        zrules = zero_lib.zero_rules(rules)
        zaxes = zero_lib.zero_axes(axes, shapes, mesh, rules)
        z_sh = shd.tree_shardings(mesh, zaxes, shapes, zrules)
    else:
        z_sh = p_sh
    repl = NamedSharding(mesh, P())
    master = (jax.tree.map(lambda x: x, z_sh)
              if _has_master(model, opt_cfg) else {})
    return {
        "params": p_sh,
        "opt": {"m": z_sh, "v": z_sh, "count": repl, "master": master},
        "step": repl,
    }


def _has_master(model, opt_cfg) -> bool:
    return opt_cfg.keep_master and model.dtype != jnp.float32


def batch_shardings(mesh: Mesh, batch_shapes,
                    rules: Optional[shd.ShardingRules] = None):
    rules = rules or shd.ShardingRules()

    def leaf(sds):
        axes = ("batch",) + (None,) * (len(sds.shape) - 1)
        return NamedSharding(mesh, shd.resolve_spec(axes, sds.shape, mesh, rules))

    return jax.tree.map(leaf, batch_shapes)


def metric_shardings(mesh: Mesh, metrics_shape):
    repl = NamedSharding(mesh, P())
    return jax.tree.map(lambda _: repl, metrics_shape)
