"""Invariant oracles: cross-validate a replay against the telemetry plane.

Each oracle takes the :class:`~repro.scenarios.runner.ScenarioResult`
(which carries the runner's independently-counted ground truth *and* the
target's post-run ``stats()`` / ``metrics_snapshot()``) and returns an
:class:`OracleResult`. The point is mutual corroboration: the runner
never reads a server counter while replaying, the servers never see the
runner's ledger — so agreement means both are right, and a mutation of
either side (an undercounted metric, a dropped future) is caught.

Counter accounting across membership churn: replicas excluded or removed
mid-run take their counters with them, so ``ClusterFrontend`` keeps a
``retired`` ledger (``stats()["retired"]`` / ``fleet_retired_*_total``)
of everything a leaver had contributed at departure. All-time truth is
``fleet + retired``, which is what the exact oracles compare.

``observations`` is the one counter checked with ``>=`` instead of
``==`` when kills/resizes occurred: a drain-and-migrate can redeliver an
observe to the new owner after the old owner already counted it (benign
at-least-once delivery), so exactness only holds on a churn-free run.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.scenarios.runner import ScenarioResult
from repro.scenarios.workload import config_from_payload, scenario_trace
from repro.serve.server import ServerStats

#: legacy ``stats()`` surfaces consumers already scrape — presence is
#: itself an invariant (PR 7 promised new telemetry adds keys, never
#: renames these)
CLUSTER_STATS_KEYS = ("replicas", "fleet", "reshard", "generations",
                      "calibration", "per_replica", "stale_replicas")
RESHARD_KEYS = ("reshards", "keys_moved", "units_moved", "keys_skipped",
                "keys_replayed", "cutover_ticks", "hedges",
                "hedge_failures", "retries", "exclusions")


@dataclasses.dataclass
class OracleResult:
    name: str
    ok: bool
    detail: str = ""

    def __bool__(self) -> bool:
        return self.ok


def failed(results: List[OracleResult]) -> List[OracleResult]:
    """The subset of oracle results that did not hold."""
    return [r for r in results if not r.ok]


def _counter_total(result: ScenarioResult, name: str) -> int:
    """All-time fleet counter: live members + the retired ledger."""
    if result.is_cluster:
        live = int(result.stats_after["fleet"].get(name, 0) or 0)
        retired = int(result.stats_after.get("retired", {})
                      .get(name, 0) or 0)
        return live + retired
    return int(result.stats_after.get(name, 0) or 0)


def _metric_total(result: ScenarioResult, name: str) -> int:
    """All-time metric counter: merged snapshot + the retired series."""
    value = int(result.metrics_after.get(f"server_{name}_total", {})
                .get("value", 0) or 0)
    value += int(result.metrics_after.get(f"fleet_retired_{name}_total", {})
                 .get("value", 0) or 0)
    return value


# -- the oracles --------------------------------------------------------------


def oracle_all_resolved(result: ScenarioResult) -> OracleResult:
    """Every submitted future reached a *clean* terminal state.

    Served (including shed-degraded) or expired with a structured
    ``DeadlineExceeded`` both count; what must never happen is an
    untyped failure, an untyped rejection, or a future that simply
    never resolves. Quota rejections happen *before* ``submitted`` is
    counted, so they don't enter this identity.
    """
    g = result.ground
    ok = (g["failed"] == 0 and g["submit_rejected"] == 0
          and g["resolved"] + g["expired"] == g["submitted"]
          and g["submitted"] > 0)
    errors = [o.get("error") for o in result.outcomes.values()
              if not o.get("ok") and not o.get("expired")
              and not o.get("quota")]
    return OracleResult(
        "all_resolved", ok,
        f"submitted={g['submitted']} resolved={g['resolved']} "
        f"expired={g['expired']} failed={g['failed']} "
        f"rejected={g['submit_rejected']}"
        + (f" first_error={errors[0]}" if errors else ""))


def oracle_counters(result: ScenarioResult) -> OracleResult:
    """``stats()`` counters equal the runner's ground truth exactly."""
    g = result.ground
    churn_free = g["kills"] == 0 and g["resizes"] == 0
    probs: List[str] = []

    def expect(name: str, got: int, want: int, exact: bool = True) -> None:
        bad = got != want if exact else got < want
        if bad:
            probs.append(f"{name}: got {got}, want "
                         f"{'==' if exact else '>='} {want}")

    # frontend replay expiries never reached a replica (the parked
    # query was expired at the cutover instead of being replayed), so
    # they appear in the runner's submitted count but not the servers'.
    accepted = g["submitted"] - g["replay_expired"]
    expect("submitted", _counter_total(result, "submitted"), accepted)
    expect("completed+failed",
           _counter_total(result, "completed")
           + _counter_total(result, "failed"), accepted)
    expect("gen_swaps", _counter_total(result, "gen_swaps"),
           g["expected_gen_swaps"])
    expect("observations", _counter_total(result, "observations"),
           g["observes_issued"], exact=churn_free)
    if result.is_cluster:
        reshard = result.stats_after["reshard"]
        expect("exclusions", int(reshard.get("exclusions", 0)),
               g["expected_exclusions"])
        if not result.supports_hedge:
            expect("hedges", int(reshard.get("hedges", 0)), 0)
    return OracleResult("counters", not probs, "; ".join(probs) or "exact")


def oracle_metrics_parity(result: ScenarioResult) -> OracleResult:
    """``metrics_snapshot()`` series equal the same ground truth — the
    metrics plane and the stats plane cannot drift apart."""
    g = result.ground
    probs: List[str] = []

    def expect(name: str, got: int, want: int) -> None:
        if got != want:
            probs.append(f"{name}: got {got}, want {want}")

    expect("server_submitted_total", _metric_total(result, "submitted"),
           g["submitted"] - g["replay_expired"])
    expect("server_gen_swaps_total", _metric_total(result, "gen_swaps"),
           g["expected_gen_swaps"])
    if result.is_cluster:
        expect("fleet_exclusions_total",
               int(result.metrics_after.get("fleet_exclusions_total", {})
                   .get("value", 0) or 0), g["expected_exclusions"])
        if not result.supports_hedge:
            expect("fleet_hedges_total",
                   int(result.metrics_after.get("fleet_hedges_total", {})
                       .get("value", 0) or 0), 0)
    return OracleResult("metrics_parity", not probs,
                        "; ".join(probs) or "exact")


def oracle_legacy_stats(result: ScenarioResult) -> OracleResult:
    """The pre-telemetry ``stats()`` surface is still fully present."""
    stats = result.stats_after
    missing: List[str] = []
    if result.is_cluster:
        missing += [k for k in CLUSTER_STATS_KEYS if k not in stats]
        fleet = stats.get("fleet", {})
        missing += [f"fleet.{c}" for c in ServerStats.COUNTERS
                    if c not in fleet]
        reshard = stats.get("reshard", {})
        missing += [f"reshard.{k}" for k in RESHARD_KEYS
                    if k not in reshard]
    else:
        missing += [c for c in ServerStats.COUNTERS if c not in stats]
        if "calibration" not in stats:
            missing.append("calibration")
    return OracleResult("legacy_stats", not missing,
                        ("missing: " + ", ".join(missing)) if missing
                        else "all keys present")


def oracle_calibration(result: ScenarioResult) -> OracleResult:
    """Windowed calibration drift sits inside the schedule's bounds.

    Every observation reports measured = estimate x factor, so its drift
    is exactly ``1/factor - 1``; the rolling window's mean must land in
    ``[min, max]`` of the per-factor drifts (+- tolerance).
    """
    bounds = result.schedule.meta.get("drift", {})
    if result.ground["observes_issued"] == 0:
        return OracleResult("calibration", True, "no observations scheduled")
    cal = result.stats_after.get("calibration", {})
    if not cal.get("count"):
        return OracleResult("calibration", False,
                            "observations issued but window is empty")
    tol = float(bounds.get("tolerance", 0.05))
    probs: List[str] = []
    for axis, key in (("time", "time_drift"), ("mem", "mem_drift")):
        span = bounds.get(axis)
        if span is None:
            continue
        got = cal.get(key)
        if got is None or not (span[0] - tol <= got <= span[1] + tol):
            probs.append(f"{key}={got} outside "
                         f"[{span[0]:.4f}, {span[1]:.4f}] +- {tol}")
    return OracleResult("calibration", not probs,
                        "; ".join(probs) or
                        f"drift in bounds over {cal['count']} observations")


def oracle_estimate_parity(result: ScenarioResult) -> OracleResult:
    """Fleet answers == a fresh single-service replay of the same queries.

    For each generation the fleet served from, rebuild a bare
    ``PredictionService`` around that generation's abacus and re-predict
    every (cfg, batch, seq) the fleet answered under it. RandomForest
    predictions are per-row exact, so micro-batching, routing, hedging
    and resharding must not change a single estimate.
    """
    from repro.serve.prediction_service import PredictionService
    probs: List[str] = []
    checked = 0
    by_gen: Dict[int, Dict] = {}
    for o in result.resolved_outcomes():
        if o.get("degraded"):
            # shed answers come from the analytical roofline floor by
            # design — parity against the learned predictor is the one
            # property they intentionally give up
            continue
        gen = o.get("generation")
        key = (o["cfg"]["name"], o["batch"], o["seq"])
        by_gen.setdefault(gen, {})[key] = o
    for gen, queries in sorted(by_gen.items(), key=lambda e: (e[0] is None,
                                                              e[0] or 0)):
        abacus = result.generations.get(gen)
        if abacus is None:
            probs.append(f"generation {gen} served but never snapshotted")
            continue
        svc = PredictionService(abacus, tracer=scenario_trace)
        for o in queries.values():
            est = svc.predict_one(config_from_payload(o["cfg"]),
                                  o["batch"], o["seq"])
            checked += 1
            if (round(est["time_s"], 12) != round(o["time_s"], 12)
                    or round(est["memory_bytes"], 6)
                    != round(o["mem_bytes"], 6)
                    or est["model"] != o["model"]):
                probs.append(
                    f"gen={gen} {o['cfg']['name']}x{o['batch']}x{o['seq']}: "
                    f"fleet=({o['time_s']}, {o['mem_bytes']}) "
                    f"fresh=({est['time_s']}, {est['memory_bytes']})")
    return OracleResult("estimate_parity", not probs,
                        "; ".join(probs[:3]) or
                        f"{checked} unique (gen, query) estimates match")


def oracle_overload_accounting(result: ScenarioResult) -> OracleResult:
    """Shed / expired / quota accounting is *exact*, on both planes.

    The runner's ground truth (degraded estimates seen, typed
    ``DeadlineExceeded`` / ``QuotaExceeded`` outcomes) must equal the
    ``stats()["overload"]`` surface AND the metric series
    (``server_*_total`` + the retired ledger; ``fleet_replay_expired_
    total`` for frontend expiries that never reached a replica).
    Trivially true on scenarios that never overload — every side is 0.
    """
    g = result.ground
    probs: List[str] = []

    def expect(name: str, got: int, want: int) -> None:
        if got != want:
            probs.append(f"{name}: got {got}, want {want}")

    ov = result.stats_after.get("overload")
    if result.is_cluster:
        ov = ov if isinstance(ov, dict) else {}
        fleet = ov.get("fleet", {}) or {}
        retired = ov.get("retired", {}) or {}
        frontend = ov.get("frontend", {}) or {}

        def total(name: str) -> int:
            return (int(fleet.get(name, 0) or 0)
                    + int(retired.get(name, 0) or 0))

        expect("stats.shed", total("shed"), g["shed"])
        expect("stats.expired", total("expired"),
               g["expired"] - g["replay_expired"])
        expect("stats.quota_rejected", total("quota_rejected"),
               g["quota_rejected"])
        expect("stats.replay_expired",
               int(frontend.get("replay_expired", 0) or 0),
               g["replay_expired"])
        expect("fleet_replay_expired_total",
               int(result.metrics_after.get("fleet_replay_expired_total", {})
                   .get("value", 0) or 0), g["replay_expired"])
    else:
        ov = ov if isinstance(ov, dict) else {}
        expect("stats.shed", int(ov.get("shed", 0) or 0), g["shed"])
        expect("stats.expired", int(ov.get("expired", 0) or 0), g["expired"])
        expect("stats.quota_rejected",
               int(ov.get("quota_rejected", 0) or 0), g["quota_rejected"])
    expect("server_shed_total", _metric_total(result, "shed"), g["shed"])
    expect("server_expired_total", _metric_total(result, "expired"),
           g["expired"] - g["replay_expired"]
           if result.is_cluster else g["expired"])
    expect("server_quota_rejected_total",
           _metric_total(result, "quota_rejected"), g["quota_rejected"])
    return OracleResult("overload_accounting", not probs,
                        "; ".join(probs) or
                        f"shed={g['shed']} expired={g['expired']} "
                        f"quota_rejected={g['quota_rejected']} "
                        f"replay_expired={g['replay_expired']} (exact)")


ORACLES = (oracle_all_resolved, oracle_counters, oracle_metrics_parity,
           oracle_legacy_stats, oracle_calibration, oracle_estimate_parity,
           oracle_overload_accounting)


def check_all(result: ScenarioResult,
              raise_on_fail: bool = False) -> List[OracleResult]:
    """Run every oracle; optionally raise on the first violation."""
    out = [oracle(result) for oracle in ORACLES]
    if raise_on_fail:
        bad = failed(out)
        if bad:
            raise AssertionError("; ".join(f"{r.name}: {r.detail}"
                                           for r in bad))
    return out
