"""Schedule replay against a live fleet, with independent ground truth.

``ScenarioRunner`` replays a :class:`~repro.scenarios.workload.Schedule`
against an ``AbacusServer`` or ``ClusterFrontend`` (in-process or RPC
replicas — the runner only touches the shared client API plus the fault
surface). Virtual timestamps are scaled by ``time_scale`` real seconds
per virtual second (0 = as fast as possible, order preserved).

The runner is the *independent witness* the oracles compare telemetry
against: it counts everything it does on its own (submits dispatched,
futures resolved/failed, observations issued, expected generation swaps
and exclusions) without reading a single server counter, and records a
per-query outcome ledger (tenant, estimate, generation at answer,
serving replica).

Fault mapping per target:

  * ``publish`` — mints the next ``ModelGeneration`` from a snapshot of
    the newest live predictor (same abacus, bumped number: estimates
    stay parity-comparable across the swap), broadcasts it, and WAITS
    until every live replica reports adoption — so the expected
    ``gen_swaps`` delta is exactly the membership size at publish time.
  * ``kill`` — RPC replica: SIGKILL the child and wait for the
    heartbeat-driven auto-exclusion. In-process replica: there is no
    process to kill, so the same end state is forced via
    ``exclude_replica`` (drain -> migrate -> cutover; the drain serves
    queued futures first). Either way: one exclusion expected.
  * ``sigstop``/``sigcont`` — RPC only (wedges the child process);
    skipped and counted against in-process targets.
  * ``resize`` — ``ClusterFrontend.resize(n)`` (one protocol pass).

Submits are asynchronous; faults run synchronously in the replay thread
(so expected counters are unambiguous), while a harvester thread awaits
each future in dispatch order and issues the schedule's observations
(measured cost = estimate x the event's drift factors).
"""

from __future__ import annotations

import dataclasses
import os
import queue
import signal
import threading
import time
from typing import Dict, List, Optional

from repro.obs import events
from repro.scenarios.workload import Schedule, config_from_payload
from repro.serve.refit import ModelGeneration
from repro.serve.server import DeadlineExceeded, QuotaExceeded

#: counters a retired (excluded/removed) replica contributed before it
#: left the fleet — everything additive in ``ServerStats.COUNTERS``
#: (``max_batch`` is a high-water mark, not additive).
#: Overload ground truth: ``expired`` counts every DeadlineExceeded
#: outcome (``replay_expired`` is the subset expired at the *frontend*,
#: i.e. a parked/replayed query that never reached a replica again);
#: ``shed`` counts estimates answered degraded from the roofline floor;
#: ``quota_rejected`` counts per-tenant admission rejections (sync
#: raises AND failed futures — the via-future form also retro-decrements
#: ``submitted``, because the server never accepted the query).
GROUND_KEYS = (
    "submitted", "resolved", "failed", "submit_rejected",
    "observes_issued", "observe_failed", "publishes",
    "expected_gen_swaps", "kills", "expected_exclusions", "resizes",
    "sigstops", "skipped_events",
    "expired", "shed", "quota_rejected", "replay_expired",
)


@dataclasses.dataclass
class ScenarioResult:
    """Everything one replay produced, in oracle-consumable form."""

    schedule: Schedule
    ground: Dict[str, int]
    outcomes: Dict[int, Dict]      # schedule event index -> outcome record
    stats_after: Dict
    metrics_after: Dict
    generations: Dict[int, object]  # generation number -> serving abacus
    is_cluster: bool
    supports_hedge: bool
    wall_s: float

    def resolved_outcomes(self) -> List[Dict]:
        return [o for _, o in sorted(self.outcomes.items()) if o.get("ok")]


class ScenarioRunner:
    """Replay one schedule against one target fleet; see module docstring."""

    def __init__(self, target, schedule: Schedule, *,
                 time_scale: float = 0.0, result_timeout: float = 120.0,
                 fault_timeout: float = 30.0):
        self.target = target
        self.schedule = schedule
        self.time_scale = float(time_scale)
        self.result_timeout = float(result_timeout)
        self.fault_timeout = float(fault_timeout)
        self.is_cluster = hasattr(target, "replicas")
        self.ground: Dict[str, int] = {k: 0 for k in GROUND_KEYS}
        self.outcomes: Dict[int, Dict] = {}
        self.generations: Dict[int, object] = {}
        self._q: "queue.Queue" = queue.Queue()
        self._glock = threading.Lock()

    # -- helpers -------------------------------------------------------------
    def _replicas(self) -> List:
        return list(self.target.replicas) if self.is_cluster \
            else [self.target]

    def _live_replicas(self) -> List:
        return [r for r in self._replicas()
                if not getattr(r, "dead", False)]

    def _member_names(self) -> List[str]:
        return [getattr(r, "name", "server") for r in self._replicas()]

    def _max_generation(self) -> int:
        gens = []
        for r in self._live_replicas():
            try:
                gens.append(int(r.service.generation))
            except Exception:
                pass
        return max(gens) if gens else 0

    def _snapshot_abacus(self):
        newest = max(self._live_replicas() or self._replicas(),
                     key=lambda r: r.service.generation)
        abacus, _ = newest.service.snapshot()
        return abacus

    def _bump(self, key: str, n: int = 1) -> None:
        with self._glock:
            self.ground[key] += n

    # -- replay --------------------------------------------------------------
    def run(self) -> ScenarioResult:
        t0 = time.perf_counter()
        self.generations.setdefault(self._max_generation(),
                                    self._snapshot_abacus())
        events.emit("scenario_start", name=self.schedule.meta.get("name"),
                    seed=self.schedule.meta.get("seed"),
                    n_events=len(self.schedule))
        harvester = threading.Thread(target=self._harvest,
                                     name="scenario-harvest", daemon=True)
        harvester.start()
        t_prev: Optional[float] = None
        try:
            for ev in self.schedule:
                if (self.time_scale > 0 and t_prev is not None
                        and ev["t"] > t_prev):
                    time.sleep((ev["t"] - t_prev) * self.time_scale)
                t_prev = ev["t"]
                self._dispatch(ev)
        finally:
            self._q.put(None)
            harvester.join(self.result_timeout
                           + self.result_timeout * len(self.schedule) ** 0.5)
        stats_after = self.target.stats()
        metrics_after = self.target.metrics_snapshot()
        wall = time.perf_counter() - t0
        result = ScenarioResult(
            schedule=self.schedule, ground=dict(self.ground),
            outcomes=self.outcomes, stats_after=stats_after,
            metrics_after=metrics_after, generations=dict(self.generations),
            is_cluster=self.is_cluster,
            supports_hedge=any(getattr(r, "supports_hedge", False)
                               for r in self._replicas()),
            wall_s=wall)
        events.emit("scenario_end", name=self.schedule.meta.get("name"),
                    wall_s=round(wall, 4), **{k: result.ground[k]
                                              for k in ("submitted",
                                                        "resolved", "failed",
                                                        "observes_issued")})
        return result

    def _dispatch(self, ev: Dict) -> None:
        op = ev["op"]
        if op == "submit":
            self._do_submit(ev)
            return
        events.emit("scenario_fault", op=op, t=ev["t"],
                    replica=ev.get("replica"), n=ev.get("n"))
        if op == "publish":
            self._do_publish(ev)
        elif op == "kill":
            self._do_kill(ev)
        elif op == "resize":
            self._do_resize(ev)
        elif op in ("sigstop", "sigcont"):
            self._do_signal(ev)
        else:
            self._bump("skipped_events")

    # -- submits + observations ----------------------------------------------
    def _do_submit(self, ev: Dict) -> None:
        cfg = config_from_payload(ev["cfg"])
        kw = {}
        if ev.get("tenant"):
            kw["tenant"] = ev["tenant"]
        if ev.get("deadline") is not None:
            # budget (seconds) -> absolute monotonic deadline, anchored
            # at dispatch so queueing (not schedule skew) consumes it
            kw["deadline"] = time.monotonic() + float(ev["deadline"])
        try:
            fut = self.target.submit(cfg, ev["batch"], ev["seq"], **kw)
        except QuotaExceeded as e:
            # typed rejection BEFORE submitted is counted: the server
            # refused the query at the door, so neither side counts it
            self._bump("quota_rejected")
            self.outcomes[ev["i"]] = {"i": ev["i"], "t": ev["t"],
                                      "tenant": ev["tenant"], "ok": False,
                                      "quota": True, "error": repr(e)}
            return
        except Exception as e:
            self._bump("submit_rejected")
            self.outcomes[ev["i"]] = {"i": ev["i"], "t": ev["t"],
                                      "tenant": ev["tenant"], "ok": False,
                                      "error": repr(e)}
            return
        self._bump("submitted")
        self._q.put((ev, cfg, fut))

    def _harvest(self) -> None:
        """Await futures in dispatch order; issue scheduled observations."""
        while True:
            item = self._q.get()
            if item is None:
                return
            ev, cfg, fut = item
            try:
                est = fut.result(self.result_timeout)
            except DeadlineExceeded as e:
                # a cleanly expired future is an *accounted* outcome, not
                # a failure: the SLO was missed, dead work was not served
                self._bump("expired")
                if getattr(e, "where", "") == "frontend":
                    self._bump("replay_expired")
                self.outcomes[ev["i"]] = {"i": ev["i"], "t": ev["t"],
                                          "tenant": ev["tenant"],
                                          "ok": False, "expired": True,
                                          "where": getattr(e, "where", ""),
                                          "error": repr(e)}
                continue
            except QuotaExceeded as e:
                # via-future quota rejection (the RPC transport relays
                # the server's sync refusal as a failed reply): the
                # server never accepted it, so undo the dispatch count
                self._bump("quota_rejected")
                self._bump("submitted", -1)
                self.outcomes[ev["i"]] = {"i": ev["i"], "t": ev["t"],
                                          "tenant": ev["tenant"],
                                          "ok": False, "quota": True,
                                          "error": repr(e)}
                continue
            except Exception as e:
                self._bump("failed")
                self.outcomes[ev["i"]] = {"i": ev["i"], "t": ev["t"],
                                          "tenant": ev["tenant"],
                                          "ok": False, "error": repr(e)}
                continue
            self._bump("resolved")
            if est.get("degraded"):
                self._bump("shed")
            self.outcomes[ev["i"]] = {
                "i": ev["i"], "t": ev["t"], "tenant": ev["tenant"],
                "ok": True, "cfg": ev["cfg"], "batch": ev["batch"],
                "seq": ev["seq"], "model": est.get("model"),
                "time_s": est.get("time_s"),
                "mem_bytes": est.get("memory_bytes"),
                "admitted": est.get("admitted"),
                "generation": est.get("generation"),
                "replica": est.get("replica"),
                "degraded": bool(est.get("degraded", False)),
            }
            obs = ev.get("observe")
            if not obs:
                continue
            time_s = float(est["time_s"]) * float(obs["time_factor"])
            mem_b = float(est["memory_bytes"]) * float(obs["mem_factor"])
            if time_s <= 0.0 or mem_b <= 0.0:
                # the server drops non-positive measurements; never let
                # one desync the expected-observations ledger
                self._bump("observe_failed")
                continue
            try:
                self.target.observe(
                    cfg, ev["batch"], ev["seq"], time_s, mem_b,
                    predicted_time_s=est["time_s"],
                    predicted_mem_bytes=est["memory_bytes"],
                    generation=est.get("generation"))
            except Exception:
                self._bump("observe_failed")
                continue
            self._bump("observes_issued")

    # -- faults --------------------------------------------------------------
    def _do_publish(self, ev: Dict) -> None:
        number = self._max_generation() + 1
        abacus = self._snapshot_abacus()
        gen = ModelGeneration(number=number, abacus=abacus,
                              source="scenario", created_at=time.time())
        expected = len(self._replicas())
        self.target.publish_generation(gen)
        self._bump("publishes")
        self._bump("expected_gen_swaps", expected)
        self.generations[number] = abacus
        # wait until every member adopted: the next event must observe a
        # fleet that is unambiguously serving generation `number`
        deadline = time.monotonic() + self.fault_timeout
        while time.monotonic() < deadline:
            try:
                if all(int(r.service.generation) >= number
                       for r in self._live_replicas()):
                    return
            except Exception:
                pass
            time.sleep(0.01)
        raise RuntimeError(
            f"generation {number} not adopted fleet-wide within "
            f"{self.fault_timeout}s")

    def _find_replica(self, name: str):
        for r in self._replicas():
            if getattr(r, "name", None) == name:
                return r
        return None

    def _do_kill(self, ev: Dict) -> None:
        if not self.is_cluster:
            self._bump("skipped_events")
            return
        name = ev["replica"]
        replica = self._find_replica(name)
        if replica is None:
            self._bump("skipped_events")
            return
        self._bump("kills")
        if getattr(replica, "proc", None) is not None:
            replica.kill()  # SIGKILL: the heartbeat verdict excludes it
            deadline = time.monotonic() + self.fault_timeout
            while name in self._member_names() \
                    and time.monotonic() < deadline:
                time.sleep(0.02)
            if name in self._member_names():
                raise RuntimeError(
                    f"killed replica {name!r} was not auto-excluded "
                    f"within {self.fault_timeout}s")
        else:
            # in-process: no process to SIGKILL — force the same end
            # state (exclusion reshard; the drain resolves queued work)
            self.target.exclude_replica(name)
        self._bump("expected_exclusions")

    def _do_resize(self, ev: Dict) -> None:
        if not self.is_cluster:
            self._bump("skipped_events")
            return
        self.target.resize(int(ev["n"]))
        self._bump("resizes")

    def _do_signal(self, ev: Dict) -> None:
        replica = self._find_replica(ev.get("replica"))
        proc = getattr(replica, "proc", None) if replica else None
        if proc is None:
            self._bump("skipped_events")
            return
        os.kill(proc.pid, signal.SIGSTOP if ev["op"] == "sigstop"
                else signal.SIGCONT)
        if ev["op"] == "sigstop":
            self._bump("sigstops")
