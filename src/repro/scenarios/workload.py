"""Seeded workload generators: declarative specs -> explicit schedules.

A ``ScenarioSpec`` describes a workload (tenants, traffic shape, drift,
churn, faults); :func:`generate` expands it into a ``Schedule`` — a flat
list of timestamped events, each one JSON-serializable, so a scenario
is *data*: it can be saved to JSONL, diffed, shipped to another host,
and replayed bit-for-bit.

Determinism contract (the property the whole zoo hangs on):

  * same seed => byte-identical ``to_jsonl()`` output, across processes
    AND across ``PYTHONHASHSEED``s. All randomness flows through ONE
    ``np.random.default_rng(seed)``; nothing touches the builtin
    ``hash()`` (salted per process), wall clock, or dict iteration
    order of unsorted inputs (every dump is ``sort_keys=True``).
  * timestamps are **virtual seconds**. The runner scales them by a
    real-time factor at replay (``time_scale``), so the same schedule
    drives a leisurely soak or an as-fast-as-possible smoke run.

Event ops (one JSON object per line; ``i`` is the creation index and
the tiebreak for equal timestamps):

  ``submit``   {t, op, i, tenant, cfg, batch, seq, observe}
               ``observe`` is null (estimate only) or
               {time_factor, mem_factor}: after the estimate resolves,
               report measured cost = estimate x factor (per-tenant
               drift; per-observation calibration drift is then exactly
               ``1/factor - 1``, which the oracles bound).
  ``publish``  {t, op, i} — mint + broadcast the next ModelGeneration.
  ``kill``     {t, op, i, replica} — SIGKILL an RPC replica / exclude
               an in-process one (both end in an exclusion reshard).
  ``sigstop``/``sigcont`` {t, op, i, replica} — wedge/unwedge an RPC
               replica process (skipped + counted in-process).
  ``resize``   {t, op, i, n} — live-reshard the fleet to n replicas.

Adversarial fingerprint churn: ``churn_rate`` adds submits whose config
payload carries a unique ``nonce`` field — ``config_fingerprint`` hashes
every attribute, so each one is a near-miss config (identical features,
fresh fingerprint) that defeats the trace cache and forces a cold trace.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import subprocess
import sys
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.features import ProfileRecord

SCHEDULE_VERSION = 1


# -- deterministic configs + tracer ------------------------------------------


class ScenarioConfig:
    """Duck-typed model config materialized from a schedule payload.

    Attributes are set in sorted-key order purely for readability;
    ``config_fingerprint`` canonicalizes over sorted ``vars()`` anyway,
    so the fingerprint is a pure function of the payload contents.
    """

    def __init__(self, **attrs):
        for k in sorted(attrs):
            setattr(self, k, attrs[k])

    def as_dict(self) -> Dict:
        return dict(vars(self))

    def __repr__(self) -> str:
        return f"ScenarioConfig({vars(self)!r})"


def config_from_payload(payload: Dict) -> ScenarioConfig:
    """Materialize the duck-typed config a ``submit`` event carries."""
    return ScenarioConfig(**payload)


def scenario_trace(cfg, batch: int, seq: int) -> ProfileRecord:
    """Deterministic dependency-free tracer for scenario configs.

    Features follow the same generative law as :func:`fit_records`
    (``dots`` parameterizes cost), so a predictor fit on those records
    is in-distribution for every scenario query. A ``nonce`` attribute
    (fingerprint churn) deliberately does NOT enter the features: the
    churned config is a *near miss* — fresh fingerprint, identical
    record — exactly the trace-cache-defeating adversary.
    """
    dots = float(getattr(cfg, "dots", 8.0))
    flops = batch * seq * dots * 1e6
    edges = {("dot", "add"): dots, ("add", "tanh"): dots,
             ("tanh", "dot"): max(1.0, dots - 1)}
    return ProfileRecord(
        model_name=cfg.name, family=getattr(cfg, "family", "dense"),
        batch_size=batch, input_size=seq, channels=64, learning_rate=1e-3,
        epoch=1, optimizer="adamw", layers=int(getattr(cfg, "num_layers", 4)),
        flops=flops, params=int(dots * 1e5), nsm_edges=edges)


def fit_records(n: int = 80, seed: int = 0) -> List[ProfileRecord]:
    """Synthetic training corpus matching :func:`scenario_trace` features."""
    rng = np.random.default_rng(seed)
    recs = []
    for i in range(n):
        batch = int(rng.choice([2, 4, 8, 16]))
        seq = int(rng.choice([32, 64, 128]))
        dots = float(rng.integers(4, 60))
        flops = batch * seq * dots * 1e6
        edges = {("dot", "add"): dots, ("add", "tanh"): dots,
                 ("tanh", "dot"): dots - 1}
        recs.append(ProfileRecord(
            model_name=f"m{i}", family="dense", batch_size=batch,
            input_size=seq, channels=64, learning_rate=1e-3, epoch=1,
            optimizer="adamw", layers=int(rng.integers(2, 16)), flops=flops,
            params=int(dots * 1e5), nsm_edges=edges,
            time_s=flops / 5e10, mem_bytes=1e6 * dots + 4.0 * batch * seq))
    return recs


def fit_abacus(seed: int = 0):
    """RandomForest-backed predictor over :func:`fit_records`.

    Per-row exact tree predictions make estimates independent of
    micro-batch composition, so scenario replays compare byte-for-byte
    against a fresh single-server replay (the parity oracle) no matter
    how ticks coalesce.
    """
    from repro.core.automl.models import RandomForestRegressor
    from repro.core.predictor import DNNAbacus
    fac = lambda s: [RandomForestRegressor(n_trees=10, seed=s)]
    return DNNAbacus(seed=seed).fit(fit_records(seed=seed),
                                    candidate_factory=fac)


# -- declarative spec ---------------------------------------------------------


@dataclasses.dataclass
class TenantSpec:
    """One tenant's config pool, traffic share, and drift law."""

    name: str
    weight: float = 1.0            # relative share of the traffic mix
    n_configs: int = 4             # distinct configs in this tenant's pool
    dots: Tuple[float, float] = (8.0, 48.0)   # cost-knob range of the pool
    batches: Tuple[int, ...] = (2, 4, 8)
    seqs: Tuple[int, ...] = (32, 64)
    time_drift: float = 1.0        # measured time = estimate x factor
    mem_drift: float = 1.0         # measured mem  = estimate x factor
    observe_fraction: float = 0.5  # fraction of submits that report back
    # SLO budget (virtual seconds) stamped on every submit of this
    # tenant; None = no deadline. The runner converts it to an absolute
    # monotonic deadline at submit time, so replay speed (time_scale)
    # does not distort it. Emitted into the event only when set — specs
    # without deadlines keep their historical schedule bytes.
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class TrafficSpec:
    """Bursty diurnal arrival process (rate in submits / virtual second).

    ``rate(t) = base_rate * max(0, 1 + burst_amplitude *
    sin(2 pi t / burst_period_s))`` — amplitude 0 is flat load,
    amplitude 1 swings between 0 and 2x over one virtual "day".
    """

    base_rate: float = 40.0
    burst_amplitude: float = 0.0
    burst_period_s: float = 24.0


@dataclasses.dataclass
class ProfileSwap:
    """Mid-stream hardware-profile swap: from virtual time ``t`` on,
    ``tenant``'s measured costs follow NEW drift factors (new kernels,
    a migrated host class)."""

    t: float
    tenant: str
    time_drift: float
    mem_drift: float


@dataclasses.dataclass
class FaultSpec:
    """One fault event: ``kill``/``sigstop``/``sigcont`` (``target`` =
    replica name), ``resize`` (``n`` = new fleet size), or ``publish``."""

    t: float
    kind: str
    target: Optional[str] = None
    n: Optional[int] = None


@dataclasses.dataclass
class ScenarioSpec:
    """Declarative scenario: everything :func:`generate` needs."""

    name: str = "scenario"
    seed: int = 0
    duration_s: float = 8.0
    tenants: List[TenantSpec] = dataclasses.field(
        default_factory=lambda: [TenantSpec(name="t0")])
    traffic: TrafficSpec = dataclasses.field(default_factory=TrafficSpec)
    churn_rate: float = 0.0        # near-miss submits / virtual second
    swaps: List[ProfileSwap] = dataclasses.field(default_factory=list)
    faults: List[FaultSpec] = dataclasses.field(default_factory=list)
    drift_tolerance: float = 0.05  # oracle slack around the drift bounds

    def to_dict(self) -> Dict:
        # round-trip through JSON so tuples land as lists: the dict a
        # loaded schedule carries compares equal to a fresh one
        return json.loads(_dumps(dataclasses.asdict(self)))

    @classmethod
    def from_dict(cls, d: Dict) -> "ScenarioSpec":
        d = dict(d)
        d["tenants"] = [TenantSpec(**dict(t, dots=tuple(t["dots"]),
                                          batches=tuple(t["batches"]),
                                          seqs=tuple(t["seqs"])))
                        for t in d.get("tenants", [])]
        d["traffic"] = TrafficSpec(**d.get("traffic", {}))
        d["swaps"] = [ProfileSwap(**s) for s in d.get("swaps", [])]
        d["faults"] = [FaultSpec(**f) for f in d.get("faults", [])]
        return cls(**d)


def tenant_payloads(tenant: TenantSpec) -> List[Dict]:
    """The tenant's deterministic config pool (no RNG: a pure function
    of the spec, so benches can enumerate a keyset without generating a
    full schedule)."""
    lo, hi = float(tenant.dots[0]), float(tenant.dots[1])
    n = max(1, int(tenant.n_configs))
    out = []
    for k in range(n):
        frac = k / (n - 1) if n > 1 else 0.0
        out.append({
            "name": f"{tenant.name}-c{k:03d}",
            "family": "dense",
            "num_layers": 2 + k % 14,
            "d_model": 64 + 16 * (k % 8),
            "dots": round(lo + (hi - lo) * frac, 6),
        })
    return out


def tenant_overload_spec(smoke: bool = True, *,
                         base_rate: Optional[float] = None,
                         duration_s: Optional[float] = None) -> ScenarioSpec:
    """The zoo's overload scenario: sustained many-times-capacity load.

    Two tenants past a deliberately tight fleet (the harness pairs this
    spec with a throttled predictor + small ``max_queue`` /
    ``shed_watermark``): "bulk" floods the queue, "slo" rides a tight
    per-query deadline. Exercises every overload path at once — quota
    rejections (bulk exhausts its weighted share), sheds (watermark
    crossings answered from the roofline floor), and deadline expiries
    (slo queries EDF-expired under the backlog) — and the overload
    oracle asserts the shed/expired/quota accounting is *exact* against
    the runner's ground truth.
    """
    if base_rate is None:
        base_rate = 400.0 if smoke else 1200.0
    if duration_s is None:
        duration_s = 4.0 if smoke else 10.0
    return ScenarioSpec(
        name="tenant_overload", seed=20250811,
        duration_s=float(duration_s),
        tenants=[
            TenantSpec(name="bulk", weight=4.0, n_configs=4,
                       dots=(8.0, 40.0), batches=(2, 4), seqs=(32,),
                       observe_fraction=0.2),
            TenantSpec(name="slo", weight=1.0, n_configs=2,
                       dots=(12.0, 24.0), batches=(2,), seqs=(32,),
                       observe_fraction=0.2, deadline_s=0.05),
        ],
        traffic=TrafficSpec(base_rate=float(base_rate),
                            burst_amplitude=0.5, burst_period_s=2.0),
    )


# -- schedule -----------------------------------------------------------------


def _dumps(obj) -> str:
    # canonical form: sorted keys, no whitespace — the byte-stability
    # contract rides on this one call
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


class Schedule:
    """An ordered event list + meta header, serializable to JSONL.

    Line 1 is the meta header (``{"scenario_meta": {...}}``: name, seed,
    event counts, the oracle drift bounds, and the full spec dict);
    every following line is one event. ``to_jsonl`` output is the
    byte-stable identity of the scenario.
    """

    def __init__(self, meta: Dict, events: List[Dict]):
        self.meta = meta
        self.events = events

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def __eq__(self, other) -> bool:
        return (isinstance(other, Schedule) and self.meta == other.meta
                and self.events == other.events)

    def to_jsonl(self) -> str:
        lines = [_dumps({"scenario_meta": self.meta})]
        lines.extend(_dumps(ev) for ev in self.events)
        return "\n".join(lines) + "\n"

    @classmethod
    def from_jsonl(cls, text: str) -> "Schedule":
        meta: Dict = {}
        events: List[Dict] = []
        for line in text.splitlines():
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            if "scenario_meta" in d:
                meta = d["scenario_meta"]
            else:
                events.append(d)
        return cls(meta, events)

    def save(self, path: str) -> str:
        with open(path, "w", encoding="utf-8") as f:
            f.write(self.to_jsonl())
        return path

    @classmethod
    def load(cls, path: str) -> "Schedule":
        with open(path, encoding="utf-8") as f:
            return cls.from_jsonl(f.read())

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for ev in self.events:
            out[ev["op"]] = out.get(ev["op"], 0) + 1
        return out


def _drift_at(spec: ScenarioSpec, tenant: str, t: float) -> Tuple[float, float]:
    """(time_factor, mem_factor) for ``tenant`` at virtual time ``t`` —
    the tenant's base drift, overridden by the latest profile swap."""
    base = next(tn for tn in spec.tenants if tn.name == tenant)
    ft, fm = float(base.time_drift), float(base.mem_drift)
    for swap in sorted(spec.swaps, key=lambda s: s.t):
        if swap.tenant == tenant and swap.t <= t:
            ft, fm = float(swap.time_drift), float(swap.mem_drift)
    return ft, fm


def generate(spec: ScenarioSpec) -> Schedule:
    """Expand a spec into its explicit event schedule (deterministic)."""
    rng = np.random.default_rng(int(spec.seed))
    tenants = list(spec.tenants)
    if not tenants:
        raise ValueError("a scenario needs at least one tenant")
    weights = np.array([max(0.0, float(t.weight)) for t in tenants])
    if weights.sum() <= 0:
        raise ValueError("tenant weights must sum to a positive value")
    weights = weights / weights.sum()
    pools = {t.name: tenant_payloads(t) for t in tenants}

    tr = spec.traffic
    events: List[Dict] = []
    i = 0
    churn_id = 0
    n_windows = int(math.ceil(float(spec.duration_s)))
    for w in range(n_windows):
        t_mid = w + 0.5
        rate = tr.base_rate * max(
            0.0, 1.0 + tr.burst_amplitude
            * math.sin(2.0 * math.pi * t_mid / tr.burst_period_s))
        n = int(rng.poisson(rate)) if rate > 0 else 0
        offsets = np.sort(rng.random(n)) if n else []
        for off in offsets:
            t = round(w + float(off), 6)
            tn = tenants[int(rng.choice(len(tenants), p=weights))]
            payload = pools[tn.name][int(rng.integers(len(pools[tn.name])))]
            batch = int(rng.choice(list(tn.batches)))
            seq = int(rng.choice(list(tn.seqs)))
            observe = None
            if rng.random() < tn.observe_fraction:
                ft, fm = _drift_at(spec, tn.name, t)
                observe = {"time_factor": ft, "mem_factor": fm}
            ev = {"i": i, "t": t, "op": "submit",
                  "tenant": tn.name, "cfg": dict(payload),
                  "batch": batch, "seq": seq, "observe": observe}
            if tn.deadline_s is not None:
                ev["deadline"] = round(float(tn.deadline_s), 6)
            events.append(ev)
            i += 1
        # adversarial fingerprint churn: near-miss configs, never observed
        m = int(rng.poisson(spec.churn_rate)) if spec.churn_rate > 0 else 0
        for _ in range(m):
            t = round(w + float(rng.random()), 6)
            tn = tenants[int(rng.choice(len(tenants), p=weights))]
            payload = dict(
                pools[tn.name][int(rng.integers(len(pools[tn.name])))])
            payload["name"] = f"{payload['name']}-churn{churn_id:05d}"
            payload["nonce"] = churn_id
            churn_id += 1
            ev = {"i": i, "t": t, "op": "submit",
                  "tenant": tn.name, "cfg": payload,
                  "batch": int(rng.choice(list(tn.batches))),
                  "seq": int(rng.choice(list(tn.seqs))),
                  "observe": None}
            if tn.deadline_s is not None:
                ev["deadline"] = round(float(tn.deadline_s), 6)
            events.append(ev)
            i += 1
    for fault in spec.faults:
        ev = {"i": i, "t": round(float(fault.t), 6), "op": str(fault.kind)}
        if fault.kind in ("kill", "sigstop", "sigcont"):
            ev["replica"] = str(fault.target)
        elif fault.kind == "resize":
            ev["n"] = int(fault.n)  # type: ignore[arg-type]
        elif fault.kind != "publish":
            raise ValueError(f"unknown fault kind {fault.kind!r}")
        events.append(ev)
        i += 1
    events.sort(key=lambda e: (e["t"], e["i"]))

    # oracle bounds: every per-observation calibration drift is exactly
    # 1/factor - 1, so the windowed mean must land inside [min, max]
    tf = sorted({ev["observe"]["time_factor"] for ev in events
                 if ev["op"] == "submit" and ev["observe"]})
    mf = sorted({ev["observe"]["mem_factor"] for ev in events
                 if ev["op"] == "submit" and ev["observe"]})
    drift = {
        "time": [1.0 / tf[-1] - 1.0, 1.0 / tf[0] - 1.0] if tf else None,
        "mem": [1.0 / mf[-1] - 1.0, 1.0 / mf[0] - 1.0] if mf else None,
        "tolerance": float(spec.drift_tolerance),
    }
    meta = {
        "name": spec.name,
        "seed": int(spec.seed),
        "version": SCHEDULE_VERSION,
        "n_events": len(events),
        "counts": Schedule(
            {}, events).counts(),
        "drift": drift,
        "spec": spec.to_dict(),
    }
    return Schedule(meta, events)


# -- determinism probes -------------------------------------------------------


def schedule_digest(spec: ScenarioSpec) -> str:
    """SHA-256 of the generated schedule's JSONL bytes."""
    return hashlib.sha256(generate(spec).to_jsonl().encode()).hexdigest()


_DIGEST_PROG = """\
import json, sys
from repro.scenarios.workload import ScenarioSpec, schedule_digest
spec = ScenarioSpec.from_dict(json.loads(sys.stdin.read()))
print(schedule_digest(spec))
"""


def schedule_digest_subprocess(spec: ScenarioSpec,
                               hash_seed: int,
                               timeout: float = 120.0) -> str:
    """The schedule digest computed in a FRESH interpreter under an
    explicit ``PYTHONHASHSEED`` — the cross-process half of the
    byte-identity contract (tests/benches compare several seeds)."""
    import repro
    src = os.path.dirname(list(repro.__path__)[0])
    env = dict(os.environ)
    env["PYTHONHASHSEED"] = str(int(hash_seed))
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    out = subprocess.run(
        [sys.executable, "-c", _DIGEST_PROG],
        input=json.dumps(spec.to_dict()), capture_output=True, text=True,
        env=env, timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"digest subprocess failed: {out.stderr}")
    return out.stdout.strip()
