"""Drift-scenario zoo: deterministic workload replay for the serving fleet.

Every scale claim the serving stack makes — overload survival, refit
MRE recovery, reshard parity, kill-and-heal — is exercised through one
reusable, seeded scenario pipeline instead of bespoke bench loops:

  * :mod:`repro.scenarios.workload` — declarative ``ScenarioSpec`` ->
    ``generate()`` -> ``Schedule``: an explicit, JSONL-serializable
    event schedule (bursty diurnal traffic, multi-tenant drift,
    mid-stream profile swaps, adversarial fingerprint churn, fault
    events). Same seed => byte-identical schedule across processes and
    ``PYTHONHASHSEED``s.
  * :mod:`repro.scenarios.runner` — ``ScenarioRunner`` replays a
    schedule against an ``AbacusServer`` or ``ClusterFrontend``
    (in-process or RPC), collecting per-query ground truth.
  * :mod:`repro.scenarios.oracles` — invariant checkers that
    cross-validate the run against the telemetry plane (counters,
    metrics snapshot, legacy ``stats()`` keys, calibration drift,
    estimate parity vs a fresh single-server replay).
"""

from repro.scenarios.oracles import (OracleResult, check_all, failed,
                                     oracle_overload_accounting)
from repro.scenarios.runner import ScenarioResult, ScenarioRunner
from repro.scenarios.workload import (FaultSpec, ProfileSwap, ScenarioConfig,
                                      ScenarioSpec, Schedule, TenantSpec,
                                      TrafficSpec, config_from_payload,
                                      fit_abacus, fit_records, generate,
                                      scenario_trace, schedule_digest,
                                      schedule_digest_subprocess,
                                      tenant_overload_spec)

__all__ = [
    "FaultSpec",
    "OracleResult",
    "ProfileSwap",
    "ScenarioConfig",
    "ScenarioResult",
    "ScenarioRunner",
    "ScenarioSpec",
    "Schedule",
    "TenantSpec",
    "TrafficSpec",
    "check_all",
    "config_from_payload",
    "failed",
    "fit_abacus",
    "fit_records",
    "generate",
    "oracle_overload_accounting",
    "scenario_trace",
    "schedule_digest",
    "schedule_digest_subprocess",
    "tenant_overload_spec",
]
