"""Qwen2.5-32B — dense, GQA kv=8, QKV bias.

[hf:Qwen/Qwen2.5-0.5B family card; hf]. 64L, d_model 5120, 40 heads,
d_ff 27648, 152k vocab.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
)
