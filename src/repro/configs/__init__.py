"""Architecture registry: ``get_config(arch_id)`` for the 10 assigned archs.

File names use underscores (importable modules); arch ids keep the dashed
form from the assignment. ``reduced_config`` shrinks any config to a
CPU-runnable smoke-test size while preserving the layer pattern/family.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable

_MODULES = {
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "arctic-480b": "arctic_480b",
    "chatglm3-6b": "chatglm3_6b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "qwen2.5-32b": "qwen2_5_32b",
    "qwen2-0.5b": "qwen2_0_5b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "whisper-tiny": "whisper_tiny",
    "mamba2-370m": "mamba2_370m",
}


def list_archs() -> List[str]:
    return list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def reduced_config(cfg: ModelConfig, dtype: str = "float32") -> ModelConfig:
    """Shrink to smoke-test size, preserving the periodic layer pattern."""
    period = cfg.period
    heads = min(cfg.num_heads, 4) or cfg.num_heads
    kv = min(cfg.num_kv_heads, heads) or cfg.num_kv_heads
    if heads and kv:
        kv = max(1, min(kv, heads))
        while heads % kv:
            kv -= 1
    repl = dict(
        num_layers=period * min(2, cfg.num_periods),
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16 if heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        vision_seq=16,
        audio_seq=32,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16,
        ssm_chunk=16,
        moe_group_size=64,
        dtype=dtype,
        remat="none",
    )
    if cfg.num_experts:
        repl["num_experts"] = min(cfg.num_experts, 4)
        repl["top_k"] = min(cfg.top_k, 2)
    if cfg.encoder_layers:
        repl["encoder_layers"] = 2
    return dataclasses.replace(cfg, **repl)


__all__ = ["get_config", "list_archs", "reduced_config", "ModelConfig",
           "ShapeConfig", "SHAPES", "shape_applicable"]
