"""Snowflake Arctic 480B — 128-expert top-2 MoE with parallel dense residual.

[hf:Snowflake/snowflake-arctic-base; hf]. 35L, d_model 7168, 56 heads
GQA kv=8; every layer: MoE (128e, d_ff 4864) + dense FFN residual branch.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    num_experts=128,
    top_k=2,
    dense_residual=True,
)
