"""Jamba v0.1 52B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf]. Period-8 pattern: attention at layer index 4 of
each block of 8, Mamba elsewhere; MoE MLP on odd layers. Mamba-1 state
size 16 realized with SSD blocks (see DESIGN.md hardware-adaptation notes).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=4,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=64,  # bounds the (B,chunks,Q,Q,H) SSD decay tensor (Q linear)
    sub_quadratic=True,
)
