"""Whisper-tiny backbone — encoder-decoder; conv frontend is a stub.

[arXiv:2212.04356; unverified]. 4 encoder + 4 decoder layers, d_model
384, 6 heads (MHA), d_ff 1536, LayerNorm. ``input_specs()`` provides
(B, audio_seq, d_model) precomputed frame embeddings (frontend stub).
Rotary positions replace Whisper's learned/sinusoidal embeddings — a
cost-neutral adaptation noted in DESIGN.md.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    norm="layernorm",
    audio_seq=1500,
)
