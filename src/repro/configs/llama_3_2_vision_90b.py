"""Llama-3.2-Vision 90B backbone — cross-attention image layers every 5th.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]. 100L, d_model 8192,
64 heads GQA kv=8, d_ff 28672. The vision frontend is a stub per the
assignment: ``input_specs()`` provides (B, vision_seq, d_model)
precomputed patch embeddings consumed by gated cross-attention layers.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_every=5,
    cross_offset=3,
    vision_seq=1600,
)
