"""Mamba2-370M — attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified]. 48L, d_model 1024, d_inner 2048
(expand 2), 32 SSD heads of dim 64, state 128, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
    sub_quadratic=True,
)
