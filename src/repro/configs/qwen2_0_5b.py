"""Qwen2-0.5B — dense, GQA kv=2, QKV bias, tied embeddings.

[arXiv:2407.10671; hf]. 24L, d_model 896, 14 heads, d_ff 4864.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
)
