"""Model / run configuration dataclasses and the layer-pattern abstraction.

Every assigned architecture is expressed as a ``ModelConfig``. Heterogeneous
stacks (Jamba's 1:7 attn:mamba interleave, Llama-vision's cross-attention
every 5th layer, MoE-every-2nd-layer) are described by a *periodic layer
pattern*: the stack is ``num_layers = period * num_periods`` layers, the
pattern lists the (mixer, mlp) kind for each layer inside one period, and
parameters are stacked across periods so the whole stack lowers as a single
``jax.lax.scan`` — HLO size stays O(period), not O(depth), which keeps
100-layer models compilable and keeps remat policy uniform.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

# Mixer kinds: "attn" (causal self-attention), "cross" (cross-attention to
# stub-embedded modality memory), "ssm" (Mamba2 SSD). MLP kinds: "dense",
# "moe", "moe_dense" (MoE plus parallel dense residual branch — Arctic).
LayerKind = Tuple[str, str]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # MoE MLP on layers where i % moe_every == moe_offset
    moe_offset: int = 0
    dense_residual: bool = False  # Arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    moe_group_size: int = 512     # tokens per dispatch group

    # --- attention ---
    qkv_bias: bool = False
    rope_style: str = "full"      # full | 2d (ChatGLM partial rotary on half dims)
    rope_theta: float = 10_000.0
    attn_every: int = 1           # attention on layers where i % attn_every == attn_offset
    attn_offset: int = 0          # (non-attention layers are SSM)

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- VLM ---
    cross_every: int = 0          # cross-attn mixer on layers where i % cross_every == cross_offset
    cross_offset: int = 0
    vision_seq: int = 1600        # stub patch-embedding count

    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0
    audio_seq: int = 1500         # stub frame-embedding count

    # --- misc ---
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "full"           # none | dots | full
    logits_softcap: float = 0.0
    sub_quadratic: bool = False   # True iff long_500k decode is supported

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.num_heads))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    def layer_kind(self, i: int) -> LayerKind:
        """(mixer, mlp) kind of decoder layer ``i``."""
        if self.cross_every and i % self.cross_every == self.cross_offset:
            mixer = "cross"
        elif self.attn_every > 1:
            mixer = "attn" if i % self.attn_every == self.attn_offset else "ssm"
        elif self.family == "ssm":
            mixer = "ssm"
        else:
            mixer = "attn"
        if self.num_experts and i % self.moe_every == self.moe_offset:
            mlp = "moe_dense" if self.dense_residual else "moe"
        elif self.family == "ssm":
            mlp = "none"  # Mamba2 blocks have no separate MLP
        else:
            mlp = "dense"
        return (mixer, mlp)

    @property
    def period(self) -> int:
        """Length of the repeating layer pattern."""
        p = 1
        if self.cross_every:
            p = math.lcm(p, self.cross_every)
        if self.attn_every > 1:
            p = math.lcm(p, self.attn_every)
        if self.num_experts:
            p = math.lcm(p, self.moe_every)
        # Find the smallest period consistent with layer_kind.
        while self.num_layers % p != 0:
            p += 1
        return p

    @property
    def num_periods(self) -> int:
        return self.num_layers // self.period

    def pattern(self) -> List[LayerKind]:
        kinds = [self.layer_kind(i) for i in range(self.num_layers)]
        p = self.period
        for i in range(self.num_layers):
            assert kinds[i] == kinds[i % p], (
                f"layer pattern of {self.name} is not periodic with period {p}")
        return kinds[:p]

    # convenience for feature extraction / MODEL_FLOPS ------------------
    def param_count(self) -> int:
        from repro.models import api
        return api.build_model(self).param_count()

    def active_param_count(self) -> int:
        from repro.models import api
        return api.build_model(self).param_count(active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell is runnable, and why not if skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("long_500k requires sub-quadratic sequence mixing; "
                       f"{cfg.name} is pure full-attention (see DESIGN.md)")
    return True, ""
