"""ChatGLM3-6B — dense, 2d (partial) RoPE, GQA kv=2, QKV bias.

[arXiv:2406.12793; hf]. 28L, d_model 4096, 32 heads, d_ff 13696.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    qkv_bias=True,
    rope_style="2d",
)
