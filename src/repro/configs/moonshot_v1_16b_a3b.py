"""Moonshot (Moonlight) 16B-A3B — MoE 64 experts top-6, every layer.

[hf:moonshotai/Moonlight-16B-A3B; hf]. 48L, d_model 2048, 16 heads
(kv=16 -> MHA), expert d_ff 1408; ~3B active parameters per token.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    num_experts=64,
    top_k=6,
)
