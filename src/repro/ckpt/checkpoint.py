"""Atomic, mesh-shape-agnostic checkpointing.

Checkpoints are written as a single ``.npz`` of *logically unsharded*
arrays keyed by tree path, plus an ``index.json`` with step metadata. The
write is atomic (tmp dir + rename), so a preemption mid-write never
corrupts the latest checkpoint; ``latest_step`` only ever sees complete
directories.

Because arrays are stored unsharded, restore can re-shard onto a mesh of
*different* shape (elastic restart: e.g. data axis 16 -> 8 after losing
hosts): pass the new ``shardings`` tree and each leaf is ``device_put``
onto it.

On a real multi-host deployment the .npz writer is replaced by per-shard
writers behind the same interface; the index/atomic-rename protocol is
unchanged (see DESIGN.md).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "|"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, state, keep: int = 3) -> str:
    """Atomically write ``state`` for ``step``. Returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=ckpt_dir)
    try:
        flat = _flatten(state)
        arrays = {k: np.asarray(v) for k, v in flat.items()}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        index = {
            "step": int(step),
            "keys": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                     for k, a in arrays.items()},
        }
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "index.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of ``like`` (a state or shape tree).

    ``shardings``: optional matching tree of NamedSharding — enables
    elastic restore onto a different mesh shape.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    flat_like = _flatten(like)
    missing = set(flat_like) - set(arrays)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
    flat_sh = _flatten(shardings) if shardings is not None else {}
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten(like).keys())
    out = []
    for key, ref in zip(keys, leaves_like):
        a = arrays[key].astype(ref.dtype) if hasattr(ref, "dtype") else arrays[key]
        if key in flat_sh:
            a = jax.device_put(a, flat_sh[key])
        out.append(a)
    return jax.tree_util.tree_unflatten(treedef, out)
