"""Production mesh construction.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module never touches jax device state. The single-pod mesh
is 16x16 = 256 chips (``data`` x ``model``); the multi-pod mesh prepends a
``pod`` axis: 2 x 16 x 16 = 512 chips. Data parallelism spans
(pod, data); tensor/expert parallelism spans ``model`` (intra-pod, where
ICI is fastest); the ``pod`` axis only ever carries gradient all-reduces
and ZeRO state, which tolerate the slower inter-pod DCN links.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit axis types; older jax has Auto semantics only
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many (possibly fake) local devices exist."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return _make_mesh((data, model), ("data", "model"))
