"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this container the launcher runs reduced configs on the host devices;
on a real cluster the same entry point runs the full config on the
production mesh (the dry-run proves those lower+compile). With
``--predict``, DNNAbacus admission control estimates step time and peak
memory for the requested config *before* allocating anything and refuses
jobs predicted to OOM — the paper's scheduling application wired into the
launcher.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (default: reduced smoke size)")
    ap.add_argument("--data", type=int, default=1, help="data-parallel axis")
    ap.add_argument("--model-par", type=int, default=1, help="model axis")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--no-zero", action="store_true")
    ap.add_argument("--predict", action="store_true",
                    help="DNNAbacus admission control before launch")
    ap.add_argument("--predictor-path", default="artifacts/abacus")
    ap.add_argument("--log", default=None)
    args = ap.parse_args(argv)

    from repro.configs import get_config, reduced_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.train import optimizer as opt_lib
    from repro.train.loop import LoopConfig, Trainer
    from repro.distributed import sharding as shd

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduced_config(cfg)
    mesh = (make_host_mesh(args.data, args.model_par)
            if args.data * args.model_par > 1 else None)
    model = build_model(cfg, sharder=shd.make_sharder(mesh))

    predicted = None
    if args.predict:
        from repro.core.predictor import DNNAbacus
        if os.path.exists(args.predictor_path + ".json"):
            service = DNNAbacus.load(args.predictor_path).service()
            est = service.predict_one(cfg, args.batch, args.seq)
            predicted = est["time_s"]
            print(f"[abacus] predicted step time {est['time_s']*1e3:.1f} ms, "
                  f"peak memory {est['memory_bytes']/2**30:.2f} GiB")
            budget = est.get("hbm_budget", 34 * 2**30)
            if est["memory_bytes"] > budget:
                print("[abacus] REFUSED: predicted OOM "
                      f"({est['memory_bytes']/2**30:.1f} GiB > "
                      f"{budget/2**30:.1f} GiB)", file=sys.stderr)
                return 2
        else:
            print("[abacus] no fitted predictor found; run "
                  "benchmarks/bench_mre.py or examples/predict_and_schedule.py "
                  "first", file=sys.stderr)

    loop_cfg = LoopConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                          ckpt_dir=args.ckpt_dir, accum=args.accum,
                          zero=not args.no_zero,
                          predicted_step_s=predicted)
    trainer = Trainer(model, opt_lib.OptConfig(), loop_cfg, mesh=mesh)
    log = trainer.run()
    for rec in log:
        print(json.dumps(rec))
    if args.log:
        trainer.write_log(args.log)
    print(f"final loss: {log[-1]['loss']:.4f} "
          f"(retries={trainer.runner.retries}, "
          f"stragglers={trainer.runner.stragglers})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
