"""Resumable dry-run sweep driver: every (arch x shape x mesh) cell.

Runs each cell in a FRESH subprocess (jax locks the fake device count at
first init; isolation also bounds compile-memory growth), appends JSONL
records, and skips cells already present — so the sweep can be
interrupted/resumed freely. Cells are ordered cheapest-first to bank
results early on a 1-core container.

Usage: PYTHONPATH=src python -m repro.launch.sweep --out artifacts/dryrun.jsonl
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCH_ORDER = [
    "whisper-tiny", "qwen2-0.5b", "mamba2-370m", "chatglm3-6b",
    "phi4-mini-3.8b", "moonshot-v1-16b-a3b", "jamba-v0.1-52b",
    "qwen2.5-32b", "arctic-480b", "llama-3.2-vision-90b",
]
SHAPE_ORDER = ["train_4k", "decode_32k", "prefill_32k", "long_500k"]

# Per-arch training scheme (measured in EXPERIMENTS.md §Perf): models
# below ~8B parameters train fastest as pure 256-way DP with FSDP weights
# ("dp"); larger models keep tensor/expert parallelism with a
# sequence-parallel residual stream ("sp"). Serving cells always use "sp".
TRAIN_SCHEME = {
    "whisper-tiny": "dp", "qwen2-0.5b": "dp", "mamba2-370m": "dp",
    "chatglm3-6b": "dp", "phi4-mini-3.8b": "dp",
    "moonshot-v1-16b-a3b": "sp", "jamba-v0.1-52b": "sp",
    "qwen2.5-32b": "sp", "arctic-480b": "sp", "llama-3.2-vision-90b": "sp",
}


def scheme_for(arch: str, shape: str) -> str:
    return TRAIN_SCHEME.get(arch, "sp") if shape.startswith("train") else "sp"


def cells(meshes):
    from repro.configs import SHAPES, get_config, shape_applicable
    out = []
    for mp in meshes:
        for shape in SHAPE_ORDER:
            for arch in ARCH_ORDER:
                ok, why = shape_applicable(get_config(arch), SHAPES[shape])
                out.append((arch, shape, mp, ok, why))
    return out


def done_keys(path):
    keys = set()
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if r.get("status") in ("ok", "skipped"):
                    keys.add((r["arch"], r["shape"], r["multi_pod"]))
    return keys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/dryrun.jsonl")
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--only-arch", default=None)
    args = ap.parse_args(argv)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    meshes = [m == "multi" for m in args.meshes.split(",")]

    done = done_keys(args.out)
    todo = [c for c in cells(meshes)
            if (c[0], c[1], c[2]) not in done
            and (args.only_arch is None or c[0] == args.only_arch)]
    print(f"[sweep] {len(todo)} cells to run ({len(done)} already done)")
    for i, (arch, shape, mp, ok, why) in enumerate(todo):
        key = f"{arch} x {shape} x {'multi' if mp else 'single'}"
        if not ok:
            rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "status": "skipped", "reason": why}
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
            print(f"[sweep] {i+1}/{len(todo)} SKIP {key}: {why[:80]}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", args.out,
               "--scheme", scheme_for(arch, shape)]
        if mp:
            cmd.append("--multi-pod")
        t0 = time.time()
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=args.timeout,
                                  env={**os.environ, "PYTHONPATH": "src"})
            status = "ok" if proc.returncode == 0 else "fail"
            tail = (proc.stderr or proc.stdout or "").strip()[-400:]
        except subprocess.TimeoutExpired:
            status, tail = "timeout", ""
            rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "status": "TIMEOUT", "timeout_s": args.timeout}
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
        dt = time.time() - t0
        print(f"[sweep] {i+1}/{len(todo)} {status} {key} ({dt:.0f}s)"
              + ("" if status == "ok" else f"\n  {tail}"), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
