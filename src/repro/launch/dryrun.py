"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

MUST be the very first statements — before any other import (jax locks the
device count at first init):
"""
import os  # noqa: E402
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis import roofline as rl  # noqa: E402
from repro.configs import SHAPES, get_config, list_archs, shape_applicable  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serve import engine  # noqa: E402
from repro.train import optimizer as opt_lib  # noqa: E402
from repro.train import step as step_lib  # noqa: E402


def input_specs(arch: str, shape_name: str = "train_4k") -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    Weak-type-correct, shardable, no device allocation. Training cells get
    {tokens, labels}; prefill cells {tokens}; decode cells {tokens, pos}.
    Modality frontends are stubs: VLM cells add precomputed patch
    embeddings, audio cells add precomputed frame embeddings.
    """
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    b = shp.global_batch
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if shp.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((b, shp.seq_len), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((b, shp.seq_len), jnp.int32)}
    elif shp.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, shp.seq_len), jnp.int32)}
    else:  # decode: one new token against a seq_len cache
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                 "pos": jax.ShapeDtypeStruct((b,), jnp.int32)}
    if cfg.cross_every and shp.kind != "decode":
        specs["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_seq, cfg.d_model), dt)
    if cfg.is_encoder_decoder and shp.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.audio_seq, cfg.d_model), dt)
    return specs


# Giant archs: parameters FSDP-shard over (pod, data) in addition to
# model-axis TP (arctic's 477B cannot fit at TP-16), and arctic trains
# masterless (pure-bf16 AdamW with stochastic rounding on TPU): master
# fp32 alone would be 477e9*4/256 = 7.5 GiB/device.
FSDP_PARAMS = {"arctic-480b", "llama-3.2-vision-90b"}
NO_MASTER = {"arctic-480b"}
# Gradient accumulation (microbatching) for train cells whose activation
# working set exceeds HBM at full batch — the standard production lever.
# (dp-scheme models excluded: their grad accumulators are replicated
# fp32 trees, so accumulation *adds* memory — measured in §Perf)
TRAIN_ACCUM = {"llama-3.2-vision-90b": 4, "arctic-480b": 4,
               "qwen2.5-32b": 4, "jamba-v0.1-52b": 4,
               "moonshot-v1-16b-a3b": 2}


def _lower_cell(arch: str, shape_name: str, mesh, rules: shd.ShardingRules,
                opt_cfg: Optional[opt_lib.OptConfig] = None,
                donate: bool = True, scheme: str = "sp"):
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    rules = shd.scheme_rules(scheme, rules)
    fsdp = arch in FSDP_PARAMS
    model = build_model(cfg, sharder=shd.make_sharder(mesh, rules, scheme))
    specs = input_specs(arch, shape_name)
    batch_sh = step_lib.batch_shardings(mesh, specs, rules)

    if shp.kind == "train":
        opt_cfg = opt_cfg or opt_lib.OptConfig(
            moment_dtype="bfloat16", keep_master=arch not in NO_MASTER)
        fn = step_lib.make_train_step(model, opt_cfg,
                                      accum=TRAIN_ACCUM.get(arch, 1))
        state_shape = step_lib.state_shapes(model, opt_cfg)
        state_sh = step_lib.state_shardings(model, opt_cfg, mesh, rules,
                                            scheme=scheme, fsdp_params=fsdp)
        jf = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                     donate_argnums=(0,) if donate else ())
        with mesh:
            lowered = jf.lower(state_shape, specs)
    elif shp.kind == "prefill":
        fn = engine.make_prefill_step(model)
        p_sh = engine.param_shardings(model, mesh, rules, fsdp_params=fsdp)
        c_sh, _ = engine.cache_shardings(model, mesh, shp.global_batch,
                                         shp.seq_len, rules)
        jf = jax.jit(fn, in_shardings=(p_sh, batch_sh),
                     out_shardings=(NamedSharding(mesh, P()), c_sh))
        with mesh:
            lowered = jf.lower(model.init_shape(), specs)
    else:  # decode
        fn = engine.make_decode_step(model)
        p_sh = engine.param_shardings(model, mesh, rules, fsdp_params=fsdp)
        c_sh, c_shape = engine.cache_shardings(model, mesh, shp.global_batch,
                                               shp.seq_len, rules)
        jf = jax.jit(fn,
                     in_shardings=(p_sh, c_sh, batch_sh["tokens"],
                                   batch_sh["pos"]),
                     donate_argnums=(1,) if donate else ())
        with mesh:
            lowered = jf.lower(model.init_shape(), c_shape,
                               specs["tokens"], specs["pos"])
    return model, lowered


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                rules: Optional[shd.ShardingRules] = None,
                verbose: bool = True, scheme: str = "sp",
                service=None) -> Dict:
    """Lower + compile one cell; return roofline record (§Dry-run/§Roofline).

    ``service`` is anything with ``predict_one(cfg, batch, seq)`` — a
    ``PredictionService`` or the micro-batched ``AbacusServer`` gateway.
    Train cells then carry the DNNAbacus (predicted) step time/memory
    next to the roofline numbers; repeated sweeps hit the trace cache,
    and with a ``TraceStore`` behind it, fresh processes warm-start.
    """
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shp)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}
    rules = rules or shd.ShardingRules()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size

    t0 = time.time()
    model, lowered = _lower_cell(arch, shape_name, mesh, rules, scheme=scheme)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    roof = rl.analyze(compiled)
    mflops = rl.model_flops(cfg, shp, model.param_count(),
                            model.param_count(active_only=True)) / n_dev
    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "devices": n_dev, "status": "ok", "scheme": scheme,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        **roof.summary(mflops),
    }
    if service is not None and shp.kind == "train":
        # the estimate is an optional annotation: never let a predictor
        # failure overwrite a successfully compiled cell's record
        try:
            est = service.predict_one(cfg, shp.global_batch, shp.seq_len)
            rec["abacus_time_s"] = round(est["time_s"], 4)
            rec["abacus_memory_gib"] = round(est["memory_bytes"] / 2**30, 3)
            if "generation" in est:
                rec["abacus_generation"] = est["generation"]
            # feed the compile-time ground truth we DO have back into the
            # refit loop: roofline-bound step time and XLA peak bytes are
            # measured proxies for the job's realized cost, so a dry-run
            # sweep doubles as a calibration pass over every train cell.
            observe = getattr(service, "observe", None)
            if observe is not None:
                t_roof = max(roof.t_compute, roof.t_memory,
                             roof.t_collective)
                observe(cfg, shp.global_batch, shp.seq_len,
                        float(t_roof), rec["peak_hbm_gib"] * 2**30,
                        predicted_time_s=est["time_s"],
                        predicted_mem_bytes=est["memory_bytes"],
                        generation=est.get("generation"),
                        job_id=f"dryrun:{arch}:{shape_name}")
        except Exception as e:
            rec["abacus_error"] = f"{type(e).__name__}: {e}"[:200]
    if verbose:
        ma = compiled.memory_analysis()
        print(f"[dryrun] {arch} x {shape_name} mesh={mesh.devices.shape}")
        print(f"  memory_analysis: args={rec['argument_gib']:.2f}GiB "
              f"temp={rec['temp_gib']:.2f}GiB peak={rec['peak_hbm_gib']:.2f}GiB")
        print(f"  cost_analysis: flops/dev={roof.flops:.3e} "
              f"bytes/dev={roof.bytes_accessed:.3e}")
        print(f"  collectives: { {k: int(v['count']) for k, v in roof.collectives.items()} }")
        print(f"  roofline: compute={roof.t_compute*1e3:.2f}ms "
              f"memory={roof.t_memory*1e3:.2f}ms "
              f"collective={roof.t_collective*1e3:.2f}ms "
              f"-> bound by {roof.bottleneck}")
        print(f"  model_flops/dev={mflops:.3e} useful={rec['useful_flop_fraction']:.3f} "
              f"mfu_bound={rec.get('mfu_bound', 0):.3f}")
        del ma
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--scheme", default="sp", help="sp | sp_heads | tp")
    ap.add_argument("--predict", action="store_true",
                    help="attach DNNAbacus estimates to train cells")
    ap.add_argument("--predictor-path", default="artifacts/abacus")
    ap.add_argument("--trace-store", default="artifacts/trace_store",
                    help="persistent trace dir ('' disables): repeated "
                         "dry-runs warm-start instead of re-tracing")
    ap.add_argument("--feedback-store", default="artifacts/feedback_store",
                    help="persistent measured-cost observations ('' "
                         "disables): each predicted train cell's roofline "
                         "time / peak HBM feed the online-refit loop")
    ap.add_argument("--replicas", type=int, default=1,
                    help="gateway replicas: > 1 serves estimates from a "
                         "fingerprint-sharded ClusterFrontend (per-replica "
                         "trace/feedback slices under the store paths)")
    ap.add_argument("--resize-to", type=int, default=0,
                    help="live-reshard the fleet to this many replicas "
                         "after the first arch (drain -> migrate -> "
                         "cutover under the sweep's own load; requires "
                         "--replicas > 1)")
    ap.add_argument("--rpc", action="store_true",
                    help="with --replicas > 1: spawn each gateway as its "
                         "own process (python -m repro.serve.rpc) behind "
                         "the TCP frame transport; a crashed replica is "
                         "auto-excluded and its warm slice rebuilt from "
                         "disk by the surviving owners")
    ap.add_argument("--store-backend", default=None,
                    choices=("json", "segment"),
                    help="physical layout for the trace/feedback stores "
                         "(default: REPRO_STORE_BACKEND env var, else "
                         "json); exported to RPC children so every "
                         "process reads one layout")
    ap.add_argument("--metrics-out", default=None,
                    help="with --predict: write the serving metrics "
                         "snapshot here at sweep end (.prom/.txt -> "
                         "Prometheus text exposition, else JSON)")
    ap.add_argument("--events-out", default=None,
                    help="with --predict: append structured JSONL "
                         "lifecycle events (gen swaps, reshards, "
                         "exclusions, refits) to this file; RPC children "
                         "append to the same file")
    args = ap.parse_args(argv)

    service = server = None
    rpc_fleet = []
    if args.rpc and args.replicas < 2:
        print("[dryrun] --rpc needs a fleet (--replicas > 1); serving "
              "in-process", file=sys.stderr)
        args.rpc = False
    if args.store_backend:
        # one env var selects the layout everywhere: the factories below
        # read it, and spawned RPC children inherit it
        os.environ["REPRO_STORE_BACKEND"] = args.store_backend
    if args.predict:
        from repro.core.predictor import DNNAbacus
        from repro.obs import events
        from repro.serve.feedback_store import make_feedback_store
        from repro.serve.server import AbacusServer
        from repro.serve.trace_store import make_trace_store
        if args.events_out:
            # O_APPEND one-line writes: RPC children share the same file
            events.configure(path=args.events_out)
        if os.path.exists(args.predictor_path + ".json"):
            if args.rpc:
                # process-separated fleet: each gateway is its own
                # ``python -m repro.serve.rpc`` child; the frontend
                # routes over TCP and keeps LOCAL store handles on the
                # same slice directories (shared disk), so exclusion
                # and migration work exactly as in-process.
                from repro.serve.cluster import ClusterFrontend
                from repro.serve.rpc import spawn_replica, shutdown_fleet
                try:
                    for i in range(args.replicas):
                        name = f"r{i}"
                        rpc_fleet.append(spawn_replica(
                            name, args.predictor_path,
                            trace_root=(os.path.join(args.trace_store, name)
                                        if args.trace_store else None),
                            feedback_root=(
                                os.path.join(args.feedback_store, name)
                                if args.feedback_store else None),
                            event_log=args.events_out or None))
                except BaseException:
                    shutdown_fleet(rpc_fleet)
                    raise
                server = ClusterFrontend(replicas=rpc_fleet,
                                         hedge_after_s=5.0).start()
            elif args.replicas > 1:
                # the fleet path: estimates route by config fingerprint
                # to N sharded gateways; each cell's observation lands
                # in the owning replica's feedback slice, ready for a
                # later federated refit pass.
                from repro.serve.cluster import ClusterFrontend
                server = ClusterFrontend(
                    DNNAbacus.load(args.predictor_path),
                    n_replicas=args.replicas,
                    trace_root=args.trace_store or None,
                    feedback_root=args.feedback_store or None).start()
            else:
                store = (make_trace_store(args.trace_store)
                         if args.trace_store else None)
                service = DNNAbacus.load(
                    args.predictor_path).service(store=store)
                feedback = (make_feedback_store(args.feedback_store)
                            if args.feedback_store else None)
                # estimates go through the micro-batched gateway, sharing
                # its trace cache (and store) with any concurrent admission
                # loop; observed cell costs land in the feedback store so a
                # later refit pass (OnlineRefitter) can consume them.
                server = AbacusServer(service, feedback=feedback).start()
        else:
            print(f"[dryrun] no fitted predictor at {args.predictor_path}; "
                  "skipping estimates", file=sys.stderr)

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    resize_to = int(args.resize_to or 0)
    if resize_to and not hasattr(server, "resize"):
        print("[dryrun] --resize-to needs a fleet (--replicas > 1); "
              "ignoring", file=sys.stderr)
        resize_to = 0
    if resize_to and rpc_fleet and resize_to > len(rpc_fleet):
        # growing an RPC fleet means spawning processes, which the
        # reshard recipe (it mints in-process gateways) cannot do
        print("[dryrun] --resize-to growth is not supported with --rpc; "
              "ignoring", file=sys.stderr)
        resize_to = 0
    failures = 0
    try:
        for arch in archs:
            for shape_name in shapes:
                for mp in meshes:
                    try:
                        rec = dryrun_cell(arch, shape_name, multi_pod=mp,
                                          scheme=args.scheme,
                                          service=server or service)
                    except Exception as e:  # a failure here is a sharding bug
                        rec = {"arch": arch, "shape": shape_name,
                               "multi_pod": mp, "status": "FAILED",
                               "error": f"{type(e).__name__}: {e}"}
                        failures += 1
                        print(f"[dryrun] FAILED {arch} x {shape_name} mp={mp}: "
                              f"{rec['error'][:500]}", file=sys.stderr)
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(json.dumps(rec) + "\n")
            if resize_to:
                # live reshard mid-sweep: remaining cells exercise the
                # post-cutover fleet (warm slices migrated, not retraced)
                mig = server.resize(resize_to)
                print(f"[dryrun] resharded fleet {len(mig['from'])} -> "
                      f"{len(mig['to'])} replicas: {mig['keys_moved']} keys "
                      f"moved ({mig['moved_fraction_bound']:.0%} of keyspace; "
                      f"naive rehash = 100%), {mig['cutover_ticks']} drain "
                      "ticks", file=sys.stderr)
                resize_to = 0
    finally:
        if server is not None:
            # works for both the single gateway and the cluster frontend
            # (whose calibration is the count-weighted fleet merge)
            cal = server.stats()["calibration"]
            if cal["count"]:
                print(f"[dryrun] calibration over {cal['count']} cells: "
                      f"time_mre={cal['time_mre']:.3f} "
                      f"time_drift={cal['time_drift']:+.3f} "
                      f"mem_mre={cal['mem_mre']:.3f}", file=sys.stderr)
            reshard = getattr(server, "reshard_stats", None)
            if reshard and reshard["reshards"]:
                print(f"[dryrun] reshards={reshard['reshards']} "
                      f"keys_moved={reshard['keys_moved']} "
                      f"replayed={reshard['keys_replayed']}", file=sys.stderr)
            if args.metrics_out:
                # snapshot BEFORE stop(): a fleet frontend fetches each
                # replica's registry over RPC, so the fleet must be alive
                try:
                    if args.metrics_out.endswith((".prom", ".txt")):
                        body = server.metrics_text()
                    else:
                        body = json.dumps(server.metrics_snapshot(),
                                          indent=2, sort_keys=True)
                    with open(args.metrics_out, "w") as f:
                        f.write(body + "\n")
                    print(f"[dryrun] metrics snapshot -> {args.metrics_out}",
                          file=sys.stderr)
                except Exception as e:
                    print(f"[dryrun] metrics snapshot failed: "
                          f"{type(e).__name__}: {e}", file=sys.stderr)
            server.stop()
        if rpc_fleet:
            from repro.serve.rpc import shutdown_fleet
            shutdown_fleet(rpc_fleet)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
