"""Mixture-of-Experts with grouped one-hot (GSPMD-style) dispatch.

TPU-native adaptation: instead of gather/scatter (MegaBlocks-style, a GPU
pattern), tokens are routed with capacity-bounded one-hot dispatch/combine
einsums — the XLA partitioner turns the expert-sharded einsums into
all-to-alls on the ``model`` axis. Tokens are split into groups of
``cfg.moe_group_size`` so the dispatch tensor stays
``tokens × (group·k·capacity_factor)`` elements, independent of E.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.module import spec

AUX_LOSS_WEIGHT = 0.01


def moe_spec(cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": spec((d, e), ("embed", None), "small", dtype=jnp.float32),
        "wi": spec((e, d, 2, f), ("experts", "embed", None, "mlp")),
        "wo": spec((e, f, d), ("experts", "mlp", "embed")),
    }


def capacity(cfg, group_size: int) -> int:
    c = int(group_size * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4, >= 4


def _constrain(shard, name, x):
    if shard is None:
        return x
    sh = shard(name, x.shape)
    import jax.lax
    return jax.lax.with_sharding_constraint(x, sh) if sh is not None else x


def apply_moe(p, cfg, x, shard=None):
    """x (B,S,D) -> (y (B,S,D), aux_loss scalar fp32)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    gs = min(cfg.moe_group_size, b * s)
    tokens = b * s
    xt = x.reshape(tokens, d)
    if tokens % gs:  # pad to a group multiple; padded rows are sliced off
        pad = gs - tokens % gs
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    g = xt.shape[0] // gs
    xg = xt.reshape(g, gs, d)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)  # (G,gs,E) fp32

    top_vals, top_idx = jax.lax.top_k(gates, k)  # (G,gs,k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)

    c = capacity(cfg, gs)
    eh = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)  # (G,gs,k,E)
    # Priority order: token-major, then choice rank.
    ehf = eh.reshape(g, gs * k, e)
    pos = jnp.cumsum(ehf, axis=1) - ehf  # (G,gs*k,E) slot within expert
    keep = (pos < c).astype(jnp.float32) * ehf
    disp = keep[..., None] * jax.nn.one_hot(pos.astype(jnp.int32), c,
                                            dtype=jnp.float32)  # (G,gs*k,E,C)
    comb = disp * top_vals.reshape(g, gs * k)[..., None, None]
    # Fold the k choices back onto tokens (each (token,expert) pair unique).
    disp4 = disp.reshape(g, gs, k, e, c).sum(axis=2).astype(x.dtype)
    comb4 = comb.reshape(g, gs, k, e, c).sum(axis=2).astype(x.dtype)
    disp4 = _constrain(shard, "moe_disp", disp4)
    comb4 = _constrain(shard, "moe_disp", comb4)

    xe = jnp.einsum("gsec,gsd->gecd", disp4, xg)  # (G,E,C,D) dispatch
    xe = _constrain(shard, "moe_xe", xe)
    hi = jnp.einsum("gecd,ednf->gecnf", xe, p["wi"].astype(x.dtype))
    h = jax.nn.silu(hi[..., 0, :].astype(jnp.float32)).astype(x.dtype) * hi[..., 1, :]
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(x.dtype))
    ye = _constrain(shard, "moe_xe", ye)
    y = jnp.einsum("gecd,gsec->gsd", ye, comb4)  # combine
    y = y.reshape(-1, d)[:tokens]

    # Load-balance auxiliary loss (Switch-style): E * sum(frac_tokens * frac_prob)
    frac_tokens = jnp.mean(eh.sum(axis=2), axis=(0, 1))  # (E,)
    frac_prob = jnp.mean(gates, axis=(0, 1))  # (E,)
    aux = e * jnp.sum(frac_tokens * frac_prob) * AUX_LOSS_WEIGHT

    return y.reshape(b, s, d), aux


def moe_flops_per_token(cfg) -> int:
    """Forward matmul FLOPs per token (routing + experts at capacity)."""
    d, f, k, cf = cfg.d_model, cfg.d_ff, cfg.top_k, cfg.capacity_factor
    expert = 2 * k * cf * d * 3 * f
    dispatch = 2 * 2 * (cfg.moe_group_size * k * cf) * d
    router = 2 * d * cfg.num_experts
    return int(expert + dispatch + router)
