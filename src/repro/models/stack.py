"""The stacked sequence model covering all ten assigned architectures.

One class, parameterized by ``ModelConfig``: dense / MoE / hybrid(SSM+attn) /
VLM(cross-attn) / enc-dec(audio) / pure-SSM stacks are all instances of a
*periodic layer pattern* scanned with ``jax.lax.scan`` over stacked
parameters (HLO size O(period), compile time independent of depth).

Entry points:
  ``loss(params, batch)``          training objective (+ metrics)
  ``forward(params, batch)``       full-sequence logits
  ``prefill(params, batch)``       logits + populated decode cache
  ``decode_step(params, cache, tokens, pos)``  one-token serving step
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.module import (count_params, init_params, logical_axes,
                                 shape_tree, spec, stack_specs)

Pytree = Any


def _dtype_of(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[cfg.dtype]


class StackModel:
    def __init__(self, cfg: ModelConfig, sharder: Optional[Callable] = None):
        self.cfg = cfg
        self.dtype = _dtype_of(cfg)
        self.pattern = cfg.pattern()
        self.sharder = sharder  # name -> Sharding | None

    # ------------------------------------------------------------------
    # Parameter specs
    # ------------------------------------------------------------------
    def _layer_spec(self, mixer: str, mlp: str) -> Dict:
        cfg = self.cfg
        p: Dict[str, Any] = {"norm1": L.norm_spec(cfg)}
        if mixer == "attn" or mixer == "enc_attn":
            p["mixer"] = attn.attn_spec(cfg)
        elif mixer == "cross":
            p["mixer"] = attn.attn_spec(cfg)
            p["gate_attn"] = spec((), (), "zeros", dtype=jnp.float32)
        elif mixer == "attn_cross":
            p["mixer"] = attn.attn_spec(cfg)
            p["norm_x"] = L.norm_spec(cfg)
            p["cross"] = attn.attn_spec(cfg)
        elif mixer == "ssm":
            p["mixer"] = ssm_lib.ssm_spec(cfg)
        else:
            raise ValueError(mixer)
        if mlp != "none":
            p["norm2"] = L.norm_spec(cfg)
        if mlp == "dense":
            p["mlp"] = L.mlp_spec(cfg)
        elif mlp == "moe":
            p["mlp"] = moe_lib.moe_spec(cfg)
        elif mlp == "moe_dense":
            p["mlp"] = moe_lib.moe_spec(cfg)
            p["mlp_dense"] = L.mlp_spec(cfg)
        return p

    def param_spec(self) -> Pytree:
        cfg = self.cfg
        layer_specs = {}
        for i, (mixer, mlp) in enumerate(self.pattern):
            layer_specs[f"L{i}"] = self._layer_spec(mixer, mlp)
        tree = {
            "embed": L.embed_spec(cfg),
            "layers": stack_specs(layer_specs, cfg.num_periods, None),
            "final_norm": L.norm_spec(cfg),
        }
        if cfg.is_encoder_decoder:
            enc = {f"L{i}": self._layer_spec("enc_attn", "dense")
                   for i in range(1)}  # encoder period is 1
            tree["encoder"] = stack_specs(enc, cfg.encoder_layers, None)
            tree["enc_norm"] = L.norm_spec(cfg)
        return tree

    def init(self, key) -> Pytree:
        return init_params(self.param_spec(), key, self.dtype)

    def init_shape(self) -> Pytree:
        return shape_tree(self.param_spec(), self.dtype)

    def param_axes(self) -> Pytree:
        return logical_axes(self.param_spec())

    def param_count(self, active_only: bool = False) -> int:
        spec_tree = self.param_spec()
        total = count_params(spec_tree)
        if not active_only or not self.cfg.num_experts:
            return total
        # Scale expert tensors by top_k / num_experts.
        cfg = self.cfg
        inactive = 0
        for path, leaf in jax.tree.flatten_with_path(
                spec_tree, is_leaf=lambda x: hasattr(x, "axes"))[0]:
            keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
            if "mlp" in keys and "experts" in leaf.axes:
                n = math.prod(leaf.shape)
                inactive += int(n * (1 - cfg.top_k / cfg.num_experts))
        return total - inactive

    # ------------------------------------------------------------------
    # Forward pass
    # ------------------------------------------------------------------
    def _constrain(self, x, name):
        if self.sharder is None:
            return x
        s = self.sharder(name, x.shape)
        return jax.lax.with_sharding_constraint(x, s) if s is not None else x

    def _apply_layer(self, i: int, p, x, positions, memory):
        cfg = self.cfg
        mixer, mlp = self.pattern[i]
        h = L.apply_norm(p["norm1"], x)
        if mixer == "attn":
            y, _ = attn.apply_self_attn(p["mixer"], cfg, h, positions,
                                        shard=self.sharder)
        elif mixer == "enc_attn":
            y, _ = attn.apply_self_attn(p["mixer"], cfg, h, positions,
                                        shard=self.sharder, causal=False)
        elif mixer == "cross":
            y, _ = attn.apply_cross_attn(p["mixer"], cfg, h, memory)
            y = y * jnp.tanh(p["gate_attn"]).astype(y.dtype)
        elif mixer == "attn_cross":
            y, _ = attn.apply_self_attn(p["mixer"], cfg, h, positions)
            x = x + y
            hx = L.apply_norm(p["norm_x"], x)
            y, _ = attn.apply_cross_attn(p["cross"], cfg, hx, memory)
        elif mixer == "ssm":
            y, _ = ssm_lib.apply_ssm(p["mixer"], cfg, h)
        else:
            raise ValueError(mixer)
        x = x + y
        x = self._constrain(x, "acts")
        aux = jnp.zeros((), jnp.float32)
        if mlp != "none":
            h = L.apply_norm(p["norm2"], x)
            if mlp == "dense":
                y = L.apply_mlp(p["mlp"], h)
            elif mlp == "moe":
                y, aux = moe_lib.apply_moe(p["mlp"], self.cfg, h,
                                           shard=self.sharder)
            elif mlp == "moe_dense":
                y, aux = moe_lib.apply_moe(p["mlp"], self.cfg, h,
                                           shard=self.sharder)
                y = y + L.apply_mlp(p["mlp_dense"], h)
            x = x + y
            x = self._constrain(x, "acts")
        return x, aux

    def _remat_wrap(self, f):
        cfg = self.cfg
        if cfg.remat == "none":
            return f
        if cfg.remat == "dots":
            pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            return jax.checkpoint(f, policy=pol)
        return jax.checkpoint(f)

    def _run_stack(self, params, x, positions, memory):
        def body(carry, layer_params):
            h, aux = carry
            for i in range(len(self.pattern)):
                h, a = self._apply_layer(i, layer_params[f"L{i}"], h,
                                         positions, memory)
                aux = aux + a
            return (h, aux), None

        body = self._remat_wrap(body)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
        return x, aux

    def _encode(self, params, frames):
        """Whisper-style encoder over stub frame embeddings (B,M,D)."""
        x = frames.astype(self.dtype)

        def body(h, layer_params):
            h, _ = self._apply_layer_generic(layer_params["L0"], h,
                                             "enc_attn", "dense")
            return h, None

        body = self._remat_wrap(body)
        x, _ = jax.lax.scan(body, x, params["encoder"])
        return L.apply_norm(params["enc_norm"], x)

    def _apply_layer_generic(self, p, x, mixer, mlp):
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        h = L.apply_norm(p["norm1"], x)
        y, _ = attn.apply_self_attn(p["mixer"], self.cfg, h, positions,
                                    shard=self.sharder, causal=False)
        x = x + y
        h = L.apply_norm(p["norm2"], x)
        x = x + L.apply_mlp(p["mlp"], h)
        return x, None

    def _memory_of(self, params, batch):
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            return self._encode(params, batch["frames"])
        if cfg.cross_every:
            return batch["patches"].astype(self.dtype)
        return None

    def forward(self, params, batch) -> jax.Array:
        """batch: {"tokens": (B,S) int32, ...modality inputs}. -> logits."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = L.embed_tokens(params["embed"], tokens, self.dtype)
        x = self._constrain(x, "acts")
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
        memory = self._memory_of(params, batch)
        x, aux = self._run_stack(params, x, positions, memory)
        x = L.apply_norm(params["final_norm"], x)
        logits = L.unembed(params["embed"], x, cfg.logits_softcap, cfg.vocab_size)
        return logits, aux

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        ce = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
        total = ce + aux
        return total, {"loss": total, "ce": ce, "aux": aux}

    # ------------------------------------------------------------------
    # Serving: prefill + decode
    # ------------------------------------------------------------------
    def _layer_cache_spec(self, i: int, batch: int, seq: int):
        cfg = self.cfg
        mixer, _ = self.pattern[i]
        kvs = attn.kv_cache_shape(cfg, batch, seq)
        ca = ("batch", "cache_seq", "kv_heads", "head_dim")
        if mixer in ("attn",):
            return {k: (v, ca, self._cache_dtype) for k, v in kvs.items()}
        if mixer == "cross":
            m = cfg.vision_seq
            kvs = attn.kv_cache_shape(cfg, batch, m)
            return {k: (v, ca, self._cache_dtype) for k, v in kvs.items()}
        if mixer == "attn_cross":
            out = {k: (v, ca, self._cache_dtype) for k, v in kvs.items()}
            kvm = attn.kv_cache_shape(cfg, batch, cfg.audio_seq)
            out.update({f"x{k}": (v, ca, self._cache_dtype)
                        for k, v in kvm.items()})
            return out
        if mixer == "ssm":
            shp = ssm_lib.ssm_cache_shape(cfg, batch)
            axes = {"state": ("batch", "heads", None, None),
                    "conv_x": ("batch", None, "mlp"),
                    "conv_b": ("batch", None, None),
                    "conv_c": ("batch", None, None)}
            return {k: (v, axes[k], jnp.float32 if k == "state" else self._cache_dtype)
                    for k, v in shp.items()}
        raise ValueError(mixer)

    @property
    def _cache_dtype(self):
        return self.dtype

    def cache_spec(self, batch: int, seq: int):
        """Returns (shape_tree, logical_axes_tree) for the decode cache."""
        cfg = self.cfg
        shapes, axes = {}, {}
        for i in range(len(self.pattern)):
            entry = self._layer_cache_spec(i, batch, seq)
            shapes[f"L{i}"] = {k: jax.ShapeDtypeStruct((cfg.num_periods,) + shp, dt)
                               for k, (shp, ax, dt) in entry.items()}
            axes[f"L{i}"] = {k: (None,) + ax for k, (shp, ax, dt) in entry.items()}
        return shapes, axes

    def init_cache(self, batch: int, seq: int):
        shapes, _ = self.cache_spec(batch, seq)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)

    def prefill(self, params, batch):
        """Full-sequence forward that also builds the decode cache.

        Returns (last_token_logits, cache, aux).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = L.embed_tokens(params["embed"], tokens, self.dtype)
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
        memory = self._memory_of(params, batch)

        def body(h, layer_params):
            caches = {}
            for i, (mixer, mlp) in enumerate(self.pattern):
                p = layer_params[f"L{i}"]
                hn = L.apply_norm(p["norm1"], h)
                cache_i = {}
                if mixer == "attn":
                    y, (k, v) = attn.apply_self_attn(p["mixer"], cfg, hn, positions)
                    cache_i = {"k": k.astype(self._cache_dtype),
                               "v": v.astype(self._cache_dtype)}
                elif mixer == "cross":
                    y, (k, v) = attn.apply_cross_attn(p["mixer"], cfg, hn, memory)
                    y = y * jnp.tanh(p["gate_attn"]).astype(y.dtype)
                    cache_i = {"k": k.astype(self._cache_dtype),
                               "v": v.astype(self._cache_dtype)}
                elif mixer == "attn_cross":
                    y, (k, v) = attn.apply_self_attn(p["mixer"], cfg, hn, positions)
                    h = h + y
                    hx = L.apply_norm(p["norm_x"], h)
                    y, (xk, xv) = attn.apply_cross_attn(p["cross"], cfg, hx, memory)
                    cache_i = {"k": k.astype(self._cache_dtype),
                               "v": v.astype(self._cache_dtype),
                               "xk": xk.astype(self._cache_dtype),
                               "xv": xv.astype(self._cache_dtype)}
                elif mixer == "ssm":
                    y, ssm_cache = ssm_lib.apply_ssm(p["mixer"], cfg, hn,
                                                     return_cache=True)
                    cache_i = ssm_cache
                h = h + y
                if mlp != "none":
                    hn = L.apply_norm(p["norm2"], h)
                    if mlp == "dense":
                        y = L.apply_mlp(p["mlp"], hn)
                    elif mlp == "moe":
                        y, _ = moe_lib.apply_moe(p["mlp"], cfg, hn, shard=self.sharder)
                    else:
                        y, _ = moe_lib.apply_moe(p["mlp"], cfg, hn, shard=self.sharder)
                        y = y + L.apply_mlp(p["mlp_dense"], hn)
                    h = h + y
                h = self._constrain(h, "acts")
                caches[f"L{i}"] = cache_i
            return h, caches

        body = self._remat_wrap(body)
        x, cache = jax.lax.scan(body, x, params["layers"])
        x = L.apply_norm(params["final_norm"], x)
        logits = L.unembed(params["embed"], x[:, -1:], cfg.logits_softcap, cfg.vocab_size)
        return logits, cache

    def decode_step(self, params, cache, tokens, pos, memory=None):
        """tokens (B,1) int32; pos (B,) write positions. -> (logits, cache)."""
        cfg = self.cfg
        x = L.embed_tokens(params["embed"], tokens, self.dtype)

        def body(h, xs):
            layer_params, layer_cache = xs
            new_cache = {}
            for i, (mixer, mlp) in enumerate(self.pattern):
                p, c = layer_params[f"L{i}"], layer_cache[f"L{i}"]
                hn = L.apply_norm(p["norm1"], h)
                if mixer == "attn":
                    y, c = attn.decode_self_attn(p["mixer"], cfg, hn, c, pos,
                                                 shard=self.sharder)
                elif mixer == "cross":
                    y, c = attn.decode_cross_attn(p["mixer"], cfg, hn, c)
                    y = y * jnp.tanh(p["gate_attn"]).astype(y.dtype)
                elif mixer == "attn_cross":
                    y, sc = attn.decode_self_attn(
                        p["mixer"], cfg, hn, {"k": c["k"], "v": c["v"]}, pos,
                        shard=self.sharder)
                    h = h + y
                    hx = L.apply_norm(p["norm_x"], h)
                    y, _ = attn.decode_cross_attn(
                        p["cross"], cfg, hx, {"k": c["xk"], "v": c["xv"]})
                    c = {**sc, "xk": c["xk"], "xv": c["xv"]}
                elif mixer == "ssm":
                    y, c = ssm_lib.decode_ssm(p["mixer"], cfg, hn, c)
                h = h + y
                if mlp != "none":
                    hn = L.apply_norm(p["norm2"], h)
                    if mlp == "dense":
                        y = L.apply_mlp(p["mlp"], hn)
                    elif mlp == "moe":
                        y, _ = moe_lib.apply_moe(p["mlp"], cfg, hn, shard=self.sharder)
                    else:
                        y, _ = moe_lib.apply_moe(p["mlp"], cfg, hn, shard=self.sharder)
                        y = y + L.apply_mlp(p["mlp_dense"], hn)
                    h = h + y
                new_cache[f"L{i}"] = c
            return h, new_cache

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        x = L.apply_norm(params["final_norm"], x)
        logits = L.unembed(params["embed"], x, cfg.logits_softcap, cfg.vocab_size)
        return logits, new_cache
