from repro.models.api import build_model  # noqa: F401
