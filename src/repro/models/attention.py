"""Grouped-query attention: training, prefill, and KV-cache decode paths.

Sharding design (see DESIGN.md §5): projection kernels are stored
*flattened* — ``wq (D, H*hd)``, ``wk/wv (D, KV*hd)``, ``wo (H*hd, D)`` —
because the flattened fan-out is divisible by the 16-way ``model`` axis
for every assigned architecture, while raw head counts (40, 24, 56, 14,
6…) are not, and jit ``in_shardings`` require even division. Activations
are reshaped to (B,S,H,hd) and head-sharded via *constraints*, where GSPMD
tolerates uneven (padded) sharding. GQA K/V are broadcast to the full head
count at compute time (the Megatron convention when tp > kv_heads); the
cache stores only the KV heads.

The full-sequence causal path runs through either the XLA einsum
implementation (default — what the dry-run lowers and ``cost_analysis``
meters) or the Pallas flash-attention kernel
(``set_attention_impl("pallas")``; TPU target, interpret-mode on CPU).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.module import spec

_IMPL = "xla"


def set_attention_impl(impl: str) -> None:
    global _IMPL
    assert impl in ("xla", "pallas", "pallas_interpret"), impl
    _IMPL = impl


def get_attention_impl() -> str:
    return _IMPL


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attn_spec(cfg):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    p = {
        "wq": spec((d, h * hd), ("embed", "heads_flat")),
        "wk": spec((d, kv * hd), ("embed", "kv_flat")),
        "wv": spec((d, kv * hd), ("embed", "kv_flat")),
        "wo": spec((h * hd, d), ("heads_flat", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = spec((h * hd,), ("heads_flat",), "zeros")
        p["bk"] = spec((kv * hd,), ("kv_flat",), "zeros")
        p["bv"] = spec((kv * hd,), ("kv_flat",), "zeros")
    return p


def _heads(cfg):
    return cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim


def _project_q(p, cfg, x):
    h, _, hd = _heads(cfg)
    q = x @ p["wq"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    return q.reshape(x.shape[0], x.shape[1], h, hd)


def _project_kv(p, cfg, src, dtype):
    _, kv, hd = _heads(cfg)
    k = src @ p["wk"].astype(dtype)
    v = src @ p["wv"].astype(dtype)
    if "bk" in p:
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    shp = (src.shape[0], src.shape[1], kv, hd)
    return k.reshape(shp), v.reshape(shp)


def _out_proj(p, ctx, dtype):
    b, s = ctx.shape[:2]
    return ctx.reshape(b, s, -1) @ p["wo"].astype(dtype)


def _expand_kv(cfg, k):
    """(B,S,KV,hd) -> (B,S,H,hd) by broadcasting each KV head over its group."""
    h, kv, hd = _heads(cfg)
    if kv == h:
        return k
    b, s = k.shape[:2]
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, h // kv, hd))
    return k.reshape(b, s, h, hd)


# ---------------------------------------------------------------------------
# Core attention math (XLA path); all tensors (B,S,H,hd) with full heads
# ---------------------------------------------------------------------------


def dot_attention(q, k, v, mask=None, score_shard=None):
    """q (B,Sq,H,hd), k/v (B,Sk,H,hd). mask broadcastable (B,1,Sq,Sk).

    fp32 softmax for fp32 inputs (smoke tests, small models). For bf16
    models the XLA path keeps the S x S tensor in bf16 with max-subtracted
    softmax — halving score-tensor HBM/ICI traffic — mirroring the memory
    profile of the Pallas flash kernel, which instead never materializes
    scores and accumulates in fp32 (exact path on real TPU).
    """
    hd = q.shape[-1]
    stat_dtype = jnp.float32 if q.dtype == jnp.float32 else q.dtype
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k,
                        preferred_element_type=stat_dtype)
    if score_shard is not None:
        scores = jax.lax.with_sharding_constraint(scores, score_shard)
    scores = scores * jnp.asarray(hd ** -0.5, stat_dtype)
    neg = jnp.asarray(-1e30 if stat_dtype == jnp.float32 else -3e38 / 4,
                      stat_dtype)
    if mask is not None:
        scores = jnp.where(mask, scores, neg)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", probs, v)


# Above this many query tokens the XLA path switches to a q-block scan so
# the S x S score tensor is never materialized (peak: block_q x S per head).
CHUNK_THRESHOLD = 4096
CHUNK_Q = 512


def causal_attention(q, k, v):
    if _IMPL in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        return kops.flash_attention(
            q, k, v, causal=True, interpret=(_IMPL == "pallas_interpret"))
    sq, sk = q.shape[1], k.shape[1]
    if sq > CHUNK_THRESHOLD and sq % CHUNK_Q == 0:
        return _chunked_causal_attention(q, k, v, CHUNK_Q)
    mask = (jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None])[None, None]
    return dot_attention(q, k, v, mask)


def _chunked_causal_attention(q, k, v, block_q: int):
    b, sq, h, hd = q.shape
    nblk = sq // block_q
    qb = jnp.moveaxis(q.reshape(b, nblk, block_q, h, hd), 1, 0)

    # checkpoint the chunk body: backward recomputes the chunk's scores
    # instead of saving stacked (nblk, B, H, bq, S) probabilities — the
    # same residual policy as the flash-attention kernel.
    @jax.checkpoint
    def blk_fn(i, qi):
        offs = i * block_q
        mask = (jnp.arange(sq)[None, :]
                <= (offs + jnp.arange(block_q))[:, None])[None, None]
        return dot_attention(qi, k, v, mask)

    def blk(carry, inp):
        i, qi = inp
        return carry, blk_fn(i, qi)

    _, ctx = jax.lax.scan(blk, 0, (jnp.arange(nblk), qb))
    return jnp.moveaxis(ctx, 0, 1).reshape(b, sq, h, hd)


# ---------------------------------------------------------------------------
# Layer-level entry points
# ---------------------------------------------------------------------------


def _constrain(shard, name, x):
    if shard is None:
        return x
    s = shard(name, x.shape)
    return jax.lax.with_sharding_constraint(x, s) if s is not None else x


def apply_self_attn(p, cfg, x, positions, shard=None, causal=True):
    """Full-sequence self-attention. Returns (y, (k_cache, v_cache))."""
    q = _project_q(p, cfg, x)
    k, v = _project_kv(p, cfg, x, x.dtype)
    q = _apply_rope(cfg, q, positions)
    k = _apply_rope(cfg, k, positions)
    kc, vc = k, v
    q = _constrain(shard, "acts_qkv", q)
    kf = _expand_kv(cfg, k)
    vf = _expand_kv(cfg, v)
    sq = q.shape[1]
    if sq > CHUNK_THRESHOLD and sq % CHUNK_Q == 0 and shard is not None:
        # hoist K/V to a replicated-over-model layout BEFORE the q-chunk
        # scan: one all-gather per layer instead of one per chunk
        kf = _constrain(shard, "acts_kv_repl", kf)
        vf = _constrain(shard, "acts_kv_repl", vf)
    else:
        kf = _constrain(shard, "acts_qkv", kf)
        vf = _constrain(shard, "acts_qkv", vf)
    if causal:
        ctx = causal_attention(q, kf, vf)
    else:
        ctx = dot_attention(q, kf, vf)
    ctx = _constrain(shard, "acts_qkv", ctx)
    return _out_proj(p, ctx, x.dtype), (kc, vc)


def apply_cross_attn(p, cfg, x, memory, shard=None):
    """Cross-attention to (B,M,D) memory (no mask, no RoPE)."""
    q = _constrain(shard, "acts_qkv", _project_q(p, cfg, x))
    k, v = _project_kv(p, cfg, memory, x.dtype)
    ctx = dot_attention(q, _expand_kv(cfg, k), _expand_kv(cfg, v))
    return _out_proj(p, ctx, x.dtype), (k, v)


def decode_self_attn(p, cfg, x_t, cache, pos, shard=None):
    """One-token decode. x_t (B,1,D); cache {"k","v"} (B,Smax,KV,hd);
    pos (B,) positions (attention masks per-request).

    The cache WRITE is a masked elementwise select at the
    batch-synchronized step offset ``pos[0]`` — it stays fully local on a
    seq-sharded cache, whereas a scatter (or a DUS at a dynamic offset)
    makes GSPMD regather the whole cache per layer. Ragged per-request
    positions are the engine's job (slot-aligned continuous batching);
    attention masking stays per-request via ``pos``.
    """
    q = _project_q(p, cfg, x_t)
    k_t, v_t = _project_kv(p, cfg, x_t, x_t.dtype)
    q = _apply_rope(cfg, q, pos[:, None])
    k_t = _apply_rope(cfg, k_t, pos[:, None])
    sel = (jnp.arange(cache["k"].shape[1]) == pos[0])[None, :, None, None]
    k = jnp.where(sel, k_t.astype(cache["k"].dtype)[:, :1], cache["k"])
    v = jnp.where(sel, v_t.astype(cache["v"].dtype)[:, :1], cache["v"])
    smax = k.shape[1]
    mask = (jnp.arange(smax)[None, :] <= pos[:, None])[:, None, None, None, :]
    # Grouped-query decode: the cache is NOT expanded to full heads (a 5x
    # traffic multiplier for 40q/8kv heads); scores stay sharded over the
    # cache-seq axis (flash-decoding split) — otherwise GSPMD gathers the
    # whole cache per layer.
    b = q.shape[0]
    h, kv, hd = _heads(cfg)
    g = h // kv
    q5 = q.reshape(b, 1, kv, g, hd)
    kq = k.astype(q.dtype)
    vq = v.astype(q.dtype)
    stat = jnp.float32 if q.dtype == jnp.float32 else q.dtype
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q5, kq,
                        preferred_element_type=stat)
    if shard is not None:
        ss = shard("decode_scores5", scores.shape)
        if ss is not None:
            scores = jax.lax.with_sharding_constraint(scores, ss)
    scores = scores * jnp.asarray(hd ** -0.5, stat)
    neg = jnp.asarray(-1e30 if stat == jnp.float32 else -3e38 / 4, stat)
    scores = jnp.where(mask, scores, neg)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bkgqs,bskd->bqkgd", probs, vq)
    ctx = ctx.reshape(b, 1, h, hd)
    return _out_proj(p, ctx, x_t.dtype), {"k": k, "v": v}


def decode_cross_attn(p, cfg, x_t, cache):
    q = _project_q(p, cfg, x_t)
    ctx = dot_attention(q, _expand_kv(cfg, cache["k"].astype(q.dtype)),
                        _expand_kv(cfg, cache["v"].astype(q.dtype)))
    return _out_proj(p, ctx, x_t.dtype), cache


def _apply_rope(cfg, x, positions):
    from repro.models.layers import apply_rope
    return apply_rope(x, positions, cfg.rope_theta, cfg.rope_style)


def kv_cache_shape(cfg, batch: int, seq: int):
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {"k": (batch, seq, kv, hd), "v": (batch, seq, kv, hd)}
