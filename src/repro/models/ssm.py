"""Mamba2 (SSD — state-space duality) blocks: chunked train path + decode.

The chunked SSD algorithm (arXiv:2405.21060) splits the sequence into
chunks of length Q: a quadratic attention-like intra-chunk term plus a
sequential inter-chunk state recurrence of length L/Q. The pure-jnp path
below is the reference; ``repro.kernels.ssd_scan`` provides the Pallas TPU
kernel for the intra-chunk term.

Projections are stored as separate tensors per semantic chunk (z, x, B, C,
dt) so each can carry its own logical sharding axis (d_inner -> ``mlp`` on
the model axis, state dims replicated).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.module import spec

_IMPL = "xla"


def set_ssd_impl(impl: str) -> None:
    global _IMPL
    assert impl in ("xla", "pallas", "pallas_interpret"), impl
    _IMPL = impl


def ssm_spec(cfg):
    d, di, n, h, w = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_conv)
    return {
        "in_z": spec((d, di), ("embed", "mlp")),
        "in_x": spec((d, di), ("embed", "mlp")),
        "in_b": spec((d, n), ("embed", None)),
        "in_c": spec((d, n), ("embed", None)),
        "in_dt": spec((d, h), ("embed", "heads")),
        "conv_x": spec((w, di), (None, "mlp"), scale=0.5),
        "conv_b": spec((w, n), (None, None), scale=0.5),
        "conv_c": spec((w, n), (None, None), scale=0.5),
        "conv_bias_x": spec((di,), ("mlp",), "zeros"),
        "conv_bias_b": spec((n,), (None,), "zeros"),
        "conv_bias_c": spec((n,), (None,), "zeros"),
        "a_log": spec((h,), ("heads",), "zeros", dtype=jnp.float32),
        "d_skip": spec((h,), ("heads",), "ones", dtype=jnp.float32),
        "dt_bias": spec((h,), ("heads",), "zeros", dtype=jnp.float32),
        "norm_scale": spec((di,), ("mlp",), "ones", dtype=jnp.float32),
        "out": spec((di, d), ("mlp", "embed")),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x (B,L,C), w (W,C), b (C,)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):  # W is 4: unrolled adds, no conv primitive needed
        out = out + xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
    return out + b.astype(x.dtype)


def _conv_step(buf, x_t, w, b):
    """Single-token causal conv. buf (B,W-1,C) past inputs; x_t (B,C)."""
    window = jnp.concatenate([buf, x_t[:, None, :]], axis=1)  # (B,W,C)
    y = jnp.einsum("bwc,wc->bc", window, w.astype(x_t.dtype)) + b.astype(x_t.dtype)
    return y, window[:, 1:, :]


def ssd_chunked(xb, dt, a_neg, bmat, cmat, chunk: int):
    """Chunked SSD scan (fp32 decay math).

    xb (B,L,H,P) pre-scaled inputs (x*dt); dt (B,L,H); a_neg (H,) negative;
    bmat/cmat (B,L,N). Returns y (B,L,H,P), final state (B,H,N,P) fp32.
    """
    if _IMPL in ("pallas", "pallas_interpret"):
        from repro.kernels import ops as kops
        return kops.ssd_scan(xb, dt, a_neg, bmat, cmat, chunk,
                             interpret=(_IMPL == "pallas_interpret"))
    return ssd_chunked_ref(xb, dt, a_neg, bmat, cmat, chunk)


def ssd_chunked_ref(xb, dt, a_neg, bmat, cmat, chunk: int):
    b, l, h, p = xb.shape
    n = bmat.shape[-1]
    q = min(chunk, l)
    if l % q:
        # pad to a chunk multiple: x=0 contributes nothing to outputs or
        # state, dt=0 makes the padded decay exactly 1 (state preserved)
        pad = q - l % q
        y, s = ssd_chunked_ref(
            jnp.pad(xb, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            a_neg,
            jnp.pad(bmat, ((0, 0), (0, pad), (0, 0))),
            jnp.pad(cmat, ((0, 0), (0, pad), (0, 0))), chunk)
        return y[:, :l], s
    nc = l // q
    dtype = xb.dtype

    loga = (dt.astype(jnp.float32) * a_neg).reshape(b, nc, q, h)  # <= 0
    xc = xb.reshape(b, nc, q, h, p)
    bc = bmat.reshape(b, nc, q, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, q, n).astype(jnp.float32)

    cum = jnp.cumsum(loga, axis=2)  # (B,C,Q,H) inclusive
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,C,Q,Q,H) t,s
    causal = jnp.tril(jnp.ones((q, q), jnp.bool_))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    # Intra-chunk (quadratic) term.
    cb = jnp.einsum("bctn,bcsn->bcts", cc, bc)
    y_intra = jnp.einsum("bcts,bctsh,bcshp->bcthp",
                         cb, decay, xc.astype(jnp.float32))

    # Per-chunk contribution to the carried state.
    w_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,C,Q,H) decay to chunk end
    s_chunk = jnp.einsum("bcsh,bcsn,bcshp->bchnp",
                         w_end, bc, xc.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,C,H) total chunk decay

    def step(s, inp):
        s_c, dec = inp  # (B,H,N,P), (B,H)
        s_new = s * dec[..., None, None] + s_c
        return s_new, s

    s0 = jnp.zeros((b, h, n, p), jnp.float32)
    s_final, s_prev = jax.lax.scan(
        step, s0, (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    s_prev = jnp.moveaxis(s_prev, 0, 1)  # (B,C,H,N,P) state entering chunk

    # Inter-chunk term: y_t += C_t . (decay-from-chunk-start * S_prev)
    w_start = jnp.exp(cum)  # (B,C,Q,H)
    cs = jnp.einsum("bctn,bchnp->bcthp", cc, s_prev)  # C_t . S_prev
    y_inter = w_start[..., None] * cs

    y = (y_intra + y_inter).astype(dtype).reshape(b, l, h, p)
    return y, s_final


def apply_ssm(p, cfg, x, return_cache: bool = False):
    """Full-sequence Mamba2 block. x (B,L,D) -> (y (B,L,D), cache_or_state)."""
    b, l, d = x.shape
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    w = cfg.ssm_conv
    z = jnp.einsum("bld,de->ble", x, p["in_z"].astype(x.dtype))
    xi_raw = jnp.einsum("bld,de->ble", x, p["in_x"].astype(x.dtype))
    bm_raw = jnp.einsum("bld,dn->bln", x, p["in_b"].astype(x.dtype))
    cm_raw = jnp.einsum("bld,dn->bln", x, p["in_c"].astype(x.dtype))
    dt = jnp.einsum("bld,dh->blh", x, p["in_dt"].astype(x.dtype))

    xi = jax.nn.silu(_causal_conv(xi_raw, p["conv_x"], p["conv_bias_x"])
                     .astype(jnp.float32)).astype(x.dtype)
    bm = jax.nn.silu(_causal_conv(bm_raw, p["conv_b"], p["conv_bias_b"])
                     .astype(jnp.float32)).astype(x.dtype)
    cm = jax.nn.silu(_causal_conv(cm_raw, p["conv_c"], p["conv_bias_c"])
                     .astype(jnp.float32)).astype(x.dtype)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,L,H)
    a_neg = -jnp.exp(p["a_log"])  # (H,)
    xh = xi.reshape(b, l, h, pdim)
    xb = (xh.astype(jnp.float32) * dt[..., None]).astype(x.dtype)

    y, s_final = ssd_chunked(xb, dt, a_neg, bm, cm, cfg.ssm_chunk)
    y = y + (p["d_skip"][:, None] * xh.astype(jnp.float32)).astype(x.dtype)
    y = y.reshape(b, l, cfg.d_inner)

    # Gated RMSNorm then output projection.
    y = _gated_norm(y, z, p["norm_scale"])
    out = jnp.einsum("ble,ed->bld", y, p["out"].astype(x.dtype))
    if return_cache:
        cache = {"state": s_final,
                 "conv_x": xi_raw[:, l - (w - 1):, :],
                 "conv_b": bm_raw[:, l - (w - 1):, :],
                 "conv_c": cm_raw[:, l - (w - 1):, :]}
        return out, cache
    return out, s_final


def _gated_norm(y, z, scale, eps: float = 1e-6):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + eps) * scale).astype(y.dtype)


def decode_ssm(p, cfg, x_t, cache):
    """Single-token Mamba2 step. x_t (B,1,D); cache {"state","conv_*"}."""
    b = x_t.shape[0]
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xt = x_t[:, 0]
    z = xt @ p["in_z"].astype(xt.dtype)
    xi = xt @ p["in_x"].astype(xt.dtype)
    bm = xt @ p["in_b"].astype(xt.dtype)
    cm = xt @ p["in_c"].astype(xt.dtype)
    dt = xt @ p["in_dt"].astype(xt.dtype)

    xi, conv_x = _conv_step(cache["conv_x"], xi, p["conv_x"], p["conv_bias_x"])
    bm, conv_b = _conv_step(cache["conv_b"], bm, p["conv_b"], p["conv_bias_b"])
    cm, conv_c = _conv_step(cache["conv_c"], cm, p["conv_c"], p["conv_bias_c"])
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(xt.dtype)
    bm = jax.nn.silu(bm.astype(jnp.float32))
    cm = jax.nn.silu(cm.astype(jnp.float32))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(dt * -jnp.exp(p["a_log"]))  # (B,H) decay
    xh = xi.reshape(b, h, pdim).astype(jnp.float32)
    s = cache["state"]  # (B,H,N,P) fp32
    s = s * a[..., None, None] + jnp.einsum(
        "bn,bhp->bhnp", bm, xh * dt[..., None])
    y = jnp.einsum("bn,bhnp->bhp", cm, s)
    y = y + p["d_skip"][:, None] * xh
    y = y.reshape(b, cfg.d_inner).astype(x_t.dtype)
    y = _gated_norm(y, z, p["norm_scale"])
    out = (y @ p["out"].astype(y.dtype))[:, None, :]
    new_cache = {"state": s, "conv_x": conv_x, "conv_b": conv_b, "conv_c": conv_c}
    return out, new_cache


def ssm_cache_shape(cfg, batch: int):
    w, di, n = cfg.ssm_conv, cfg.d_inner, cfg.ssm_state
    return {
        "state": (batch, cfg.ssm_heads, n, cfg.ssm_head_dim),
        "conv_x": (batch, w - 1, di),
        "conv_b": (batch, w - 1, n),
        "conv_c": (batch, w - 1, n),
    }
