"""Public model-construction API."""

from __future__ import annotations

from typing import Callable, Optional

from repro.configs.base import ModelConfig
from repro.models.stack import StackModel


def build_model(cfg: ModelConfig, sharder: Optional[Callable] = None) -> StackModel:
    return StackModel(cfg, sharder=sharder)
