"""Minimal parameter-spec module system.

Modules are plain functions. A module's parameters are described by a pytree
of :class:`ParamSpec` leaves (shape + logical axis names + initializer).
``init_params`` materializes the tree with real arrays; ``logical_axes``
extracts the parallel tree of logical-axis tuples consumed by
``repro.distributed.sharding`` to build ``PartitionSpec`` trees.

Keeping specs and initialization in one place guarantees the sharding tree
can never drift from the parameter tree.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative description of one parameter tensor."""

    shape: tuple
    axes: tuple  # logical axis name (str) or None per dim
    init: str = "normal"  # normal | zeros | ones | embed | small
    scale: Optional[float] = None
    dtype: Optional[Any] = None  # overrides the model dtype (e.g. fp32 norms)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec(shape: Sequence[int], axes: Sequence[Optional[str]], init: str = "normal",
         scale: Optional[float] = None, dtype: Optional[Any] = None) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), init, scale, dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _fan_in(shape: tuple) -> int:
    # Convention: last dim is fan-out, everything before is fan-in.
    if len(shape) <= 1:
        return max(1, shape[0] if shape else 1)
    n = 1
    for d in shape[:-1]:
        n *= d
    return max(1, n)


def _init_leaf(ps: ParamSpec, key, dtype) -> jax.Array:
    dt = ps.dtype or dtype
    if ps.init == "zeros":
        return jnp.zeros(ps.shape, dt)
    if ps.init == "ones":
        return jnp.ones(ps.shape, dt)
    if ps.init == "embed":
        sc = ps.scale if ps.scale is not None else 1.0
        return (jax.random.normal(key, ps.shape, jnp.float32) * sc).astype(dt)
    # dense-kernel initializers: truncated-normal-ish scaled by fan-in
    sc = ps.scale if ps.scale is not None else 1.0 / math.sqrt(_fan_in(ps.shape))
    if ps.init == "small":
        sc = sc * 0.1
    return (jax.random.normal(key, ps.shape, jnp.float32) * sc).astype(dt)


def init_params(spec_tree: Pytree, key, dtype=jnp.float32) -> Pytree:
    """Materialize a ParamSpec tree into arrays."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(ps, k, dtype) for ps, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def logical_axes(spec_tree: Pytree) -> Pytree:
    """ParamSpec tree -> tree of logical-axis tuples (same structure)."""
    return jax.tree.map(lambda ps: ps.axes, spec_tree, is_leaf=is_spec)


def shape_tree(spec_tree: Pytree, dtype=jnp.float32) -> Pytree:
    """ParamSpec tree -> ShapeDtypeStruct tree (no allocation)."""
    def leaf(ps: ParamSpec):
        return jax.ShapeDtypeStruct(ps.shape, ps.dtype or dtype)
    return jax.tree.map(leaf, spec_tree, is_leaf=is_spec)


def stack_specs(spec_tree: Pytree, n: int, axis_name: Optional[str] = None) -> Pytree:
    """Prepend a stacking dim (e.g. scan-over-layers periods) to every leaf."""
    def leaf(ps: ParamSpec):
        return ParamSpec((n,) + ps.shape, (axis_name,) + ps.axes, ps.init,
                         ps.scale, ps.dtype)
    return jax.tree.map(leaf, spec_tree, is_leaf=is_spec)


def count_params(tree: Pytree) -> int:
    """Number of scalar parameters in an array / ShapeDtypeStruct / spec tree."""
    def leaf_size(x):
        if isinstance(x, ParamSpec):
            return math.prod(x.shape)
        return math.prod(x.shape)
    return sum(leaf_size(x) for x in jax.tree.leaves(tree, is_leaf=is_spec))
