"""Shared neural-net layers: norms, RoPE, gated MLP, embeddings.

All apply functions take the params subtree produced from the matching
``*_spec`` function. Compute runs in the activation dtype; reductions that
need it (norm statistics, softmax, loss) run in fp32.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.module import spec

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_spec(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": spec((d,), ("embed",), "ones", dtype=jnp.float32),
                "bias": spec((d,), ("embed",), "zeros", dtype=jnp.float32)}
    return {"scale": spec((d,), ("embed",), "ones", dtype=jnp.float32)}


def apply_norm(p, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if "bias" in p:  # LayerNorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"] + p["bias"]
    else:  # RMSNorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float, rotary_dims: Optional[int] = None):
    rd = rotary_dims or head_dim
    inv = 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))
    return inv  # (rd//2,)


def apply_rope(x, positions, theta: float, style: str = "full"):
    """x: (..., S, H, D). positions: broadcastable to (..., S) int32.

    style "full": rotate all D dims (Llama / Qwen / Phi).
    style "2d":   ChatGLM partial rotary — rotate only the first half of the
                  head dims, pass the second half through (the "2d" RoPE of
                  GLM applies position to half the channels).
    """
    d = x.shape[-1]
    rd = d // 2 if style == "2d" else d
    inv = rope_frequencies(d, theta, rd)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, rd//2)
    sin = jnp.sin(ang)[..., None, :]  # (..., S, 1, rd//2) broadcast over heads
    cos = jnp.cos(ang)[..., None, :]
    xr = x[..., :rd].astype(jnp.float32)
    x1, x2 = xr[..., : rd // 2], xr[..., rd // 2:]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    out = jnp.concatenate([rot, x[..., rd:].astype(jnp.float32)], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------


def mlp_spec(cfg, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wi": spec((d, 2, f), ("embed", None, "mlp")),  # fused gate+up
        "wo": spec((f, d), ("mlp", "embed")),
    }


def apply_mlp(p, x):
    gu = jnp.einsum("...d,dgf->...gf", x, p["wi"].astype(x.dtype))
    g, u = gu[..., 0, :], gu[..., 1, :]
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Embeddings / unembedding
# ---------------------------------------------------------------------------


def padded_vocab(v: int) -> int:
    """Vocab rows padded to a multiple of 256 so the table shards evenly on
    any mesh axis (51865, 50280, ... are not 16-divisible)."""
    return -(-v // 256) * 256


def embed_spec(cfg):
    v, d = padded_vocab(cfg.vocab_size), cfg.d_model
    if cfg.tie_embeddings:
        # One table, vocab-sharded: output projection is comm-free; the input
        # gather pays a (B,S,D) all-reduce over the model axis (see DESIGN.md).
        return {"table": spec((v, d), ("vocab", "embed"), "embed", scale=0.02)}
    return {
        # Input table embed-sharded: gather is comm-free, one AG to full D.
        "table": spec((v, d), (None, "mlp"), "embed", scale=0.02),
        "unembed": spec((d, v), ("embed", "vocab"), "normal"),
    }


def embed_tokens(p, tokens, dtype):
    return jnp.take(p["table"], tokens, axis=0).astype(dtype)


def unembed(p, x, softcap: float = 0.0, vocab: int = 0):
    w = p["table"].T if "unembed" not in p else p["unembed"]
    logits = jnp.einsum("...d,dv->...v", x, w.astype(x.dtype)).astype(jnp.float32)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    if vocab and vocab < logits.shape[-1]:
        # mask padded vocab columns out of the softmax
        pad_mask = jnp.arange(logits.shape[-1]) < vocab
        logits = jnp.where(pad_mask, logits, jnp.float32(-1e30))
    return logits


def cross_entropy(logits, labels, mask=None):
    """Mean token cross-entropy in fp32. logits (..., V), labels (...) int."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
