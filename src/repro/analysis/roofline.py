"""Roofline-term derivation from AOT-compiled executables.

Hardware model (TPU v5e target, per assignment):
  peak_flops = 197e12 bf16 FLOP/s per chip
  hbm_bw     = 819e9  B/s per chip
  link_bw    = 50e9   B/s per ICI link

Terms (seconds, per step, per chip — cost_analysis of the SPMD-partitioned
module is already per-device):
  compute    = HLO_FLOPs / peak_flops
  memory     = HLO_bytes_accessed / hbm_bw
  collective = weighted collective bytes / link_bw
               (all-reduce counts 2x — ring AR moves ~2 x size/device;
                all-gather / reduce-scatter / all-to-all / permute 1x)

``collective_bytes`` is parsed from the compiled HLO text: result-shape
bytes of every collective op (async ``-start`` forms counted once).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_WEIGHT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "collective-broadcast": 1.0,
    "ragged-all-to-all": 1.0,
}

# "bf16[8,128,4096]{2,1,0} all-gather(" — possibly a tuple for variadic ops.
_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^\]=]*\][^ ]*\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"collective-broadcast|ragged-all-to-all)(-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind {count, bytes, weighted_bytes} from HLO text."""
    out: Dict[str, Dict[str, float]] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        e = out.setdefault(kind, {"count": 0, "bytes": 0.0, "weighted": 0.0})
        e["count"] += 1
        e["bytes"] += b
        e["weighted"] += b * _COLL_WEIGHT[kind]
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    collective_bytes: float        # raw result bytes
    collective_weighted: float     # link-time-weighted bytes
    collectives: Dict[str, Dict[str, float]]
    peak_hbm_per_device: float
    argument_bytes: float
    output_bytes: float
    temp_bytes: float

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_weighted / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def summary(self, model_flops_per_device: Optional[float] = None) -> Dict:
        d = {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "collective_weighted_bytes": self.collective_weighted,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "peak_hbm_gib": self.peak_hbm_per_device / 2**30,
            "argument_gib": self.argument_bytes / 2**30,
            "temp_gib": self.temp_bytes / 2**30,
            "collectives": self.collectives,
        }
        if model_flops_per_device:
            d["model_flops_per_device"] = model_flops_per_device
            d["useful_flop_fraction"] = model_flops_per_device / max(self.flops, 1)
            d["mfu_bound"] = (model_flops_per_device / PEAK_FLOPS) / max(
                self.t_bound, 1e-12)
        return d


def analyze(compiled, lowered=None) -> Roofline:
    """Roofline terms from the compiled artifact.

    FLOPs/bytes/collectives come from the loop-aware HLO static analyzer
    (``repro.analysis.hlo``) because XLA's flat ``cost_analysis()`` counts
    ``while`` (scan-over-layers) bodies once; memory sizes come from
    ``memory_analysis()`` (allocation-based, loop-correct already).
    """
    from repro.analysis import hlo as hlo_lib
    ma = compiled.memory_analysis()
    text = compiled.as_text()
    cost = hlo_lib.analyze_text(text)
    colls = {k: {"count": v} for k, v in cost.coll_counts.items()}
    temp = getattr(ma, "temp_size_in_bytes", 0)
    arg = getattr(ma, "argument_size_in_bytes", 0)
    out = getattr(ma, "output_size_in_bytes", 0)
    alias = getattr(ma, "alias_size_in_bytes", 0)
    peak = arg + out + temp - alias
    return Roofline(
        flops=cost.flops,
        bytes_accessed=cost.bytes,
        collective_bytes=cost.coll_bytes,
        collective_weighted=cost.coll_weighted,
        collectives=colls,
        peak_hbm_per_device=peak,
        argument_bytes=arg,
        output_bytes=out,
        temp_bytes=temp,
    )


def flops_estimate(cfg, batch: int, seq: int) -> float:
    """Zero-trace FLOP estimate for one (config, batch, seq) query.

    Configs carrying a ``dots`` attribute (the scenario zoo's synthetic
    profiles, where cost laws are linear in ``batch*seq*dots``) use it
    directly; real transformer configs fall back to the standard
    ``12 * layers * d_model^2`` per-token forward approximation.
    """
    dots = getattr(cfg, "dots", None)
    if dots is not None:
        return float(batch) * float(seq) * float(dots) * 1e6
    layers = int(getattr(cfg, "num_layers", getattr(cfg, "layers", 1)) or 1)
    d_model = int(getattr(cfg, "d_model", getattr(cfg, "hidden_size", 0))
                  or 1024)
    return 12.0 * layers * float(d_model) ** 2 * float(batch) * float(seq)


def floor_estimate(cfg, batch: int, seq: int) -> Dict[str, float]:
    """Analytical roofline floor: the cheapest defensible answer.

    ``inference_time = flops / device_flops`` bounded below by the HBM
    streaming time of the (approximate) parameter bytes — no trace, no
    model build, O(1). Saturated replicas answer shed queries from this
    floor instead of queueing them; the estimate is stamped
    ``degraded: True`` so consumers can tell it from a learned one.
    """
    flops = flops_estimate(cfg, batch, seq)
    dots = getattr(cfg, "dots", None)
    if dots is not None:
        param_bytes = 4.0 * float(dots) * 1e5
    else:
        layers = int(getattr(cfg, "num_layers",
                             getattr(cfg, "layers", 1)) or 1)
        d_model = int(getattr(cfg, "d_model",
                              getattr(cfg, "hidden_size", 0)) or 1024)
        param_bytes = 4.0 * 12.0 * layers * float(d_model) ** 2
    act_bytes = 4.0 * float(batch) * float(seq) * 1024.0
    mem_bytes = param_bytes + act_bytes
    time_s = max(flops / PEAK_FLOPS, mem_bytes / HBM_BW)
    return {
        "model": "roofline-floor",
        "time_s": float(time_s),
        "memory_bytes": float(mem_bytes),
        "flops": float(flops),
        "degraded": True,
    }


def model_flops(cfg, shape, n_params: int, n_active: int) -> float:
    """MODEL_FLOPS: 6·N·D train (fwd+bwd), 2·N·D forward-only."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n = n_active if cfg.num_experts else n_params
    mult = 6 if shape.kind == "train" else 2
    return float(mult * n * tokens)
