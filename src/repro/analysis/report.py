"""Emit the EXPERIMENTS.md §Roofline markdown table from dryrun JSONL.

    PYTHONPATH=src python -m repro.analysis.report artifacts/dryrun.jsonl
"""

from __future__ import annotations

import json
import sys
from typing import Dict


def load(path: str) -> Dict:
    recs = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            recs[(r.get("arch"), r.get("shape"), r.get("multi_pod"))] = r
    return recs


def table(path: str) -> str:
    recs = load(path)
    lines = [
        "| arch | shape | scheme | t_compute | t_memory | t_collective | "
        "bound | peak GiB | useful | mfu_bound |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    singles = sorted(
        (r for r in recs.values()
         if r.get("status") == "ok" and not r.get("multi_pod")),
        key=lambda r: (r["arch"], r["shape"]))
    for r in singles:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('scheme','sp')} "
            f"| {r['t_compute_s']*1e3:,.0f} ms | {r['t_memory_s']*1e3:,.0f} ms "
            f"| {r['t_collective_s']*1e3:,.0f} ms | {r['bottleneck']} "
            f"| {r['peak_hbm_gib']:.1f} | {r.get('useful_flop_fraction',0):.2f} "
            f"| {r.get('mfu_bound',0):.3f} |")
    multi_ok = sum(1 for r in recs.values()
                   if r.get("multi_pod") and r.get("status") == "ok")
    skipped = sum(1 for r in recs.values() if r.get("status") == "skipped")
    lines.append("")
    lines.append(f"Single-pod cells: {len(singles)} ok; multi-pod (512-chip) "
                 f"cells: {multi_ok} ok; skipped (documented): {skipped}.")
    # bottleneck census
    census: Dict[str, int] = {}
    for r in singles:
        census[r["bottleneck"]] = census.get(r["bottleneck"], 0) + 1
    lines.append(f"Bottleneck census (single-pod): {census}.")
    worst = [r for r in singles if r["shape"] == "train_4k"]
    if worst:
        w = min(worst, key=lambda r: r.get("mfu_bound", 0))
        lines.append(f"Worst train-cell roofline fraction: {w['arch']} "
                     f"(mfu_bound {w.get('mfu_bound',0):.3f}).")
        c = max(worst, key=lambda r: r["t_collective_s"])
        lines.append(f"Most collective-bound train cell: {c['arch']} "
                     f"(t_collective {c['t_collective_s']:.2f}s).")
    return "\n".join(lines)


if __name__ == "__main__":
    print(table(sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun.jsonl"))
