"""Static cost analysis of compiled HLO text with loop-trip attribution.

XLA's built-in ``compiled.cost_analysis()`` counts ``while`` bodies ONCE,
which under-reports every scan-over-layers model by ~num_layers x. This
module re-derives FLOPs / bytes-accessed / collective-bytes directly from
``compiled.as_text()``:

  - computations are parsed into instruction lists with resolved shapes;
  - ``while`` ops multiply their body cost by the trip count taken from
    the ``backend_config known_trip_count`` (emitted by JAX scans), with
    nested loops multiplying recursively;
  - ``fusion`` / ``call`` / ``reduce`` recurse into their called
    computations for FLOPs but charge HBM bytes only at the call site
    (fusion internals live in registers/VMEM);
  - dot FLOPs = 2 x result elements x contracted elements; convolution
    FLOPs = 2 x result elements x kernel elements / out-channels;
    elementwise ops are charged 1 FLOP per result element;
  - collective bytes (all-reduce 2x weighting, others 1x) accumulate with
    the same loop multipliers — a per-layer all-gather inside the scan is
    counted num_layers times, as it executes.

This is the dry-run "profiler" used for the roofline terms in
EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute", "collective-broadcast", "ragged-all-to-all")
_COLL_WEIGHT = {k: (2.0 if k == "all-reduce" else 1.0) for k in COLL_OPS}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OP_RE = re.compile(r"\s*([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _parse_instr_line(line: str) -> Optional[Tuple[str, str, str, str]]:
    """-> (name, type_str, op, rest-after-open-paren) or None."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    rest = line[m.end():]
    if rest.startswith("("):  # tuple type: consume balanced parens
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i + 1
                    break
        type_str, rest = rest[:end], rest[end:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest = rest[:sp], rest[sp:]
    m2 = _OP_RE.match(rest)
    if not m2:
        return None
    return m.group(1), type_str, m2.group(1), rest[m2.end():]


def _shape_info(shape_str: str) -> Tuple[int, int]:
    """-> (elements, bytes) summed over tuple components."""
    elems_total, bytes_total = 0, 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems_total += n
        bytes_total += n * _DTYPE_BYTES[dt]
    return elems_total, bytes_total


def _dims_of(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str  # raw remainder of the line (operands + attributes)
    operands: List[str]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_weighted: float = 0.0
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        self.coll_weighted += other.coll_weighted * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations = self._parse(hlo_text)
        self.entry = self._entry_name(hlo_text)
        self._memo: Dict[Tuple[str, bool], Cost] = {}

    # -- parsing -----------------------------------------------------------
    @staticmethod
    def _entry_name(text: str) -> Optional[str]:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        return m.group(1) if m else None

    @staticmethod
    def _parse(text: str) -> Dict[str, List[Instr]]:
        comps: Dict[str, List[Instr]] = {}
        current: Optional[str] = None
        for line in text.splitlines():
            if current is None:
                stripped = line.strip()
                m = (_COMP_RE.match(stripped)
                     if stripped.endswith("{") and "->" in stripped else None)
                if m:
                    current = m.group(1)
                    comps[current] = []
                continue
            if line.strip() == "}":
                current = None
                continue
            parsed = _parse_instr_line(line)
            if not parsed:
                continue
            name, shape, op, rest = parsed
            paren = rest.split(")")[0] if ")" in rest else rest
            operands = _OPERAND_RE.findall(paren)
            comps[current].append(Instr(name, shape.strip(), op, rest, operands))
        return comps

    # -- cost model --------------------------------------------------------
    def _shape_env(self, comp: str) -> Dict[str, str]:
        return {i.name: i.shape for i in self.computations.get(comp, [])}

    @staticmethod
    def _trip_count(rest: str) -> float:
        m = re.search(r'known_trip_count[\\"]*:\s*{[\\"]*n[\\"]*:[\\"]*(\d+)', rest)
        if m:
            return float(m.group(1))
        m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', rest)
        if m:
            return float(m.group(1))
        return 1.0  # unknown loop: conservative single execution

    def _called(self, rest: str) -> List[str]:
        names = []
        for key in ("calls=", "to_apply=", "condition=", "body=", "branch_computations="):
            for m in re.finditer(re.escape(key) + r"\{?%?([\w.\-]+)", rest):
                names.append(m.group(1))
        return [n for n in names if n in self.computations]

    def _dot_flops(self, instr: Instr, env: Dict[str, str]) -> float:
        out_elems, _ = _shape_info(instr.shape)
        lhs = env.get(instr.operands[0], "") if instr.operands else ""
        dims = _dims_of(lhs)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
        contracted = 1
        if m and dims:
            for d in m.group(1).split(","):
                if d:
                    contracted *= dims[int(d)]
        return 2.0 * out_elems * contracted

    def _conv_flops(self, instr: Instr, env: Dict[str, str]) -> float:
        out_elems, _ = _shape_info(instr.shape)
        kern = env.get(instr.operands[1], "") if len(instr.operands) > 1 else ""
        kelems, _ = _shape_info(kern)
        m = re.search(r"dim_labels=\S*->(\w+)", instr.rest)
        out_dims = _dims_of(instr.shape)
        cout = 1
        if m and out_dims:
            pos = m.group(1).find("f")
            if 0 <= pos < len(out_dims):
                cout = out_dims[pos]
        g = 1
        mg = re.search(r"feature_group_count=(\d+)", instr.rest)
        if mg:
            g = int(mg.group(1))
        return 2.0 * out_elems * (kelems / max(cout, 1)) * 1.0 if g else 0.0

    def _instr_cost(self, instr: Instr, comp: str, env: Dict[str, str],
                    top_level: bool) -> Cost:
        c = Cost()
        op = instr.op
        base = op[:-len("-start")] if op.endswith("-start") else op
        _, out_bytes = _shape_info(instr.shape)
        operand_bytes = sum(_shape_info(env.get(o, ""))[1]
                            for o in instr.operands)

        if base in COLL_OPS:
            c.coll_bytes += out_bytes
            c.coll_weighted += out_bytes * _COLL_WEIGHT[base]
            c.coll_counts[base] = c.coll_counts.get(base, 0) + 1
            c.bytes += out_bytes + operand_bytes
            return c
        if op in ("get-tuple-element", "tuple", "parameter", "constant",
                  "bitcast", "after-all", "all-reduce-done",
                  "all-gather-done", "copy-done"):
            return c
        if op == "while":
            trip = self._trip_count(instr.rest)
            mb = re.search(r"body=%?([\w.\-]+)", instr.rest)
            mc = re.search(r"condition=%?([\w.\-]+)", instr.rest)
            if mb and mb.group(1) in self.computations:
                c.add(self.comp_cost(mb.group(1)), trip)
            if mc and mc.group(1) in self.computations:
                c.add(self.comp_cost(mc.group(1)), trip + 1)
            return c
        if op == "conditional":
            branches = self._called(instr.rest)
            if branches:
                worst = max((self.comp_cost(b) for b in branches),
                            key=lambda x: x.flops + x.bytes, default=Cost())
                c.add(worst)
            c.bytes += out_bytes + operand_bytes
            return c
        if op == "dynamic-slice":
            # reads only the slice, not the (possibly loop-carried) buffer
            c.flops += 0
            if top_level:
                c.bytes += 2 * out_bytes
            return c
        if op == "dynamic-update-slice":
            # aliased in-place update: traffic ~ 2x the updated slice
            upd = (_shape_info(env.get(instr.operands[1], ""))[1]
                   if len(instr.operands) > 1 else out_bytes)
            if top_level:
                c.bytes += 2 * upd
            return c
        if op in ("fusion", "call", "custom-call", "reduce", "map", "sort",
                  "reduce-window", "scatter", "select-and-scatter",
                  "async-start"):
            materialized_inner = op in ("call", "custom-call", "async-start")
            for name in self._called(instr.rest):
                inner = self.comp_cost(name, materialized=materialized_inner)
                c.flops += inner.flops
                c.bytes += inner.bytes
                c.coll_bytes += inner.coll_bytes
                c.coll_weighted += inner.coll_weighted
                for k, v in inner.coll_counts.items():
                    c.coll_counts[k] = c.coll_counts.get(k, 0) + v
            if top_level:
                if op == "fusion" and self._called(instr.rest):
                    c.bytes += self._fusion_io_bytes(instr, env)
                else:
                    c.bytes += out_bytes + operand_bytes
            if op == "reduce" and not self._called(instr.rest):
                c.flops += sum(_shape_info(env.get(o, ""))[0]
                               for o in instr.operands[:1])
            return c
        if op == "dot":
            c.flops += self._dot_flops(instr, env)
            if top_level:
                c.bytes += out_bytes + operand_bytes
            return c
        if op == "convolution":
            c.flops += self._conv_flops(instr, env)
            if top_level:
                c.bytes += out_bytes + operand_bytes
            return c
        # generic elementwise-ish op: 1 flop per output element
        out_elems, _ = _shape_info(instr.shape)
        c.flops += out_elems
        if top_level:
            c.bytes += out_bytes + operand_bytes
        return c

    def _fusion_io_bytes(self, instr: Instr, env: Dict[str, str]) -> float:
        """HBM traffic of a fusion: operands read + result written, except
        operands consumed ONLY via dynamic-slice inside the fusion are
        charged at slice size (loop-carried stacked buffers are views),
        and a dynamic-update-slice root writes only its update slice."""
        comp = self._called(instr.rest)[0]
        instrs = self.computations.get(comp, [])
        ienv = {i.name: i.shape for i in instrs}
        # map parameter name -> index (Instr.rest starts right after the
        # op's open paren: "0), ..." for "parameter(0)")
        pidx = {}
        for i in instrs:
            if i.op == "parameter":
                m = re.match(r"\s*(\d+)\)", i.rest)
                if m:
                    pidx[i.name] = int(m.group(1))
        consumers: Dict[str, List[Instr]] = {}
        for i in instrs:
            for o in i.operands:
                consumers.setdefault(o, []).append(i)
        total = 0.0
        for pname, idx in pidx.items():
            outer = (env.get(instr.operands[idx], "")
                     if idx < len(instr.operands) else "")
            full = _shape_info(outer or ienv.get(pname, ""))[1]
            cons = consumers.get(pname, [])
            if cons and all(c.op == "dynamic-slice" and c.operands
                            and c.operands[0] == pname for c in cons):
                total += sum(_shape_info(c.shape)[1] for c in cons)
            elif cons and all(c.op == "dynamic-update-slice" and c.operands
                              and c.operands[0] == pname for c in cons):
                total += 0.0  # aliased buffer pass-through
            else:
                total += full
        root = instrs[-1] if instrs else None
        if root is not None and root.op == "dynamic-update-slice":
            total += 2 * _shape_info(
                ienv.get(root.operands[1], ""))[1] if len(root.operands) > 1 else 0
        else:
            total += _shape_info(instr.shape)[1]
        return total

    def comp_cost(self, comp: str, materialized: bool = True) -> Cost:
        """Cost of one computation. ``materialized=False`` for fusion-called
        bodies whose intermediates live in registers (flops only, no HBM
        bytes); while/call bodies are materialized."""
        key = (comp, materialized)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Cost()  # cycle guard
        env = self._shape_env(comp)
        total = Cost()
        for instr in self.computations.get(comp, []):
            total.add(self._instr_cost(instr, comp, env,
                                       top_level=materialized))
        self._memo[key] = total
        return total

    def total(self) -> Cost:
        if not self.entry:
            return Cost()
        return self.comp_cost(self.entry)


def analyze_text(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).total()
