"""End-to-end training driver: ~100M-parameter LM for a few hundred steps.

Uses the full production stack — sharded data pipeline, AdamW with ZeRO
state, atomic checkpoints, fault-tolerant step runner — on the host
devices. The model is a scaled qwen2-family config of ~100M params.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.models import build_model
from repro.train import optimizer as opt_lib
from repro.train.loop import LoopConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="artifacts/train_lm_ckpt")
    args = ap.parse_args()

    # ~100M params: qwen2 family at width 512 / 8 layers / 16k vocab
    cfg = dataclasses.replace(
        get_config("qwen2-0.5b"), name="qwen2-100m", num_layers=8,
        d_model=512, num_heads=8, num_kv_heads=2, head_dim=64, d_ff=2048,
        vocab_size=16384, dtype="float32", remat="none",
        tie_embeddings=True)
    model = build_model(cfg)
    print(f"params: {model.param_count()/1e6:.1f}M")

    trainer = Trainer(
        model, opt_lib.OptConfig(lr=1e-3, warmup_steps=50,
                                 decay_steps=args.steps),
        LoopConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                   ckpt_every=100, ckpt_dir=args.ckpt_dir, log_every=20))
    log = trainer.run()
    trainer.write_log("artifacts/train_lm_log.jsonl")
    first, last = log[0]["loss"], log[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"(retries={trainer.runner.retries})")
    assert last < first, "loss did not improve"


if __name__ == "__main__":
    main()
