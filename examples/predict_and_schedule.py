"""End-to-end scheduling driver (the paper's application, §4.3).

Profiles a mixed pool of networks, fits DNNAbacus, predicts cost for 20
training jobs, and schedules them onto two machines with the genetic
algorithm — comparing against optimal and random placement. Saves the
fitted predictor for the launcher's admission control
(``python -m repro.launch.train --predict``).

Online queries then go through the serving subsystem: a persistent
``TraceStore`` (so re-running this script warm-starts from prior
traces), the micro-batched ``AbacusServer`` gateway, and an
``AdmissionController`` placing two arrival waves incrementally.
Finished jobs report measured costs back (``report_completion``); once
enough feedback accrues the ``OnlineRefitter`` publishes a new model
generation, the server hot-swaps it between ticks, and the next wave's
windowed MRE (from ``server.stats()``) drops.

    PYTHONPATH=src python examples/predict_and_schedule.py
"""

import sys

sys.path.insert(0, "src")

import time

import numpy as np

from repro.core.automl.models import (GradientBoostingRegressor,
                                      RandomForestRegressor, RidgeRegressor)
from repro.core.predictor import DNNAbacus
from repro.core.profiler import profile_zoo
from repro.core.scheduler import (Machine, jobs_from_estimates, schedule_ga,
                                  schedule_jobs)
from repro.serve import (AbacusServer, AdmissionController, ClusterFrontend,
                         FeedbackStore, OnlineRefitter, PredictionService,
                         Query, TraceStore)

GIB = 2**30
TIME_DRIFT, MEM_DRIFT = 3.0, 1.5  # synthetic fleet drift ("reality")


def main():
    nets = ["lenet5", "squeezenet", "nin", "mobilenet_v1", "shufflenet_v2"]
    print("== collecting profiles ==")
    records = []
    for net in nets:
        for batch in (8, 16, 32, 64):
            records.append(profile_zoo(net, batch=batch, steps=2))
            print(f"  {net} b={batch}: {records[-1].time_s*1e3:.0f} ms")

    fac = lambda seed: [RandomForestRegressor(n_trees=30, seed=seed),
                        GradientBoostingRegressor(n_stages=120, seed=seed),
                        RidgeRegressor()]
    abacus = DNNAbacus().fit(records, candidate_factory=fac)
    abacus.save("artifacts/abacus")
    print("predictor saved to artifacts/abacus.json")

    # all online queries go through the batched, trace-caching service,
    # backed by a persistent store: re-running this script warm-starts
    service = PredictionService(abacus, store=TraceStore("artifacts/trace_store"))

    # 20 jobs with predicted cost — one design matrix, one ensemble pass
    rng = np.random.default_rng(0)
    chosen = [records[i] for i in rng.choice(len(records), 20)]
    t_pred, m_pred = service.predict_records(chosen)
    jobs = jobs_from_estimates([r.model_name for r in chosen], t_pred, m_pred,
                               time_scale=100, mem_pad=GIB // 2)
    machines = [Machine("system1", 11 * GIB), Machine("system2", 24 * GIB)]

    opt, _ = schedule_jobs(jobs, machines, plan="optimal")
    rand_mean, _ = schedule_jobs(jobs, machines, plan="random", trials=100)
    ga, assign, hist = schedule_ga(jobs, machines, generations=20,
                                   return_history=True)
    print(f"== makespans ==\n  optimal : {opt:9.1f} s\n"
          f"  random  : {rand_mean:9.1f} s (mean of 100)\n"
          f"  GA      : {ga:9.1f} s "
          f"({(1 - ga / rand_mean) * 100:.1f}% better than random)")
    print(f"  GA generations to best: {int(np.argmin(hist)) + 1}")
    print(f"  assignment: {assign}")

    # admission-control queries on LM configs now go through the async
    # micro-batched gateway: concurrent submissions coalesce into one
    # ensemble pass, cold traces run on the trace pool, and the backing
    # TraceStore makes the *next process* answer them with zero traces.
    from repro.configs import get_config, reduced_config
    cfg = reduced_config(get_config("qwen2-0.5b"))
    queries = [Query(cfg, b, 32) for b in (2, 4)]
    # demo-specific store: artifacts/feedback_store is the shared path
    # dryrun --predict accumulates into, and must not be wiped here
    feedback = FeedbackStore("artifacts/feedback_store_demo")
    feedback.clear()  # each run demonstrates one fresh feedback cycle
    refitter = OnlineRefitter(service, feedback, seed_records=records,
                              min_observations=4, feedback_repeat=8)
    with refitter, AbacusServer(service, feedback=feedback,
                                refitter=refitter) as server:
        t0 = time.perf_counter()
        server.predict_many(queries)
        cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        ests = server.predict_many(queries)
        warm = time.perf_counter() - t0
        print("== admission control (AbacusServer gateway) ==")
        for e in ests:
            print(f"  {e['model']}: {e['time_s']*1e3:.1f} ms, "
                  f"{e['memory_bytes']/GIB:.2f} GiB, admitted={e['admitted']}")
        print(f"  cold {cold*1e3:.0f} ms -> warm {warm*1e3:.1f} ms "
              f"(server {server.server_info()})")

        # streaming admission: two waves placed incrementally against
        # rolling cluster state (committed busy time + reserved HBM)
        ctl = AdmissionController(server, machines, time_scale=100,
                                  mem_pad=GIB // 2, generations=10, seed=0)
        print("== streaming admission (AdmissionController) ==")
        truth = {}  # drifted reality per (batch, seq), fixed across waves
        for wave, bs in enumerate(((2, 4), (2, 2, 4))):
            wave_qs = [Query(cfg, b, 32) for b in bs]
            verdicts = ctl.admit(wave_qs)
            for v, q in zip(verdicts, wave_qs):
                where = v.machine if v.admitted else f"REJECTED ({v.reason})"
                print(f"  wave{wave} {v.job_id}: {where}")
                if not v.admitted:
                    continue
                # the job "runs"; its measured cost is the drifted reality
                mt, mm = truth.setdefault(
                    (q.batch, q.seq),
                    (v.time_s * TIME_DRIFT, v.mem_bytes * MEM_DRIFT))
                ctl.report_completion(v.job_id, time_s=mt, mem_bytes=mm)
        state = ctl.cluster_state()
        print(f"  cluster makespan {state['makespan_s']:.1f} s, "
              f"{state['resident_jobs']} resident jobs "
              "(all completions reported)")

        # the background refitter saw >= min_observations completions:
        # wait for the new generation to be published and hot-swapped
        print("== online refit (feedback -> new generation) ==")
        pre = server.stats()["calibration"]
        print(f"  pre-refit window: time_mre={pre['time_mre']:.3f} "
              f"drift={pre['time_drift']:+.3f}")
        deadline = time.time() + 60
        while service.generation == 0 and time.time() < deadline:
            time.sleep(0.05)
        if service.generation == 0:
            print(f"  no generation published within 60 s "
                  f"(refit state: {refitter.info()})")
        else:
            gen = refitter.generation
            print(f"  generation {gen.number} published "
                  f"(fit on {gen.n_train_records} records, "
                  f"{gen.n_feedback} observations, "
                  f"refit {refitter.last_refit_s*1e3:.0f} ms); "
                  f"service now at generation {service.generation}")

            # wave 3 runs under the refit generation, SAME reality
            wave3_qs = [Query(cfg, b, 32) for b in (2, 4)]
            for v, q in zip(ctl.admit(wave3_qs), wave3_qs):
                if v.admitted:
                    mt, mm = truth[(q.batch, q.seq)]
                    ctl.report_completion(v.job_id, time_s=mt, mem_bytes=mm)
            by_gen = server.stats()["calibration"]["by_generation"]
            mre0 = by_gen.get(0, {}).get("time_mre")
            mre1 = by_gen.get(service.generation, {}).get("time_mre")
            if mre0 is None or mre1 is None:
                print(f"  calibration by generation: {by_gen}")
            else:
                print(f"  windowed time-MRE: generation 0 = {mre0:.3f} "
                      f"-> generation {service.generation} = {mre1:.3f} "
                      f"({mre0 / max(mre1, 1e-12):.1f}x better)")

    # the same queries now go through the multi-host fabric: N sharded
    # gateway replicas behind a consistent-hash frontend, each owning a
    # fingerprint slice of the trace store. The refit generation from
    # above is broadcast fleet-wide (each replica applies it between
    # ticks), so every replica answers from the freshest predictor.
    print("== multi-host fabric (ClusterFrontend, 2 replicas) ==")
    with ClusterFrontend(abacus, n_replicas=2,
                         trace_root="artifacts/cluster_trace_store") as fleet:
        if refitter.generation.number > 0:
            fleet.publish_generation(refitter.generation)
        for q in queries:
            fp, owner = fleet.route(q.cfg)
            print(f"  {q.cfg.name} b={q.batch} s={q.seq} -> {owner.name} "
                  f"(fingerprint {fp[:8]}...)")
        ests = fleet.predict_many(queries)
        for e in ests:
            print(f"  [{e['replica']}] {e['model']}: "
                  f"{e['time_s']*1e3:.1f} ms, {e['memory_bytes']/GIB:.2f} GiB "
                  f"(generation {e['generation']})")
        s = fleet.stats()
        print(f"  fleet: {s['fleet']['completed']} served across "
              f"{s['replicas']} replicas, generations={s['generations']}")


if __name__ == "__main__":
    main()
