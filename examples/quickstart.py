"""Quickstart: the three layers of the framework in one script.

1. profile a few training configurations (the paper's §2 rig);
2. fit DNNAbacus and predict cost for an unseen configuration (§3);
3. train a reduced assigned architecture for a few steps with the
   production Trainer (checkpointed, fault-tolerant).

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import jax

from repro.configs import get_config, reduced_config
from repro.core.automl.models import (GradientBoostingRegressor,
                                      RandomForestRegressor, RidgeRegressor)
from repro.core.predictor import DNNAbacus
from repro.core.profiler import profile_zoo
from repro.models import build_model
from repro.train import optimizer as opt_lib
from repro.train.loop import LoopConfig, Trainer


def main():
    # 1. profile -------------------------------------------------------
    print("== profiling a few CNN training configs ==")
    records = []
    for net in ("lenet5", "squeezenet", "nin"):
        for batch in (8, 16, 32):
            r = profile_zoo(net, batch=batch, steps=2)
            records.append(r)
            print(f"  {net:12s} b={batch:3d}  {r.time_s*1e3:8.1f} ms  "
                  f"{r.mem_bytes/2**20:8.1f} MiB")

    # 2. fit + predict --------------------------------------------------
    print("== fitting DNNAbacus ==")
    fac = lambda seed: [RandomForestRegressor(n_trees=25, seed=seed),
                        GradientBoostingRegressor(n_stages=100, seed=seed),
                        RidgeRegressor()]
    abacus = DNNAbacus().fit(records, candidate_factory=fac)
    probe = profile_zoo("squeezenet", batch=24, steps=2)  # unseen batch
    t_pred, m_pred = abacus.predict([probe])
    print(f"  unseen config: predicted {t_pred[0]*1e3:.1f} ms "
          f"(measured {probe.time_s*1e3:.1f} ms), "
          f"{m_pred[0]/2**20:.1f} MiB (measured {probe.mem_bytes/2**20:.1f})")

    # 3. train an assigned arch (reduced) --------------------------------
    print("== training reduced qwen2-0.5b for 10 steps ==")
    cfg = reduced_config(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    trainer = Trainer(model, opt_lib.OptConfig(),
                      LoopConfig(steps=10, batch=4, seq=64, log_every=3))
    log = trainer.run()
    for rec in log:
        print(f"  step {rec['step']:3d} loss {rec['loss']:.4f}")
    print("done.")


if __name__ == "__main__":
    main()
