"""Serving example: batched prefill + greedy decode with the KV engine.

    PYTHONPATH=src python examples/serve_lm.py [--arch jamba-v0.1-52b]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.models import build_model
from repro.serve.engine import DecodeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    total = args.prompt_len + args.steps + 1
    engine = DecodeEngine(model, params, batch=args.batch, max_seq=total)

    prompts = (jnp.arange(args.batch * total, dtype=jnp.int32)
               .reshape(args.batch, total) * 13) % (cfg.vocab_size - 1)
    prompts = prompts.at[:, args.prompt_len:].set(0)

    t0 = time.perf_counter()
    first = engine.prefill({"tokens": prompts})
    t_prefill = time.perf_counter() - t0
    t0 = time.perf_counter()
    out = engine.generate(first, args.steps)
    t_decode = time.perf_counter() - t0
    print(f"arch={args.arch} (reduced) batch={args.batch}")
    print(f"prefill: {t_prefill*1e3:.1f} ms, decode: "
          f"{t_decode/args.steps*1e3:.1f} ms/token")
    for i in range(args.batch):
        print(f"  request {i}: {out[i].tolist()}")


if __name__ == "__main__":
    main()
