"""Optional-``hypothesis`` compat shim.

Property tests import ``given/settings/st`` from here instead of from
``hypothesis`` directly. When hypothesis is installed the real machinery
is re-exported unchanged; on a bare environment a tiny deterministic
fallback runs each property against a fixed, seeded sample of the
strategy space (endpoints always included), so the suite still collects
and exercises the properties — just without shrinking/coverage search.
"""

from __future__ import annotations

import itertools

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import numpy as np

    class _IntStrategy:
        """Inclusive integer range, like ``hypothesis.strategies.integers``."""

        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = int(lo), int(hi)

        def samples(self, n: int, rng) -> list:
            fixed = [self.lo, self.hi]
            drawn = [int(rng.integers(self.lo, self.hi + 1))
                     for _ in range(max(0, n - len(fixed)))]
            return (fixed + drawn)[:n]

    class _St:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _IntStrategy:
            return _IntStrategy(min_value, max_value)

    st = _St()

    def settings(max_examples: int = 10, **_ignored):
        """Records ``max_examples``; other hypothesis knobs are no-ops."""

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        """Run the test over a fixed seeded sample of the strategy space."""

        def deco(fn):
            def runner():
                # @settings may sit above @given (stamps the runner) or
                # below it (stamps the original fn) — honor both.
                n = getattr(runner, "_max_examples",
                            getattr(fn, "_max_examples", 10))
                rng = np.random.default_rng(0)
                cols = [s.samples(n, rng) for s in strategies]
                for example in itertools.islice(zip(*cols), n):
                    fn(*example)

            # no functools.wraps: pytest must see runner's 0-arg
            # signature, not the property's parameters (-> "fixtures")
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner

        return deco
