"""Checkpointing (atomic, elastic) and fault-tolerance runtime."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.ft.runtime import FTConfig, FailureInjector, StepFailure, StepRunner


def _state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v), "b": jnp.zeros((4,))},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, _state(1.5))
    assert ckpt.latest_step(d) == 7
    out = ckpt.restore(d, 7, _state())
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.full((4, 4), 1.5))
    assert int(out["step"]) == 7


def test_atomicity_no_partial_dirs(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, _state())
    ckpt.save(d, 2, _state())
    names = set(os.listdir(d))
    assert not any(n.startswith(".tmp") for n in names)
    assert ckpt.all_steps(d) == [1, 2]


def test_gc_keeps_last_k(tmp_path):
    d = str(tmp_path / "ck")
    for s in range(1, 6):
        ckpt.save(d, s, _state(), keep=2)
    assert ckpt.all_steps(d) == [4, 5]


def test_restore_missing_key_raises(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"a": jnp.zeros(2)})
    with pytest.raises(ValueError):
        ckpt.restore(d, 1, {"a": jnp.zeros(2), "b": jnp.zeros(3)})


def test_restore_casts_dtype(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"w": jnp.ones((2, 2), jnp.float32)})
    out = ckpt.restore(
        d, 1, {"w": jax.ShapeDtypeStruct((2, 2), jnp.bfloat16)})
    assert out["w"].dtype == jnp.bfloat16


def test_elastic_restore_resharding(tmp_path):
    """Restore onto a (1-device) mesh sharding — the elastic-resume path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    d = str(tmp_path / "ck")
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(d, 3, state)
    mesh = make_host_mesh(1, 1)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out = ckpt.restore(d, 3, state, sh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(state["w"]))
    assert out["w"].sharding == sh["w"]


# -- fault tolerance ---------------------------------------------------------


def test_runner_retries_transient_failures():
    inj = FailureInjector(fail_on_calls=(1, 2))
    fn = inj.wrap(lambda x: x + 1)
    runner = StepRunner(fn, FTConfig(max_retries=2))
    assert runner(41) == 42
    assert runner.retries == 2


def test_runner_gives_up_after_max_retries():
    inj = FailureInjector(fail_on_calls=(1, 2, 3, 4))
    runner = StepRunner(inj.wrap(lambda x: x), FTConfig(max_retries=1))
    with pytest.raises(StepFailure):
        runner(1)


def test_straggler_detection_with_prediction():
    import time
    flags = []
    def slow_then_fast(x):
        time.sleep(0.05 if len(flags) == 0 and not slow_then_fast.done else 0)
        slow_then_fast.done = True
        return x
    slow_then_fast.done = False
    runner = StepRunner(slow_then_fast, FTConfig(straggler_factor=2.0),
                        predicted_step_s=0.005,
                        on_straggler=lambda i, dt: flags.append(dt))
    runner(1)   # slow step -> flagged
    runner(1)
    assert runner.stragglers >= 1 and len(flags) >= 1


def test_trainer_resumes_after_simulated_crash(tmp_path):
    """Kill training mid-run; a fresh Trainer resumes from the checkpoint."""
    from repro.configs import get_config, reduced_config
    from repro.models import build_model
    from repro.train import optimizer as opt_lib
    from repro.train.loop import LoopConfig, Trainer

    cfg = reduced_config(get_config("qwen2-0.5b"))
    model = build_model(cfg)
    d = str(tmp_path / "ck")
    lc = LoopConfig(steps=6, batch=2, seq=32, ckpt_every=2, ckpt_dir=d,
                    log_every=1)
    t1 = Trainer(model, opt_lib.OptConfig(), lc)
    t1.run(steps=4)  # "crash" after 4 steps (ckpts at 2,4)
    assert ckpt.latest_step(d) == 4

    t2 = Trainer(model, opt_lib.OptConfig(), lc)
    log = t2.run()  # resumes at 4, finishes 6
    steps_seen = [r["step"] for r in t2.metrics_log]
    assert min(steps_seen) >= 4
    assert ckpt.latest_step(d) == 6
