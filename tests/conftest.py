import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests import given/settings/st from tests/_hypo.py, which
# re-exports hypothesis when installed and falls back to a deterministic
# fixed-example runner when not (so the suite collects on bare envs).
