import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests import given/settings/st from tests/_hypo.py, which
# re-exports hypothesis when installed and falls back to a deterministic
# fixed-example runner when not (so the suite collects on bare envs).


@pytest.fixture(params=("json", "segment"))
def store_engine(request, monkeypatch):
    """Parametrize a test over both store backends.

    For ``segment`` the fixture rebinds the store classes that
    ``test_kvstore``/``test_trace_store`` reference as module globals,
    so those suites' test functions — invoked by the differential
    harness in ``test_store_engines.py`` — run verbatim against the
    segment-log engine. For ``json`` nothing is patched (the historical
    layout the suites were written against)."""
    if request.param == "segment":
        import test_store_engines

        test_store_engines.patch_segment(monkeypatch)
    return request.param
