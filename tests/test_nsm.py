"""NSM extraction: paper's construction semantics + properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core.nsm import NSMFeaturizer, nsm_edges, nsm_of_fn


def _sds(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def test_chain_counts():
    """A sequential chain of K distinct ops yields exactly K-1 edges."""
    def f(x):
        a = jnp.tanh(x)       # tanh
        b = jnp.exp(a)        # exp
        c = jnp.sin(b)        # sin
        return c
    e = nsm_of_fn(f, _sds(4))
    assert e == {("tanh", "exp"): 1.0, ("exp", "sin"): 1.0}


def test_fanout_counts_each_consumer():
    def f(x):
        a = jnp.tanh(x)
        return jnp.exp(a) + jnp.sin(a)
    e = nsm_of_fn(f, _sds(4))
    assert e[("tanh", "exp")] == 1.0
    assert e[("tanh", "sin")] == 1.0
    assert e[("exp", "add")] == 1.0
    assert e[("sin", "add")] == 1.0


def test_scan_multiplies_body_edges():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y
    e = nsm_of_fn(f, _sds(4, 8), _sds(8, 8))
    assert e[("dot", "tanh")] == 7.0
    assert e[("tanh", "dot")] == 6.0  # carry feedback edges


def test_transparent_calls():
    def f(x):
        g = jax.jit(lambda a: jnp.exp(a))
        return jnp.sin(g(jnp.tanh(x)))
    e = nsm_of_fn(f, _sds(4))
    assert e[("tanh", "exp")] == 1.0
    assert e[("exp", "sin")] == 1.0
    assert all("jit" not in k for pair in e for k in pair)


def test_grad_graph_has_more_edges():
    def loss(w, x):
        return jnp.sum(jnp.tanh(x @ w))
    fwd = nsm_of_fn(lambda w, x: jnp.sum(jnp.tanh(x @ w)),
                    _sds(8, 8), _sds(4, 8))
    bwd = nsm_of_fn(jax.grad(loss), _sds(8, 8), _sds(4, 8))
    assert sum(bwd.values()) > sum(fwd.values())


def test_featurizer_fixed_dim_and_other_bucket():
    e1 = {("dot", "tanh"): 3.0, ("tanh", "dot"): 2.0}
    e2 = {("conv", "max"): 5.0}
    f = NSMFeaturizer(max_vocab=3).fit([e1, e2])
    assert len(f.vocab) == 3 and f.vocab[-1] == "<other>"
    v1 = f.vector(e1)
    assert v1.shape == (3 * 3 + 6,)
    unseen = f.vector({("weird", "op"): 1.0})
    assert unseen.sum() > 0  # lands in <other>


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 5), st.integers(2, 9))
def test_property_depth_scaling(width, depth):
    """Stacking the same block d times scales every edge count by ~d."""
    def block(x):
        return jnp.tanh(x * 2.0 + 1.0)

    def deep(x):
        for _ in range(depth):
            x = block(x)
        return x

    e1 = nsm_of_fn(block, _sds(width))
    ed = nsm_of_fn(deep, _sds(width))
    for pair, n in e1.items():
        assert ed[pair] >= n * depth - depth  # boundary edges differ by <=1/iter


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(1, 8))
def test_property_scan_linear(length, width):
    def f(x):
        def body(c, _):
            return jnp.tanh(c) * 1.5, None
        y, _ = jax.lax.scan(body, x, None, length=length)
        return y
    e = nsm_of_fn(f, _sds(width))
    assert e[("tanh", "mul")] == length
    # all counts non-negative integers
    assert all(v >= 0 and float(v).is_integer() for v in e.values())
