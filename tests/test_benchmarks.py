"""Harness coverage for benchmarks/collect.py and benchmarks/run.py.

The bench driver is CI's gatekeeper (a red bench must exit nonzero) and
the profile cache is the corpus every MRE bench reads — neither had a
test before this file.
"""

import json
import os
import sys
import types

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import collect, run as bench_run
from repro.core.features import ProfileRecord, record_to_json


def _record(name="m0"):
    return ProfileRecord(
        model_name=name, family="dense", batch_size=4, input_size=32,
        channels=64, learning_rate=1e-3, epoch=1, optimizer="adamw",
        layers=3, flops=1e9, params=1000,
        nsm_edges={("dot", "add"): 4.0}, time_s=0.5, mem_bytes=2e6)


# -- collect.py ---------------------------------------------------------------


def test_load_cache_skips_corrupt_lines(tmp_path, monkeypatch):
    cache = tmp_path / "profiles.jsonl"
    combo = {"kind": "zoo", "name": "lenet5", "batch": 8, "image": 32}
    good = {"key": collect._key(combo), "record": record_to_json(_record())}
    cache.write_text(json.dumps(good) + "\n"
                     "{not json at all\n"
                     '{"key_is_missing": 1}\n')
    monkeypatch.setattr(collect, "CACHE", str(cache))
    loaded = collect._load_cache()
    assert list(loaded) == [collect._key(combo)]


def test_collect_serves_cached_records_without_profiling(tmp_path,
                                                         monkeypatch):
    cache = tmp_path / "profiles.jsonl"
    combo = {"kind": "zoo", "name": "lenet5", "batch": 8, "image": 32}
    cache.write_text(json.dumps(
        {"key": collect._key(combo),
         "record": record_to_json(_record("lenet5"))}) + "\n")
    monkeypatch.setattr(collect, "CACHE", str(cache))
    # any cache miss would profile for real — fail the test instead
    monkeypatch.setattr(collect, "_profile",
                        lambda c: pytest.fail("cache should have hit"))
    recs = collect.collect([combo], verbose=False)
    assert len(recs) == 1
    assert recs[0].model_name == "lenet5"
    assert recs[0].time_s == 0.5


def test_collect_appends_new_records_to_cache(tmp_path, monkeypatch):
    cache = tmp_path / "profiles.jsonl"
    combo = {"kind": "zoo", "name": "nin", "batch": 8, "image": 32}
    monkeypatch.setattr(collect, "CACHE", str(cache))
    monkeypatch.setattr(collect, "_profile", lambda c: _record("nin"))
    recs = collect.collect([combo], verbose=False)
    assert len(recs) == 1 and cache.exists()
    # second call round-trips through the freshly written cache
    monkeypatch.setattr(collect, "_profile",
                        lambda c: pytest.fail("cache should have hit"))
    again = collect.collect([combo], verbose=False)
    assert again[0].model_name == "nin"


# -- run.py -------------------------------------------------------------------


def _fake_bench(monkeypatch, name, run_fn):
    mod = types.ModuleType(f"benchmarks._fake_{name}")
    mod.run = run_fn
    monkeypatch.setitem(sys.modules, mod.__name__, mod)
    return (name, mod.__name__)


def test_run_exits_nonzero_when_a_bench_raises(monkeypatch, capsys):
    benches = [
        _fake_bench(monkeypatch, "ok", lambda: [("metric", 1.0)]),
        _fake_bench(monkeypatch, "boom",
                    lambda: (_ for _ in ()).throw(RuntimeError("gate"))),
    ]
    monkeypatch.setattr(bench_run, "BENCHES", benches)
    assert bench_run.main([]) == 1
    out = capsys.readouterr().out
    assert "ok.metric,1" in out
    assert "boom.wall_s" in out  # wall time still reported for the failure


def test_run_exits_zero_when_all_benches_pass(monkeypatch, capsys):
    benches = [_fake_bench(monkeypatch, "ok", lambda: [("metric", 2.0)])]
    monkeypatch.setattr(bench_run, "BENCHES", benches)
    assert bench_run.main([]) == 0
    assert "ok.metric,2" in capsys.readouterr().out


def test_run_only_filter(monkeypatch, capsys):
    ran = []
    benches = [
        _fake_bench(monkeypatch, "a", lambda: ran.append("a") or []),
        _fake_bench(monkeypatch, "b", lambda: ran.append("b") or []),
    ]
    monkeypatch.setattr(bench_run, "BENCHES", benches)
    assert bench_run.main(["--only", "b"]) == 0
    assert ran == ["b"]


def test_scenarios_bench_is_registered():
    assert ("scenarios", "benchmarks.bench_scenarios") in bench_run.BENCHES


def test_aggregate_artifacts(tmp_path):
    (tmp_path / "BENCH_refit.json").write_text(
        json.dumps({"time_mre_improvement": 3.0, "smoke": True}))
    (tmp_path / "BENCH_rpc.json").write_text(
        json.dumps({"resolve_errors": 0.0}))
    (tmp_path / "BENCH_broken.json").write_text("{truncated")
    (tmp_path / "BENCH_all.json").write_text(
        json.dumps({"stale": "previous aggregate"}))
    agg = bench_run.aggregate_artifacts(str(tmp_path))
    assert sorted(agg) == ["refit", "rpc"]  # corrupt + old aggregate skipped
    assert agg["refit"]["time_mre_improvement"] == 3.0


def test_run_aggregate_flag_writes_bench_all(monkeypatch, tmp_path):
    monkeypatch.chdir(tmp_path)
    (tmp_path / "BENCH_x.json").write_text(json.dumps({"v": 1.0}))
    monkeypatch.setattr(bench_run, "BENCHES", [])
    assert bench_run.main(["--aggregate"]) == 0
    agg = json.loads((tmp_path / "BENCH_all.json").read_text())
    assert agg == {"x": {"v": 1.0}}
