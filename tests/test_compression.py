"""Gradient compression with error feedback: accuracy + EF accumulation."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.compression import (allreduce_compressed,
                                           ef_compress, ef_decompress,
                                           ef_init, shard_map)
from repro.launch.mesh import make_host_mesh


def test_quantize_roundtrip_small_error():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 0.1}
    ef = ef_init(g)
    comp, ef = ef_compress(g, ef)
    out = ef_decompress(comp)
    rel = float(jnp.linalg.norm(out["w"] - g["w"])
                / jnp.linalg.norm(g["w"]))
    assert rel < 0.02  # int8 per-tensor quantization
    assert comp.q["w"].dtype == jnp.int8


def test_error_feedback_accumulates_to_truth():
    """Repeatedly compressing the SAME gradient, the EF-corrected mean of
    decompressed gradients converges to the true gradient."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (32,)) * 0.05}
    ef = ef_init(g)
    acc = jnp.zeros_like(g["w"])
    n = 20
    for _ in range(n):
        comp, ef = ef_compress(g, ef)
        acc = acc + ef_decompress(comp)["w"]
    rel = float(jnp.linalg.norm(acc / n - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 5e-3  # EF drives the time-averaged error to ~0


def test_allreduce_compressed_single_device():
    mesh = make_host_mesh(1, 1)
    g = {"w": jax.random.normal(jax.random.PRNGKey(2), (16, 16)) * 0.1}
    ef = ef_init(g)

    def f(g, ef):
        return allreduce_compressed(g, ef, "data")

    out, ef2 = jax.jit(
        shard_map(f, mesh=mesh,
                  in_specs=(P(), P()), out_specs=(P(), P())))(g, ef)
    rel = float(jnp.linalg.norm(out["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.02
