"""AutoML-lite: trees, forests, boosting, ensembling, serialization."""

import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core.automl.models import (ExtraTreesRegressor,
                                      GradientBoostingRegressor,
                                      KNNRegressor, RandomForestRegressor,
                                      RidgeRegressor, model_from_dict)
from repro.core.automl.search import fit_automl
from repro.core.automl.tree import DecisionTreeRegressor, TreeConfig
from repro.core.features import mre


def _data(n=300, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0, 1, (n, 8))
    y = 3 * x[:, 0] + np.where(x[:, 1] > 0.5, 2.0, -1.0) + 0.5 * x[:, 2] ** 2
    return x, y


def test_tree_fits_step_function():
    x, y = _data()
    t = DecisionTreeRegressor(TreeConfig(max_depth=8)).fit(x, y)
    pred = t.predict(x)
    assert np.mean((pred - y) ** 2) < 0.05 * np.var(y)


def test_tree_respects_max_depth():
    x, y = _data()
    t = DecisionTreeRegressor(TreeConfig(max_depth=1)).fit(x, y)
    assert len(np.unique(t.predict(x))) <= 2


@pytest.mark.parametrize("cls,kw,factor", [
    (RandomForestRegressor, {"n_trees": 15}, 0.7),
    # random-threshold splits need more trees to average out on 240 points
    (ExtraTreesRegressor, {"n_trees": 40}, 0.85),
    (GradientBoostingRegressor, {"n_stages": 60}, 0.7),
    (RidgeRegressor, {}, 0.7),
    (KNNRegressor, {}, 0.7),
])
def test_models_beat_mean_and_roundtrip(cls, kw, factor):
    x, y = _data()
    xt, yt = x[:240], y[:240]
    xv, yv = x[240:], y[240:]
    m = cls(**kw).fit(xt, yt)
    mse = np.mean((m.predict(xv) - yv) ** 2)
    base = np.mean((np.mean(yt) - yv) ** 2)
    assert mse < factor * base, (cls.KIND, mse, base)
    m2 = model_from_dict(m.to_dict())
    np.testing.assert_allclose(m2.predict(xv), m.predict(xv), rtol=1e-9)


def test_fit_automl_selects_and_predicts_positive():
    x, y = _data()
    y = np.exp(y)  # strictly positive target, wide range
    ens = fit_automl(x[:240], y[:240],
                     candidates=[RandomForestRegressor(n_trees=10),
                                 RidgeRegressor()])
    pred = ens.predict(x[240:])
    assert (pred > 0).all()
    assert mre(pred, y[240:]) < 0.5
    assert len(ens.leaderboard) == 2


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_property_tree_predictions_within_target_range(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(50, 4))
    y = rng.normal(size=50)
    t = DecisionTreeRegressor(TreeConfig(max_depth=6)).fit(x, y)
    p = t.predict(rng.normal(size=(20, 4)))
    assert p.min() >= y.min() - 1e-9 and p.max() <= y.max() + 1e-9
