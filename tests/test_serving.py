"""Serving engine: prefill + greedy decode loop, MoE/SSM decode paths."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import build_model
from repro.serve.engine import DecodeEngine

# compiles prefill/decode for three archs: tier-2 only
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-370m",
                                  "jamba-v0.1-52b"])
def test_generate_runs_and_is_deterministic(arch):
    cfg = reduced_config(get_config(arch))
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    B, S, steps = 2, 16, 4
    eng = DecodeEngine(model, params, batch=B, max_seq=S + steps + 1)
    batch = {"tokens": (jnp.arange(B * S, dtype=jnp.int32)
                        .reshape(B, S)) % 50}
    # engine decodes against a cache sized by prefill output; pad inputs
    toks0 = eng.prefill({"tokens": jnp.pad(batch["tokens"],
                                           ((0, 0), (0, steps + 1)))})
    out1 = np.asarray(eng.generate(toks0, steps))

    eng2 = DecodeEngine(model, params, batch=B, max_seq=S + steps + 1)
    toks0b = eng2.prefill({"tokens": jnp.pad(batch["tokens"],
                                             ((0, 0), (0, steps + 1)))})
    out2 = np.asarray(eng2.generate(toks0b, steps))
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (B, steps + 1)
    assert (out1 >= 0).all() and (out1 < cfg.vocab_size + 256).all()
