"""End-to-end behaviour of the paper's system: profile -> NSM features ->
AutoML predictor -> schedule, plus the launcher admission-control path."""

import numpy as np
import pytest

from repro.core.automl.models import (GradientBoostingRegressor,
                                      RandomForestRegressor, RidgeRegressor)
from repro.core.predictor import DNNAbacus
from repro.core.profiler import profile_zoo
from repro.core.scheduler import Job, Machine, schedule_ga, schedule_random

GIB = 2**30

# profiles + compiles real train steps end to end: tier-2 only
pytestmark = pytest.mark.slow


def _factory(seed):
    return [RandomForestRegressor(n_trees=25, max_depth=16,
                                  min_samples_leaf=1, seed=seed),
            GradientBoostingRegressor(n_stages=120, seed=seed),
            RidgeRegressor()]


def test_profile_fit_predict_schedule_end_to_end(tmp_path):
    # 1. profile real training steps (paper §2 rig)
    records = []
    for net in ("lenet5", "squeezenet", "nin"):
        for batch in (8, 16, 32):
            r = profile_zoo(net, batch=batch, steps=2)
            assert r.time_s > 0 and r.mem_bytes > 0 and r.flops > 0
            assert r.nsm_edges  # the structural matrix is non-empty
            records.append(r)

    # 2. fit DNNAbacus (paper §3) and sanity-check in-sample MRE
    ab = DNNAbacus().fit(records, candidate_factory=_factory)
    ev = ab.evaluate(records)
    assert ev["time_mre"] < 1.0 and ev["mem_mre"] < 1.0

    # 3. persistence roundtrip (launcher admission control loads this)
    path = str(tmp_path / "abacus")
    ab.save(path)
    ab2 = DNNAbacus.load(path)
    t1, _ = ab.predict(records[:3])
    t2, _ = ab2.predict(records[:3])
    np.testing.assert_allclose(t1, t2)

    # 4. schedule 9 jobs from PREDICTED costs (paper §4.3)
    t_pred, m_pred = ab2.predict(records)
    jobs = [Job(r.model_name, float(t) * 50, float(m) + GIB // 4)
            for r, t, m in zip(records, t_pred, m_pred)]
    machines = [Machine("sys1", 11 * GIB), Machine("sys2", 24 * GIB)]
    ga, assign = schedule_ga(jobs, machines, generations=25, seed=0)
    rnd, _ = schedule_random(jobs, machines, trials=50, seed=0)
    assert np.isfinite(ga)
    assert ga <= rnd * 1.0001  # GA at least matches mean random placement
    assert len(assign) == len(jobs)
