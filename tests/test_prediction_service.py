"""PredictionService: trace cache, batched queries, vectorized NSM parity."""

import dataclasses

import numpy as np
import pytest

from _hypo import given, settings, st
from repro.core.automl.models import RidgeRegressor
from repro.core.features import ProfileRecord
from repro.core.nsm import NSMFeaturizer
from repro.core.predictor import DNNAbacus
from repro.core.scheduler import (Machine, jobs_from_estimates,
                                  schedule_jobs)
from repro.serve.prediction_service import (PredictionService, Query,
                                            config_fingerprint)

OPS = ["dot", "add", "tanh", "exp", "conv", "max", "mul", "weird_op",
       "unseen1", "unseen2"]


def _random_edges(rng, n_edges: int):
    return {(OPS[int(rng.integers(len(OPS)))],
             OPS[int(rng.integers(len(OPS)))]): float(rng.integers(1, 50))
            for _ in range(n_edges)}


def _records(n=40, seed=0):
    rng = np.random.default_rng(seed)
    recs = []
    for i in range(n):
        batch = int(rng.choice([2, 4, 8]))
        edges = _random_edges(rng, 6)
        recs.append(ProfileRecord(
            model_name=f"m{i}", family="dense", batch_size=batch,
            input_size=32, channels=16, learning_rate=1e-3, epoch=1,
            optimizer="adamw", layers=4, flops=batch * 1e8,
            params=10_000, nsm_edges=edges,
            time_s=batch * 0.01, mem_bytes=batch * 1e6))
    return recs


def _abacus(seed=0):
    fac = lambda s: [RidgeRegressor()]
    return DNNAbacus(seed=seed).fit(_records(seed=seed),
                                    candidate_factory=fac)


def _fake_cfg(name="fake", batch_sens=1.0):
    """Duck-typed stand-in for ModelConfig (fingerprint uses vars())."""

    class _Cfg:
        def __init__(self):
            self.name = name
            self.family = "dense"
            self.num_layers = 4
            self.d_model = 16
            self.batch_sens = batch_sens

    return _Cfg()


def _counting_tracer(calls):
    def tracer(cfg, batch, seq):
        calls.append((cfg.name, batch, seq))
        rng = np.random.default_rng(batch * 1000 + seq)
        return ProfileRecord(
            model_name=cfg.name, family=cfg.family, batch_size=batch,
            input_size=seq, channels=16, learning_rate=1e-3, epoch=1,
            optimizer="adamw", layers=cfg.num_layers, flops=batch * seq * 1e6,
            params=10_000, nsm_edges=_random_edges(rng, 5))
    return tracer


# -- vectorized NSM featurization parity -------------------------------------


def _naive_matrix(feat: NSMFeaturizer, edges) -> np.ndarray:
    """The original O(E*V) implementation, kept as the parity oracle."""
    def idx(op):
        try:
            return feat.vocab.index(op)
        except ValueError:
            return len(feat.vocab) - 1

    v = len(feat.vocab)
    m = np.zeros((v, v), np.float64)
    for (a, b), n in edges.items():
        m[idx(a), idx(b)] += n
    return m


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 40))
def test_vectorized_matrix_bitmatches_naive(seed, n_edges):
    rng = np.random.default_rng(seed)
    fit_dicts = [_random_edges(rng, 8) for _ in range(4)]
    feat = NSMFeaturizer(max_vocab=6).fit(fit_dicts)
    edges = _random_edges(rng, n_edges)
    np.testing.assert_array_equal(feat.matrix(edges),
                                  _naive_matrix(feat, edges))
    np.testing.assert_array_equal(
        feat.vector(edges),
        np.log1p(np.concatenate([
            _naive_matrix(feat, edges).reshape(-1),
            _naive_matrix(feat, edges).sum(0),
            _naive_matrix(feat, edges).sum(1)])))


def test_featurizer_index_rebuilds_after_vocab_swap():
    feat = NSMFeaturizer(max_vocab=4).fit([{("dot", "add"): 1.0}])
    m1 = feat.matrix({("dot", "add"): 2.0})
    assert m1.sum() == 2.0
    feat.vocab = ["tanh", "exp", "<other>"]  # as DNNAbacus.load does
    m2 = feat.matrix({("tanh", "exp"): 3.0})
    assert m2[0, 1] == 3.0 and m2.shape == (3, 3)


def test_batched_vectors_match_single():
    rng = np.random.default_rng(7)
    dicts = [_random_edges(rng, 5) for _ in range(6)]
    feat = NSMFeaturizer(max_vocab=5).fit(dicts)
    batched = feat.vectors(dicts)
    assert batched.shape == (6, feat.dim)
    for i, d in enumerate(dicts):
        np.testing.assert_array_equal(batched[i], feat.vector(d))


# -- trace cache -------------------------------------------------------------


def test_second_query_hits_cache_no_retrace():
    calls = []
    svc = PredictionService(_abacus(), tracer=_counting_tracer(calls))
    cfg = _fake_cfg()
    e1 = svc.predict_one(cfg, 2, 32)
    assert len(calls) == 1
    e2 = svc.predict_one(cfg, 2, 32)
    assert len(calls) == 1  # cache hit: no second trace
    assert e1["time_s"] == e2["time_s"]
    assert e1["memory_bytes"] == e2["memory_bytes"]
    svc.predict_one(cfg, 4, 32)
    assert len(calls) == 2  # new (batch) key -> one new trace
    info = svc.cache_info()
    assert info["hits"] == 1 and info["misses"] == 2 and info["entries"] == 2


def test_concurrent_identical_queries_trace_once():
    import threading
    import time

    calls = []
    base = _counting_tracer(calls)

    def slow_tracer(cfg, batch, seq):
        time.sleep(0.05)
        return base(cfg, batch, seq)

    svc = PredictionService(_abacus(), tracer=slow_tracer)
    cfg = _fake_cfg()
    results = []

    def worker():
        results.append(svc.predict_one(cfg, 2, 32))

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(calls) == 1  # in-flight dedup: a burst pays one trace
    assert len(results) == 8
    assert len({r["time_s"] for r in results}) == 1


def test_fingerprint_is_content_addressed():
    from repro.configs import get_config, reduced_config
    cfg = reduced_config(get_config("qwen2-0.5b"))
    twin = dataclasses.replace(cfg)  # distinct object, equal content
    assert cfg is not twin
    assert config_fingerprint(cfg) == config_fingerprint(twin)
    other = dataclasses.replace(cfg, num_layers=cfg.num_layers + 1)
    assert config_fingerprint(cfg) != config_fingerprint(other)


class _GnarlyCfg:
    """Config with every field shape json.dumps(default=str) mangles."""

    def __init__(self):
        self.name = "gnarly"
        self.pattern = (("attn", "dense"), ("ssm", "moe"))  # nested tuples
        self.tags = {"b", "a", "c"}                 # set: hash-seed order
        self.table = {("k", 1): 2.0, ("k", 0): 1.0}  # non-str dict keys
        self.opt = object()                          # id()-bearing repr


def test_fingerprint_canonicalizes_nested_payloads():
    assert config_fingerprint(_GnarlyCfg()) == config_fingerprint(_GnarlyCfg())

    class A:
        def __init__(self):
            self.x = (1, 2)

    class B:
        def __init__(self):
            self.x = [1, 2]

    # tuples and lists must NOT collide into one cache entry
    assert config_fingerprint(A()) != config_fingerprint(B())

    class C:
        def __init__(self):
            self.x = [1, 2, 3]

    assert config_fingerprint(B()) != config_fingerprint(C())


def test_fingerprint_numpy_and_callable_fields():
    import functools

    def _cfg(**fields):
        class _C:
            def __init__(self):
                for k, v in fields.items():
                    setattr(self, k, v)
        return _C()

    # multi-element ndarrays fingerprint (no .item() crash), and neither
    # collide with the equivalent list nor with a bare scalar
    arr = config_fingerprint(_cfg(w=np.array([256, 512])))
    assert arr == config_fingerprint(_cfg(w=np.array([256, 512])))
    assert arr != config_fingerprint(_cfg(w=[256, 512]))
    assert (config_fingerprint(_cfg(w=np.array([2])))
            != config_fingerprint(_cfg(w=2)))
    assert (config_fingerprint(_cfg(w=np.float32(2.0)))
            == config_fingerprint(_cfg(w=2.0)))

    # functools.partial: content-addressed by (func, args, kwargs), never
    # by its id()-bearing repr
    p1 = config_fingerprint(_cfg(act=functools.partial(max, 1)))
    assert p1 == config_fingerprint(_cfg(act=functools.partial(max, 1)))
    assert p1 != config_fingerprint(_cfg(act=functools.partial(max, 2)))

    # callable *instances* use their attrs, not '<... object at 0x..>'
    class _Act:
        def __init__(self, scale):
            self.scale = scale

        def __call__(self, x):
            return x * self.scale

    a1 = config_fingerprint(_cfg(act=_Act(2.0)))
    assert a1 == config_fingerprint(_cfg(act=_Act(2.0)))
    assert a1 != config_fingerprint(_cfg(act=_Act(3.0)))


def test_fingerprint_stable_across_processes():
    """The persistent TraceStore key must not depend on hash seed or id().

    A child interpreter with a different PYTHONHASHSEED must fingerprint
    the same gnarly config (sets, nested tuples, plain objects)
    identically — ``default=str`` failed this for any field whose str()
    embeds a memory address.
    """
    import os
    import subprocess
    import sys

    code = """
import sys
sys.path.insert(0, sys.argv[1])
from repro.serve.prediction_service import config_fingerprint

class _GnarlyCfg:
    def __init__(self):
        self.name = "gnarly"
        self.pattern = (("attn", "dense"), ("ssm", "moe"))
        self.tags = {"b", "a", "c"}
        self.table = {("k", 1): 2.0, ("k", 0): 1.0}
        self.opt = object()

print(config_fingerprint(_GnarlyCfg()))
"""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    fps = set()
    for seed in ("0", "1", "12345"):
        env = dict(os.environ, PYTHONHASHSEED=seed)
        out = subprocess.run([sys.executable, "-c", code, src],
                             capture_output=True, text=True, env=env,
                             check=True)
        fps.add(out.stdout.strip())
    assert fps == {config_fingerprint(_GnarlyCfg())}


def test_lru_eviction_bounds_cache():
    calls = []
    svc = PredictionService(_abacus(), max_cache_entries=2,
                            tracer=_counting_tracer(calls))
    cfg = _fake_cfg()
    for batch in (2, 4, 8):
        svc.predict_one(cfg, batch, 32)
    assert svc.cache_info()["entries"] == 2
    assert svc.stats.evictions == 1
    svc.predict_one(cfg, 2, 32)  # evicted -> re-traced
    assert len(calls) == 4


# -- batched prediction ------------------------------------------------------


def test_predict_many_matches_looped_predict_one():
    calls = []
    ab = _abacus()
    svc = PredictionService(ab, tracer=_counting_tracer(calls))
    cfgs = [_fake_cfg("a"), _fake_cfg("b"), _fake_cfg("c")]
    queries = [Query(c, b, 32) for c in cfgs for b in (2, 4)]
    many = svc.predict_many(queries)
    fresh = PredictionService(ab, tracer=_counting_tracer([]))
    looped = [fresh.predict_one(q.cfg, q.batch, q.seq) for q in queries]
    assert len(many) == len(queries)
    for e_many, e_loop in zip(many, looped):
        np.testing.assert_allclose(e_many["time_s"], e_loop["time_s"])
        np.testing.assert_allclose(e_many["memory_bytes"],
                                   e_loop["memory_bytes"])


def test_predict_many_accepts_tuples_and_empty():
    svc = PredictionService(_abacus(), tracer=_counting_tracer([]))
    assert svc.predict_many([]) == []
    ests = svc.predict_many([(_fake_cfg(), 2, 32)])
    assert np.isfinite(ests[0]["time_s"])
    assert np.isfinite(ests[0]["memory_bytes"])


def test_predict_config_goes_through_service_cache():
    """DNNAbacus.predict_config shares the service's trace cache."""
    ab = _abacus()
    calls = []
    ab._service = PredictionService(ab, tracer=_counting_tracer(calls))
    cfg = _fake_cfg()
    e1 = ab.predict_config(cfg, 2, 32)
    e2 = ab.predict_config(cfg, 2, 32)
    assert len(calls) == 1
    assert e1["time_s"] == e2["time_s"]
    assert "hbm_budget" in e1


# -- scheduling bridge -------------------------------------------------------


GIB = 2**30


def test_service_schedules_predicted_jobs():
    svc = PredictionService(_abacus(), tracer=_counting_tracer([]))
    queries = [Query(_fake_cfg(n), b, 32)
               for n in ("a", "b", "c") for b in (2, 4)]
    machines = [Machine("m1", 11 * GIB), Machine("m2", 24 * GIB)]
    span, assign = svc.schedule(queries, machines, plan="ga",
                                time_scale=50, mem_pad=GIB // 4,
                                generations=10, seed=0)
    assert np.isfinite(span)
    assert len(assign) == len(queries)
    assert set(assign) <= {0, 1}


def test_schedule_jobs_dispatch_and_unknown_plan():
    jobs = jobs_from_estimates(["j1", "j2"], [1.0, 2.0], [GIB, GIB],
                               time_scale=10, mem_pad=0.5 * GIB)
    assert jobs[0].time_s == 10.0 and jobs[0].mem_bytes == 1.5 * GIB
    machines = [Machine("m1", 4 * GIB)]
    span, _ = schedule_jobs(jobs, machines, plan="optimal")
    assert span == 30.0
    with pytest.raises(ValueError):
        schedule_jobs(jobs, machines, plan="nope")


# -- end-to-end with the real tracer (reduced LM config) ---------------------


def test_predict_many_equals_predict_config_real_trace():
    from repro.configs import get_config, reduced_config
    ab = _abacus()
    cfg = reduced_config(get_config("qwen2-0.5b"))
    queries = [Query(cfg, 2, 32), Query(cfg, 4, 32)]
    many = ab.service().predict_many(queries)
    looped = [ab.predict_config(cfg, 2, 32), ab.predict_config(cfg, 4, 32)]
    for e_many, e_loop in zip(many, looped):
        np.testing.assert_allclose(e_many["time_s"], e_loop["time_s"])
        np.testing.assert_allclose(e_many["memory_bytes"],
                                   e_loop["memory_bytes"])
    # the looped predict_config calls hit the predict_many traces
    assert ab.service().cache_info()["misses"] == 2
