"""JsonFileStore base: property tests shared by every durable store.

Round-trip, ``merge`` commutativity/idempotence, compaction never
dropping the newest entry per key, corrupt-file injection never
raising, and the schema-version unification regression (TraceStore and
FeedbackStore historically carried *separate* version constants and
skip semantics; one v-mixed directory now behaves identically under
both). Properties run with or without hypothesis via ``tests/_hypo``.
"""

import json
import os
import threading
import time

import numpy as np
import pytest

from _hypo import given, settings, st
from repro.serve import kvstore
from repro.serve.feedback_store import FeedbackStore
from repro.serve.kvstore import JsonFileStore
from repro.serve.trace_store import SCHEMA_VERSION, TraceStore

from test_prediction_service import _random_edges
from test_trace_store import _record


def _key(rng) -> tuple:
    return (f"{int(rng.integers(0, 16**8)):08x}" * 2,
            int(rng.integers(1, 5)) * 2, int(rng.choice([32, 64, 128])))


def _rand_record(rng, name=None):
    batch, seq = int(rng.integers(1, 5)) * 2, int(rng.choice([32, 64]))
    rec = _record(name or f"m{int(rng.integers(1e6))}", batch=batch, seq=seq)
    return rec


# -- unification regression (satellite: one version ladder) -------------------


def test_schema_version_is_shared_by_every_store():
    """The latent bug class: TraceStore and FeedbackStore each had their
    own SCHEMA_VERSION constant, so bumping one silently left the other
    on an old ladder. Both now inherit the single kvstore constant."""
    assert TraceStore.schema_version == FeedbackStore.schema_version
    assert TraceStore.schema_version == kvstore.SCHEMA_VERSION
    from repro.serve import feedback_store, trace_store
    assert trace_store.SCHEMA_VERSION == feedback_store.SCHEMA_VERSION
    assert trace_store.SCHEMA_VERSION == kvstore.SCHEMA_VERSION
    assert SCHEMA_VERSION == kvstore.SCHEMA_VERSION


def test_v_mixed_directory_loads_identically_in_both_stores(tmp_path):
    """A directory holding entries from several schema generations (an
    in-place upgrade, a rolled-back host) must serve current-version
    entries and skip+count the rest — same semantics in both stores."""
    ts = TraceStore(str(tmp_path / "traces"))
    fb = FeedbackStore(str(tmp_path / "fb"))
    keys = [("aa" * 8, 2, 32), ("bb" * 8, 4, 32), ("cc" * 8, 8, 64)]
    for key in keys:
        ts.put(key, _record(batch=key[1], seq=key[2]))
        fb.add(key, 1.5, 2e9, ts=10.0)
    # rewrite one entry per store to a PAST version, one to a FUTURE one
    for store, versions in ((ts, (0, 99)), (fb, (0, 99))):
        for key, version in zip(keys[:2], versions):
            path = store.path_for(key)
            with open(path) as f:
                payload = json.load(f)
            payload["version"] = version
            with open(path, "w") as f:
                json.dump(payload, f)
    # loads: current entry served, foreign versions skipped (never fatal)
    assert ts.get(keys[2]) is not None and fb.get(keys[2]) != []
    for key in keys[:2]:
        assert ts.get(key) is None
        assert fb.get(key) == []
    assert ts.stats.corrupt >= 2 and fb.stats.corrupt >= 2
    assert list(ts.keys()) == [keys[2]]
    assert fb.keys() == [keys[2]]
    assert fb.total(rescan=True) == 1
    # compaction drops the unservable generations, keeps the current one
    assert ts.compact()["stale_schema"] == 2
    assert fb.compact()["corrupt_files"] == 2
    assert len(ts._files()) == 1 and len(fb._files()) == 1
    assert ts.get(keys[2]) is not None and fb.get(keys[2]) != []


def test_filename_key_disagreement_dead_on_every_path(tmp_path):
    """Skip-semantics unification: a renamed file (stored key disagrees
    with its filename) is dead EVERYWHERE — get() refuses it (historic
    FeedbackStore served it), iter/keys/merge never propagate it, and
    compact() reclaims it instead of letting it re-count as corrupt on
    every read forever."""
    ts = TraceStore(str(tmp_path / "t"))
    fb = FeedbackStore(str(tmp_path / "f"))
    key, other = ("11" * 8, 2, 32), ("22" * 8, 4, 64)
    ts.put(key, _record())
    fb.add(key, 1.0, 1e9, ts=5.0)
    os.rename(ts.path_for(key), ts.path_for(other))
    os.rename(fb.path_for(key), fb.path_for(other))
    assert ts.get(other) is None and ts.stats.corrupt == 1
    assert fb.get(other) == [] and fb.stats.corrupt == 1
    assert ts.get(key) is None and fb.get(key) == []  # original key too
    assert list(ts.keys()) == [] and fb.keys() == []
    assert fb.total(rescan=True) == 0
    sink_t, sink_f = TraceStore(str(tmp_path / "st")), \
        FeedbackStore(str(tmp_path / "sf"))
    assert sink_t.merge(ts) == 0 and sink_f.merge(fb) == 0
    assert ts.compact()["stale_schema"] == 1
    assert fb.compact()["corrupt_files"] == 1
    assert ts._files() == [] and fb._files() == []


# -- round-trip ----------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 6))
def test_trace_roundtrip_property(seed, n):
    rng = np.random.default_rng(seed)
    with __import__("tempfile").TemporaryDirectory() as root:
        store = TraceStore(root)
        entries = {}
        for _ in range(n):
            key = _key(rng)
            rec = _rand_record(rng)
            store.put(key, rec)
            entries[key] = rec
        for key, rec in entries.items():
            got = store.get(key)
            assert got == rec
            assert got.nsm_edges == rec.nsm_edges  # tuple keys survive JSON
        assert set(store.keys()) == set(entries)
        # a fresh instance over the same directory sees everything
        again = TraceStore(root)
        assert again.raw_snapshot() == store.raw_snapshot()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 8))
def test_feedback_roundtrip_property(seed, n):
    rng = np.random.default_rng(seed)
    with __import__("tempfile").TemporaryDirectory() as root:
        store = FeedbackStore(root)
        key = _key(rng)
        for i in range(n):
            store.add(key, float(rng.integers(1, 100)) / 10.0,
                      float(rng.integers(1, 100)) * 1e6, ts=float(i))
        obs = store.get(key)
        assert len(obs) == n
        assert [o.ts for o in obs] == sorted(o.ts for o in obs)
        assert FeedbackStore(root).total() == n


# -- merge: commutative, idempotent, convergent -------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 8))
def test_trace_merge_is_commutative_and_idempotent(seed, n):
    """Any merge order over any split converges to one fixed point —
    including keys where two hosts traced *different* records."""
    rng = np.random.default_rng(seed)
    entries = [(_key(rng), _rand_record(rng)) for _ in range(n)]
    # one deliberately conflicting key: both halves write different records
    conflict = _key(rng)
    import tempfile
    with tempfile.TemporaryDirectory() as root:
        a, b = TraceStore(root + "/a"), TraceStore(root + "/b")
        half = n // 2
        for key, rec in entries[:half]:
            a.put(key, rec)
        for key, rec in entries[half:]:
            b.put(key, rec)
        a.put(conflict, _rand_record(rng, name="host_a"))
        b.put(conflict, _rand_record(rng, name="host_b"))
        m1, m2 = TraceStore(root + "/m1"), TraceStore(root + "/m2")
        m1.merge(a), m1.merge(b)
        m2.merge(b), m2.merge(a)
        assert m1.raw_snapshot() == m2.raw_snapshot()  # commutative
        assert m1.merge(a) == 0 and m1.merge(b) == 0   # idempotent
        assert set(m1.keys()) == {k for k, _ in entries} | {conflict}
        # the conflict key may count twice (imported, then replaced by
        # the deterministic winner) — never less than one per entry
        assert len(m1) <= m1.stats.merged <= len(m1) + 1


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(3, 12))
def test_feedback_merge_three_way_converges(seed, n):
    rng = np.random.default_rng(seed)
    obs = [(_key(rng), float(rng.integers(1, 100)) / 10.0,
            float(rng.integers(1, 100)) * 1e6, float(i)) for i in range(n)]
    import tempfile
    with tempfile.TemporaryDirectory() as root:
        shards = [FeedbackStore(f"{root}/s{i}") for i in range(3)]
        for i, (key, t, m, ts) in enumerate(obs):
            shards[i % 3].add(key, t, m, ts=ts)
        orders = [(0, 1, 2), (2, 1, 0), (1, 2, 0)]
        snaps = []
        for j, order in enumerate(orders):
            central = FeedbackStore(f"{root}/c{j}")
            for idx in order:
                central.merge(shards[idx])
            for idx in order:                     # merge AGAIN: idempotent
                assert central.merge(shards[idx]) == 0
            snaps.append(central.snapshot())
        assert snaps[0] == snaps[1] == snaps[2]
        assert sum(len(v) for v in snaps[0].values()) == n


# -- compaction keeps the newest entry per key --------------------------------


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 8), st.integers(1, 4))
def test_trace_compact_never_drops_newest(seed, n, cap):
    rng = np.random.default_rng(seed)
    import tempfile
    with tempfile.TemporaryDirectory() as root:
        store = TraceStore(root)
        now = time.time()
        keys = []
        for i in range(n):
            key = _key(rng)
            store.put(key, _rand_record(rng))
            # distinct, strictly increasing mtimes (i newest at i=n-1)
            t = now - 1000 + i
            os.utime(store.path_for(key), (t, t))
            keys.append(key)
        out = store.compact(max_entries=cap)
        assert out["kept"] == min(cap, n)
        assert store.get(keys[-1]) is not None     # newest always survives
        survivors = set(store.keys())
        assert survivors == set(keys[-min(cap, n):])


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 10), st.integers(1, 3))
def test_feedback_compact_never_drops_newest_per_key(seed, n, cap):
    rng = np.random.default_rng(seed)
    import tempfile
    with tempfile.TemporaryDirectory() as root:
        store = FeedbackStore(root)
        keys = [_key(rng), _key(rng)]
        newest = {}
        for key in keys:
            for i in range(n):
                ts = float(i)
                store.add(key, float(rng.integers(1, 100)) / 10.0, 1e9, ts=ts)
                newest[key] = ts
        store.compact(max_per_key=cap)
        for key in keys:
            obs = store.get(key)
            assert len(obs) == min(cap, n)
            assert obs[-1].ts == newest[key]       # newest always survives
        # TTL that covers the newest observation also keeps it
        store.compact(max_age_s=time.time())       # everything is younger
        for key in keys:
            assert store.get(key)[-1].ts == newest[key]


# -- corrupt injection never raises -------------------------------------------


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 6))
def test_corrupt_injection_never_raises(seed, n_garbage):
    """Random garbage — overwritten entries, foreign junk files, binary
    noise — must never raise from any read, merge, or compact path."""
    rng = np.random.default_rng(seed)
    garbage = [bytes(rng.integers(0, 256, size=int(rng.integers(0, 200)),
                                  dtype=np.uint8)) for _ in range(n_garbage)]
    import tempfile
    with tempfile.TemporaryDirectory() as root:
        ts, fb = TraceStore(root + "/t"), FeedbackStore(root + "/f")
        key = _key(rng)
        ts.put(key, _rand_record(rng))
        fb.add(key, 1.0, 1e9, ts=1.0)
        # overwrite the real entries with noise + drop foreign junk files
        for store, path in ((ts, ts.path_for(key)), (fb, fb.path_for(key))):
            with open(path, "wb") as f:
                f.write(garbage[0] if garbage else b"")
            for i, blob in enumerate(garbage):
                with open(os.path.join(store.root,
                                       f"{store.FILE_PREFIX}junk{i}.json"),
                          "wb") as f:
                    f.write(blob)
        assert ts.get(key) is None
        assert fb.get(key) == []
        assert list(ts.keys()) == [] and fb.keys() == []
        assert fb.total(rescan=True) == 0 and len(ts._files()) >= 1
        sink_t, sink_f = TraceStore(root + "/st"), FeedbackStore(root + "/sf")
        assert sink_t.merge(ts) == 0 and sink_f.merge(fb) == 0
        ts.compact(), fb.compact()
        # compaction physically reclaimed the junk: a fresh instance
        # scans the directory without finding a single corrupt record
        rescan = TraceStore(root + "/t")
        assert rescan.raw_snapshot() == {} and rescan.stats.corrupt == 0
        # a fresh put/add repairs each store
        ts.put(key, _rand_record(rng))
        fb.add(key, 2.0, 1e9, ts=2.0)
        assert ts.get(key) is not None and len(fb.get(key)) == 1


# -- the base is reusable for new stores --------------------------------------


class _TagStore(JsonFileStore):
    """Minimal subclass: value = {tag: count}, merge = max-count union."""

    FILE_PREFIX = "tag_"
    VALUE_FIELD = "tags"

    def _check_raw(self, raw):
        if not isinstance(raw, dict):
            raise ValueError("missing tag map")
        return raw

    def _merge_raw(self, mine, theirs):
        merged = dict(mine or {})
        n_new = 0
        for tag, count in theirs.items():
            if int(merged.get(tag, -1)) < int(count):
                merged[tag] = int(count)
                n_new += 1
        return merged, n_new


def test_base_supports_new_store_kinds(tmp_path):
    a, b = _TagStore(str(tmp_path / "a")), _TagStore(str(tmp_path / "b"))
    key = ("dd" * 8, 2, 32)
    a.put_raw(key, {"x": 1, "y": 5})
    b.put_raw(key, {"x": 3, "z": 2})
    m1, m2 = _TagStore(str(tmp_path / "m1")), _TagStore(str(tmp_path / "m2"))
    m1.merge(a), m1.merge(b)
    m2.merge(b), m2.merge(a)
    assert m1.raw_snapshot() == m2.raw_snapshot() \
        == {key: {"x": 3, "y": 5, "z": 2}}
    assert m1.merge(a) == 0
    # shares the fleet-wide schema version and skip semantics for free
    assert _TagStore.schema_version == TraceStore.schema_version
    with open(m1.path_for(key), "w") as f:
        f.write("{ not json !!")
    assert m1.get_raw(key) is None


def test_clear_removes_only_own_prefix(tmp_path):
    """Two stores sharing one directory must not clear each other."""
    ts = TraceStore(str(tmp_path))
    fb = FeedbackStore(str(tmp_path))
    key = ("ee" * 8, 2, 32)
    ts.put(key, _record())
    fb.add(key, 1.0, 1e9, ts=1.0)
    assert fb.clear() == 1
    assert ts.get(key) is not None  # trace entry survived feedback clear


def test_merge_raw_contract_is_enforced():
    with pytest.raises(NotImplementedError):
        JsonFileStore.__new__(JsonFileStore)._merge_raw(None, {})


def test_split_serializes_concurrent_writer(tmp_path):
    """Lost-update regression: ``split`` holds the source lock across
    its whole read→merge→unlink sequence, so a ``put_raw`` landing a
    NEWER value mid-migration is serialized behind it and survives on
    the source instead of being unlinked unseen."""
    entered, resume = threading.Event(), threading.Event()

    class _GatedDest(_TagStore):
        # the destination merge is the middle of split's window: gate it
        # open so a writer can try to race the source while we're inside
        def _merge_raw(self, mine, theirs):
            entered.set()
            assert resume.wait(10)
            return super()._merge_raw(mine, theirs)

    src = _TagStore(str(tmp_path / "src"))
    dst = _GatedDest(str(tmp_path / "dst"))
    key = ("ff" * 8, 2, 32)
    src.put_raw(key, {"old": 1})

    splitter = threading.Thread(target=lambda: src.split([key], into=dst))
    splitter.start()
    assert entered.wait(10)        # split read {"old": 1}, merge in flight
    writer = threading.Thread(
        target=lambda: src.put_raw(key, {"old": 1, "new": 1}))
    writer.start()
    writer.join(0.3)
    assert writer.is_alive()       # serialized behind the migration window
    resume.set()
    splitter.join(10), writer.join(10)
    assert not splitter.is_alive() and not writer.is_alive()
    # the concurrent write landed AFTER the unlink: nothing lost
    assert src.get_raw(key) == {"old": 1, "new": 1}
    assert dst.get_raw(key) == {"old": 1}  # migrated snapshot


# -- compact under live readers, once per subclass level ----------------------


def test_feedback_compact_is_safe_under_concurrent_readers(tmp_path):
    """FeedbackStore's finer-grained compact (within-file pruning via
    ``put_raw`` rewrites, not just unlinks) under hammering readers:
    every ``get`` sees a validated observation list or nothing — never
    a torn or half-pruned file."""
    store = FeedbackStore(str(tmp_path))
    keys = [("ab" * 8, batch, 32) for batch in range(2, 18, 2)]
    for key in keys:
        for ts in (1.0, 2.0, 3.0, 4.0):
            store.add(key, time_s=ts, mem_bytes=1e6 * ts, ts=ts)
    reader = FeedbackStore(str(tmp_path))         # separate stats/lock
    stop = threading.Event()
    errors = []

    def hammer():
        while not stop.is_set():
            for key in keys:
                try:
                    for obs in reader.get(key):   # validated or absent
                        assert obs.time_s > 0 and obs.mem_bytes > 0
                except Exception as e:  # pragma: no cover
                    errors.append(e)
                    return

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    for cap in (3, 2, 1):
        store.compact(max_per_key=cap)
    stop.set()
    for t in threads:
        t.join(10)
    assert not errors
    # newest observation per key survives the whole ladder
    for key in keys:
        remaining = store.get(key)
        assert len(remaining) == 1
        assert remaining[0].ts == 4.0


def test_base_compact_is_safe_under_concurrent_readers(tmp_path):
    """The shared ``JsonFileStore.compact`` ladder at the bare-base
    level (``_TagStore``): unlink-only compaction never tears a
    concurrent ``get_raw``."""
    store = _TagStore(str(tmp_path))
    keys = [("cd" * 8, batch, 32) for batch in range(2, 34, 2)]
    for n, key in enumerate(keys):
        store.put_raw(key, {"tag": n + 1})
    reader = _TagStore(str(tmp_path))             # separate stats/lock
    stop = threading.Event()
    errors = []

    def hammer():
        while not stop.is_set():
            for key in keys:
                try:
                    raw = reader.get_raw(key)     # dict or None, never torn
                    assert raw is None or int(raw["tag"]) > 0
                except Exception as e:  # pragma: no cover
                    errors.append(e)
                    return

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    for cap in (12, 6, 2, 0):
        store.compact(max_entries=cap)
    stop.set()
    for t in threads:
        t.join(10)
    assert not errors
    assert len(store) == 0
