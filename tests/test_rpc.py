"""RPC transport: frame protocol, config codec, remote replicas, healing.

Process-separated tests spawn real ``python -m repro.serve.rpc``
children over a RandomForest-backed predictor: tree predictions are
per-row exact (no BLAS micro-batch-composition wobble in the last ULP),
so an RPC fleet's verdicts must match an in-process fleet byte-for-byte
at repo parity precision — ``time_s`` at 1e-12, ``memory_bytes`` at
1e-6. The chaos test kills one replica with SIGKILL under concurrent
load and asserts the frontend's full healing story: every in-flight
Future resolves, the dead member is reshard-excluded, and warm keys are
served from the migrated on-disk slice with zero re-traces.
"""

import dataclasses
import json
import os
import socket
import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.core.automl.models import RandomForestRegressor
from repro.core.predictor import DNNAbacus
from repro.serve import rpc
from repro.serve.cluster import ClusterFrontend, GatewayReplica
from repro.serve.prediction_service import config_fingerprint
from repro.serve.refit import ModelGeneration
from repro.serve.rpc import (WireConfig, decode_config, encode_config,
                             pack_frame, read_frame_sock, shutdown_fleet,
                             spawn_fleet, synthetic_trace)
from repro.serve.server import ServerStats

from test_prediction_service import _fake_cfg, _records


def _rf_abacus(seed=0):
    """RandomForest-backed predictor: per-row exact predictions, so
    verdicts are independent of how queries split into micro-batches —
    the property the byte-for-byte RPC parity assertions need."""
    fac = lambda s: [RandomForestRegressor(n_trees=10, seed=s)]
    return DNNAbacus(seed=seed).fit(_records(seed=seed),
                                    candidate_factory=fac)


def _verdict(est):
    """Parity tuple at repo precision (time @1e-12, mem @1e-6)."""
    return (est["model"], round(est["time_s"], 12),
            round(est["memory_bytes"], 6), est["admitted"],
            est["generation"])


def _cfgs(n):
    return [_fake_cfg(f"job{i:04d}") for i in range(n)]


@pytest.fixture(scope="module")
def rf_setup(tmp_path_factory):
    root = tmp_path_factory.mktemp("rpc")
    ab = _rf_abacus()
    path = str(root / "predictor")
    ab.save(path)
    return ab, path, str(root)


@pytest.fixture(scope="module")
def pair_fleet(rf_setup):
    """Two spawned replicas shared by the interface tests (the chaos
    test spawns its own disposable fleet)."""
    ab, path, root = rf_setup
    fleet = spawn_fleet(2, path, os.path.join(root, "pair"),
                        tracer="repro.serve.rpc:synthetic_trace")
    yield ab, fleet
    shutdown_fleet(fleet)


# -- frame protocol ----------------------------------------------------------


def test_frame_roundtrip_and_pipelining():
    a, b = socket.socketpair()
    try:
        msg = {"id": 1, "op": "ping", "params": {"deep": [1, 2.5, "x"]}}
        a.sendall(pack_frame(msg))
        assert read_frame_sock(b) == msg
        # pipelined frames parse one at a time, in order
        a.sendall(pack_frame({"id": 2}) + pack_frame({"id": 3}))
        assert read_frame_sock(b)["id"] == 2
        assert read_frame_sock(b)["id"] == 3
        a.close()
        assert read_frame_sock(b) is None  # clean EOF, not an exception
    finally:
        b.close()


def test_frame_oversize_rejected_both_directions():
    with pytest.raises(ValueError, match="exceeds MAX_FRAME"):
        pack_frame({"blob": "x" * rpc.MAX_FRAME})
    a, b = socket.socketpair()
    try:
        # a hostile/corrupt length header must be refused before any
        # attempt to allocate/read the payload
        a.sendall((rpc.MAX_FRAME + 1).to_bytes(4, "big"))
        with pytest.raises(ValueError, match="exceeds MAX_FRAME"):
            read_frame_sock(b)
    finally:
        a.close(), b.close()


# -- config codec ------------------------------------------------------------


def test_config_codec_roundtrips_tuples_dicts_and_fingerprint():
    cfg = _fake_cfg("wire")
    cfg.shape = (3, (4, 5))        # nested tuples must survive JSON
    cfg.opts = {"lr": 1e-3, "warmup": (1, 2), "tags": ["a", "b"]}
    dec = decode_config(json.loads(json.dumps(encode_config(cfg))))
    assert isinstance(dec, WireConfig)
    assert dec.shape == (3, (4, 5))
    assert dec.opts == {"lr": 1e-3, "warmup": (1, 2), "tags": ["a", "b"]}
    # fingerprints key the TraceStore: the decoded duck must hash the
    # same or every remote trace would land under a foreign key
    assert config_fingerprint(dec) == config_fingerprint(cfg)


@dataclasses.dataclass
class _DcCfg:
    name: str = "dc"
    family: str = "dense"
    num_layers: int = 3
    d_model: int = 32
    widen: tuple = (1, 2)


def test_config_codec_dataclass_roundtrip():
    cfg = _DcCfg()
    dec = decode_config(json.loads(json.dumps(encode_config(cfg))))
    assert isinstance(dec, _DcCfg) and dec == cfg
    assert config_fingerprint(dec) == config_fingerprint(cfg)


def test_config_codec_rejects_unserializable():
    cfg = _fake_cfg("bad")
    cfg.blob = object()
    with pytest.raises(TypeError, match="not wire-serializable"):
        encode_config(cfg)
    cfg2 = _fake_cfg("badkeys")
    cfg2.table = {1: "x"}
    with pytest.raises(TypeError, match="str keys"):
        encode_config(cfg2)


def test_synthetic_trace_is_deterministic():
    a = synthetic_trace(_fake_cfg("det"), 4, 32)
    b = synthetic_trace(_fake_cfg("det"), 4, 32)
    assert a == b  # byte-identical across calls (and thus processes)
    assert synthetic_trace(_fake_cfg("other"), 4, 32) != a


# -- remote replica interface ------------------------------------------------


def test_remote_fleet_matches_in_process_byte_for_byte(pair_fleet):
    ab, fleet = pair_fleet
    queries = [(cfg, 2 + 2 * (i % 2), 32) for i, cfg in enumerate(_cfgs(12))]
    remote_fe = ClusterFrontend(replicas=fleet)
    remote_fe.start()
    got = [_verdict(e) for e in remote_fe.predict_many(queries, timeout=60)]
    with ClusterFrontend(ab, n_replicas=2, tracer=synthetic_trace) as local:
        want = [_verdict(e) for e in local.predict_many(queries, timeout=60)]
    assert got == want
    # repeat queries hit the remote caches: still identical
    again = [_verdict(e) for e in remote_fe.predict_many(queries, timeout=60)]
    assert again == want


def test_remote_stats_attribute_and_callable_views(pair_fleet):
    _, fleet = pair_fleet
    rem = fleet[0]
    rem.predict_one(_fake_cfg("statq"), 2, 32, timeout=30)
    # attribute access mirrors ServerStats counters over the wire
    assert rem.stats.ticks >= 1 and rem.stats.completed >= 1
    assert rem.stats.mean_batch > 0
    d = rem.stats.as_dict()
    assert set(ServerStats.COUNTERS) <= set(d)
    # callable view: full stats dict, calibration keys un-stringified
    full = rem.stats()
    assert full["ticks"] == d["ticks"] or full["ticks"] >= d["ticks"]
    assert all(k is None or isinstance(k, int)
               for k in full["calibration"].get("by_generation", {}))
    info = rem.server_info()
    assert info["running"] is True and "queued" in info


def test_remote_stop_and_start_over_the_wire(pair_fleet):
    _, fleet = pair_fleet
    rem = fleet[1]
    assert rem.running
    rem.stop(timeout=10)
    assert not rem.running and not rem.draining
    rem.start()
    assert rem.running
    assert np.isfinite(rem.predict_one(_fake_cfg("restart"), 2, 32,
                                       timeout=30)["time_s"])


def test_remote_observe_lands_in_shared_disk_slice(pair_fleet):
    _, fleet = pair_fleet
    fe = ClusterFrontend(replicas=fleet)
    fe.start()
    cfg = _fake_cfg("observed")
    est = fe.predict_one(cfg, 2, 32, timeout=30)
    before = {r.name: r.feedback.total(rescan=True) for r in fleet}
    fe.observe(cfg, 2, 32, est["time_s"] * 1.1, est["memory_bytes"],
               predicted_time_s=est["time_s"],
               predicted_mem_bytes=est["memory_bytes"],
               generation=est["generation"], job_id="j1")
    # the server process wrote through the SAME directory the stub's
    # local FeedbackStore handle reads: exactly one replica gained one
    after = {r.name: r.feedback.total(rescan=True) for r in fleet}
    gained = {n: after[n] - before[n] for n in after if after[n] != before[n]}
    assert sum(gained.values()) == 1
    # and the owning replica's calibration window saw the completion
    owner = fe.replica_for(config_fingerprint(cfg))
    assert owner.stats()["calibration"]["count"] >= 1


def test_publish_generation_and_snapshot_over_the_wire(pair_fleet):
    ab, fleet = pair_fleet
    rem = fleet[0]
    # snapshot is the serialization seam: byte-identical to the source
    snap, gen0 = rem.service.snapshot()
    assert snap.to_dict() == ab.to_dict()
    gen = ModelGeneration(number=gen0 + 7, abacus=_rf_abacus(seed=1),
                          n_feedback=5, source="test")
    swaps_before = rem.stats.gen_swaps
    assert rem.publish_generation(gen)
    deadline = time.monotonic() + 10
    while rem.service.generation < gen.number:
        assert time.monotonic() < deadline, "generation never adopted"
        time.sleep(0.05)
    assert rem.stats.gen_swaps == swaps_before + 1
    # estimates now stamp the adopted generation
    est = rem.predict_one(_fake_cfg("gen"), 2, 32, timeout=30)
    assert est["generation"] == gen.number
    # a predictor that cannot serialize is refused loudly, not half-sent
    bad = ModelGeneration(number=gen.number + 1, abacus=object())
    with pytest.raises(TypeError, match="to_dict"):
        rem.publish_generation(bad)


# -- chaos: kill -9 under load -----------------------------------------------


def test_killed_replica_is_excluded_and_fleet_heals(rf_setup, tmp_path):
    ab, path, _ = rf_setup
    queries = [(cfg, 2 + 2 * (i % 2), 32) for i, cfg in enumerate(_cfgs(24))]
    with ClusterFrontend(ab, n_replicas=4, tracer=synthetic_trace) as local:
        want = [_verdict(e) for e in local.predict_many(queries, timeout=60)]

    fleet = spawn_fleet(4, path, str(tmp_path),
                        tracer="repro.serve.rpc:synthetic_trace",
                        heartbeat_interval=0.25, heartbeat_misses=2)
    fe = ClusterFrontend(replicas=fleet, hedge_after_s=0.75,
                         reshard_timeout=30)
    try:
        fe.start()
        # warm every key on its owner (traces write through to disk)
        got = [_verdict(e) for e in fe.predict_many(queries, timeout=60)]
        assert got == want  # pre-kill byte-for-byte parity
        victim = fe.replica_for(config_fingerprint(queries[0][0]))
        survivors = [r for r in fleet if r.name != victim.name]

        # concurrent load while the victim dies mid-flight
        futs, flock = [], threading.Lock()
        stop_load = threading.Event()

        def load():
            while not stop_load.is_set():
                for cfg, batch, seq in queries:
                    try:
                        f = fe.submit(cfg, batch, seq)
                    except Exception as e:  # pragma: no cover - must not
                        f = Future()
                        f.set_exception(e)
                    with flock:
                        futs.append(f)
                time.sleep(0.01)

        threads = [threading.Thread(target=load) for _ in range(3)]
        for t in threads:
            t.start()
        time.sleep(0.3)
        victim.kill()  # SIGKILL: no drain, no goodbye
        # auto-exclusion reshards the dead member out
        deadline = time.monotonic() + 20
        while victim.name in fe._by_name:
            assert time.monotonic() < deadline, "victim never excluded"
            time.sleep(0.1)
        time.sleep(0.5)  # a little post-heal load through the new ring
        stop_load.set()
        for t in threads:
            t.join(30)

        # EVERY in-flight future resolves (hedged, retried, or replayed)
        # to the same byte-exact verdicts the in-process fleet produced
        assert len(futs) > 0
        results = [f.result(60) for f in futs]
        want_by_model = {w[0]: w for w in want}  # job names are unique
        assert all(_verdict(r) == want_by_model[r["model"]]
                   for r in results)

        st = fe.stats()
        assert st["reshard"]["exclusions"] == 1
        assert victim.dead and len(fe.replicas) == 3
        assert all(not r.dead for r in survivors)

        # post-heal: warm keys serve from the migrated slices with ZERO
        # re-traces, and verdicts still match the in-process fleet
        cold_before = fe.stats()["fleet"]["cold_traces"]
        healed = [_verdict(e) for e in fe.predict_many(queries, timeout=60)]
        assert healed == want
        assert fe.stats()["fleet"]["cold_traces"] == cold_before
    finally:
        shutdown_fleet(fleet)


# -- hedging -----------------------------------------------------------------


class _StalledReplica:
    """Transport-shaped replica whose submits never resolve: the
    frontend's hedge timer is the only way a query routed here ever
    answers."""

    supports_hedge = True
    running = True
    draining = False

    def __init__(self, name="s0"):
        self.name = name
        self.dead = False
        self.on_dead = None
        self.stats = ServerStats()
        self.feedback = None
        self.service = None
        self.submissions = 0

    def submit(self, cfg, batch, seq, fp=None):
        self.submissions += 1
        return Future()  # black hole

    def submit_many(self, queries):
        return [self.submit(None, 0, 0) for _ in queries]

    def start(self):
        return self

    def stop(self, timeout=None):
        pass


def test_hedge_duplicates_slow_query_to_next_ring_owner():
    stalled = _StalledReplica("s0")
    gw = GatewayReplica("g1", _rf_abacus(), tracer=synthetic_trace)
    fe = ClusterFrontend(replicas=[stalled, gw], hedge_after_s=0.05,
                         auto_exclude=False)
    fe.start()
    try:
        cfg = next(c for c in _cfgs(64)
                   if fe.ring.route(config_fingerprint(c)) == "s0")
        fut = fe.submit(cfg, 2, 32)
        est = fut.result(10)  # resolved by the hedge, not the primary
        assert est["replica"] == "g1" and np.isfinite(est["time_s"])
        assert stalled.submissions == 1  # primary did get the query first
        assert fe.reshard_stats["hedges"] >= 1
        assert fe.reshard_stats["hedge_failures"] == 0
    finally:
        gw.stop()


def test_failed_hedge_counts_as_hedge_failure_not_hedge():
    """Regression: ``hedges`` used to move before the duplicate submit
    was attempted, so a hedge that never reached another replica still
    counted as issued. A fleet of one stalled member makes every hedge
    attempt fail (nothing to duplicate to): the failure must land in
    ``hedge_failures`` and leave ``hedges`` untouched."""
    stalled = _StalledReplica("s0")
    fe = ClusterFrontend(replicas=[stalled], hedge_after_s=0.05,
                         auto_exclude=False)
    fe.start()
    fut = fe.submit(_fake_cfg("hf"), 2, 32)
    deadline = time.monotonic() + 10
    while fe.reshard_stats["hedge_failures"] < 1:
        assert time.monotonic() < deadline, "hedge timer never fired"
        time.sleep(0.02)
    assert fe.reshard_stats["hedges"] == 0
    assert not fut.done()  # the primary still owns the only copy


# -- stale stats fallback ----------------------------------------------------


def test_dead_replica_stats_fallback_is_stamped_stale(rf_setup, tmp_path):
    """A dead member's last-known counters keep serving ``stats()`` but
    must be distinguishable from live data: ``stale``/``dead`` flags,
    an ``as_of_monotonic`` age stamp, and the fleet view lists the
    member under ``stale_replicas``."""
    ab, path, _ = rf_setup
    fleet = spawn_fleet(2, path, str(tmp_path),
                        tracer="repro.serve.rpc:synthetic_trace",
                        heartbeat_interval=0.25, heartbeat_misses=2)
    fe = ClusterFrontend(replicas=fleet, reshard_timeout=30,
                         auto_exclude=False)  # keep the corpse around
    try:
        fe.start()
        fe.predict_many([(cfg, 2, 32) for cfg in _cfgs(4)], timeout=60)
        victim = fleet[0]
        completed_before = victim.stats.completed  # populates the cache
        t_cached = time.monotonic()
        victim.kill()
        deadline = time.monotonic() + 20
        while not victim.dead:
            assert time.monotonic() < deadline, "death never detected"
            time.sleep(0.05)
        d = victim.stats()
        assert d["stale"] is True and d["dead"] is True
        assert d["as_of_monotonic"] <= t_cached
        assert d["completed"] == completed_before  # last words preserved
        st = fe.stats()
        assert victim.name in st["stale_replicas"]
        assert st["fleet"]["completed"] >= completed_before
    finally:
        shutdown_fleet(fleet)
