"""Multi-host fabric: routing stability, single-server parity, generation
distribution, federated feedback merge.

Tier-1: deterministic routing (hash-seed/process stable), N-replica
frontend returning identical estimates to one ``AbacusServer``,
concurrent submit waves, a mid-load ``publish_generation`` never mixing
generations within any replica's tick (deterministic, gated tracer),
and the federated feedback -> central refit -> broadcast loop. Tier-2
(``slow``): a live fleet under sustained concurrent load with repeated
publishes.
"""

import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core.scheduler import Machine
from repro.serve import (AbacusServer, AdmissionController, ClusterFrontend,
                         GatewayReplica, GenerationPublisher, HashRing,
                         ModelGeneration, PredictionService, Query,
                         config_fingerprint)

from test_prediction_service import _abacus, _counting_tracer, _fake_cfg

GIB = 2**30


def _fleet(n, tmp_path=None, calls=None, **kw):
    roots = {}
    if tmp_path is not None:
        roots = {"trace_root": str(tmp_path / "traces"),
                 "feedback_root": str(tmp_path / "feedback")}
    return ClusterFrontend(_abacus(), n_replicas=n,
                           tracer=_counting_tracer(
                               calls if calls is not None else []),
                           **roots, **kw)


def _verdict(est):
    """The comparable core of one estimate (tick/replica stripped)."""
    return (est["model"], round(est["time_s"], 12),
            round(est["memory_bytes"], 6), est["admitted"],
            est["generation"])


def _grid(names="abcdef", batches=(2, 4), seqs=(32, 64)):
    return [(_fake_cfg(n), b, s) for n in names for b in batches
            for s in seqs]


# -- consistent-hash routing --------------------------------------------------


def test_ring_routing_is_deterministic_and_balanced():
    ring = HashRing([f"r{i}" for i in range(4)], vnodes=64)
    keys = [f"{i:032x}" for i in range(256)]
    table = ring.table(keys)
    assert table == ring.table(keys)              # pure function
    counts = {}
    for owner in table.values():
        counts[owner] = counts.get(owner, 0) + 1
    assert set(counts) == {f"r{i}" for i in range(4)}
    # 64 vnodes keep the split sane: no replica starves or hogs
    assert all(256 * 0.05 <= c <= 256 * 0.55 for c in counts.values()), counts


def test_ring_rejects_degenerate_fleets():
    with pytest.raises(ValueError):
        HashRing([])
    with pytest.raises(ValueError):
        HashRing(["r0", "r0"])


def test_routing_is_stable_across_processes_and_hash_seeds():
    """The slice a replica owns must be a pure function of the key: a
    different process with a different PYTHONHASHSEED must produce the
    same routing table (CI re-runs this whole module under two random
    seeds — this test locks the property in-repo as well)."""
    keys = [f"{i:032x}" for i in range(64)]
    here = HashRing(["r0", "r1", "r2"], vnodes=32).table(keys)
    code = """
import json, sys
sys.path.insert(0, {src!r})
from repro.serve.cluster import HashRing
keys = [f"{{i:032x}}" for i in range(64)]
print(json.dumps(HashRing(["r0", "r1", "r2"], vnodes=32).table(keys)))
""".format(src=os.path.join(os.path.dirname(__file__), "..", "src"))
    seed = "123" if os.environ.get("PYTHONHASHSEED") != "123" else "321"
    env = {**os.environ, "PYTHONHASHSEED": seed}
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, check=True)
    assert json.loads(out.stdout.strip()) == here


def test_fingerprint_sharding_keeps_a_model_on_one_replica(tmp_path):
    """Sharding is by config fingerprint, not the full key: every shape
    of one model lands on one replica (cache locality), and the trace
    files land only in that replica's store slice."""
    fleet = _fleet(3, tmp_path)
    with fleet:
        fleet.predict_many(_grid(names="ab"))
    for name in "ab":
        fp = config_fingerprint(_fake_cfg(name))
        owner = fleet.replica_for(fp)
        owned = [k for k in owner.service.store.keys() if k[0] == fp]
        assert len(owned) == 4                    # every (batch, seq) shape
        for replica in fleet.replicas:
            if replica is not owner:
                assert all(k[0] != fp for k in replica.service.store.keys())


# -- acceptance: identical estimates to a single server -----------------------


def test_cluster_matches_single_server_estimates():
    """Deterministic acceptance check: the N-replica frontend returns
    per-query estimates identical to one ``AbacusServer`` over the same
    predictor and tracer — sharding changes where a query runs, never
    what it answers."""
    queries = _grid()
    with AbacusServer(PredictionService(
            _abacus(), tracer=_counting_tracer([]))) as srv:
        base = srv.predict_many(queries)
    for n in (1, 3, 4):
        with _fleet(n) as fleet:
            ests = fleet.predict_many(queries)
        assert [_verdict(e) for e in ests] == [_verdict(b) for b in base]
        assert {e["replica"] for e in ests} <= \
            {r.name for r in fleet.replicas}


def test_concurrent_waves_match_single_server_verdicts():
    """Satellite: concurrent submit waves across replicas produce the
    same verdict multiset as a single-server run."""
    queries = _grid()
    with AbacusServer(PredictionService(
            _abacus(), tracer=_counting_tracer([]))) as srv:
        expected = sorted(_verdict(e) for e in srv.predict_many(queries))
    with _fleet(3) as fleet:
        results, errors = [], []
        lock = threading.Lock()

        def wave(qs):
            try:
                futs = fleet.submit_many(qs)
                got = [f.result(30) for f in futs]
                with lock:
                    results.extend(got)
            except Exception as e:  # pragma: no cover
                errors.append(e)

        waves = [queries[i::4] for i in range(4)]
        threads = [threading.Thread(target=wave, args=(w,)) for w in waves]
        for t in threads:
            t.start()
        for t in threads:
            t.join(30)
    assert not errors
    assert sorted(_verdict(e) for e in results) == expected
    info = fleet.server_info()
    assert info["fleet"]["completed"] == len(queries)
    assert info["fleet"]["failed"] == 0


def test_submit_many_preserves_input_order():
    queries = _grid(names="abc", batches=(2, 4, 8), seqs=(32,))
    with _fleet(3) as fleet:
        ests = [f.result(30) for f in fleet.submit_many(queries)]
    for (cfg, b, s), est in zip(queries, ests):
        assert est["model"] == cfg.name


# -- generation distribution --------------------------------------------------


def test_publish_reaches_every_replica_between_ticks():
    with _fleet(3) as fleet:
        fleet.predict_many(_grid(names="ab", seqs=(32,)))
        gen = ModelGeneration(number=1, abacus=_abacus(seed=5))
        assert fleet.publish_generation(gen)
        ests = fleet.predict_many(_grid(names="ab", seqs=(32,)))
    assert all(e["generation"] == 1 for e in ests)
    assert all(r.service.generation == 1 for r in fleet.replicas)
    assert fleet.stats()["generations"] == [1]
    pub = fleet.publisher.info()
    assert pub["published"] == 1 and pub["deliveries"] == 3
    assert pub["failures"] == 0 and pub["last_generation"] == 1


def test_mid_load_publish_never_mixes_generations_on_any_replica():
    """Acceptance (deterministic): hold a tick open on EVERY replica
    (gated tracer), publish a generation mid-tick, pile on more
    queries, release — no (replica, tick) pair may span two
    generations, every in-flight tick finishes on generation 0, and
    every replica ends on generation 1."""
    n = 3
    fleet = _fleet(n)
    # one config per replica, so one gated trace holds each replica's tick
    owned, i = {}, 0
    while len(owned) < n and i < 200:
        cfg = _fake_cfg(f"g{i}")
        owner = fleet.replica_for(config_fingerprint(cfg)).name
        owned.setdefault(owner, cfg)
        i += 1
    assert len(owned) == n
    base = _counting_tracer([])
    started, release = set(), threading.Event()
    started_lock, all_started = threading.Lock(), threading.Event()

    def gated_tracer(cfg, batch, seq):
        if not release.is_set():
            with started_lock:
                started.add(cfg.name)
                if len(started) >= n:
                    all_started.set()
            release.wait(10)
        return base(cfg, batch, seq)

    for replica in fleet.replicas:
        replica.service._tracer = gated_tracer
    with fleet:
        first = fleet.submit_many([(cfg, 2, 32) for cfg in owned.values()])
        assert all_started.wait(10)           # every replica is mid-tick
        assert fleet.publish_generation(
            ModelGeneration(number=1, abacus=_abacus(seed=5)))
        late = fleet.submit_many([(cfg, b, 32) for cfg in owned.values()
                                  for b in (4, 8)])
        release.set()
        ests = [f.result(30) for f in first + late]
    by_tick = {}
    for e in ests:
        by_tick.setdefault((e["replica"], e["tick"]), set()).add(
            e["generation"])
    assert all(len(gens) == 1 for gens in by_tick.values()), by_tick
    for e in ests[:n]:                        # in-flight ticks: generation 0
        assert e["generation"] == 0
    assert all(r.service.generation == 1 for r in fleet.replicas)
    assert all(r.stats.gen_swaps == 1 for r in fleet.replicas)


# -- federated feedback + central refit ---------------------------------------


def test_observe_routes_to_owning_replica_slice(tmp_path):
    fleet = _fleet(3, tmp_path)
    queries = _grid(names="abcd", seqs=(32,))
    with fleet:
        ests = fleet.predict_many(queries)
        for (cfg, b, s), est in zip(queries, ests):
            fleet.observe(cfg, b, s, est["time_s"] * 2.0,
                          est["memory_bytes"] * 1.5,
                          predicted_time_s=est["time_s"],
                          predicted_mem_bytes=est["memory_bytes"],
                          generation=est["generation"])
    total = 0
    for replica in fleet.replicas:
        for key, obs in replica.feedback.items():
            # every observation sits in the slice that owns its fingerprint
            assert fleet.replica_for(key[0]) is replica
            total += len(obs)
    assert total == len(queries)
    # fleet calibration is the count-weighted merge of replica windows
    cal = fleet.stats()["calibration"]
    assert cal["count"] == len(queries)
    assert cal["time_mre"] == pytest.approx(0.5)   # |p - 2p| / 2p
    assert cal["mem_mre"] == pytest.approx(1 / 3)
    assert fleet.sync_feedback() == len(queries)
    assert fleet.feedback.total() == len(queries)
    assert fleet.sync_feedback() == 0              # merge is idempotent


def test_federated_refit_publishes_to_whole_fleet(tmp_path):
    """The whole loop: drifted completions land in per-replica slices,
    the central refitter consumes their federated merge (resolving
    records from the owning shards), and the new generation reaches
    every replica — whose next predictions track the drift."""
    fleet = _fleet(3, tmp_path)
    refitter = fleet.make_refitter(min_observations=6, min_train_records=4)
    queries = _grid()
    with fleet:
        ests = fleet.predict_many(queries)
        for (cfg, b, s), est in zip(queries, ests):
            fleet.observe(cfg, b, s, est["time_s"] * 3.0,
                          est["memory_bytes"] * 1.5,
                          predicted_time_s=est["time_s"],
                          predicted_mem_bytes=est["memory_bytes"],
                          generation=est["generation"])
        assert refitter.should_refit()        # federated sync armed it
        gen = refitter.refit_now()
        assert gen is not None and gen.number == 1
        assert gen.n_unresolved == 0          # every key resolved cross-shard
        assert gen.n_feedback == len(queries)
        for _ in range(100):                  # swaps land between ticks
            if all(r.service.generation == 1 for r in fleet.replicas):
                break
            time.sleep(0.02)
        assert all(r.service.generation == 1 for r in fleet.replicas)
        post = fleet.predict_many(queries)
    # the fleet now predicts the drifted regime everywhere
    for pre, after in zip(ests, post):
        assert after["generation"] == 1
        assert after["time_s"] > pre["time_s"] * 1.5
    stats = fleet.stats()
    assert stats["refit"]["refits"] == 1
    assert stats["refit"]["synced"] == len(queries)
    assert stats["publisher"]["deliveries"] == 3
    assert stats["generations"] == [1]


def test_admission_controller_works_against_the_fleet(tmp_path):
    """Existing consumers point at a fleet unchanged: the controller's
    predict_many/observe contract is the frontend's API."""
    fleet = _fleet(2, tmp_path)
    machines = [Machine("m1", 1e21), Machine("m2", 1e21)]
    with fleet:
        ctl = AdmissionController(fleet, machines, plan="optimal")
        verdicts = ctl.admit([Query(_fake_cfg(n), b, 32)
                              for n in ("a", "b") for b in (2, 4)])
        assert all(v.admitted for v in verdicts)
        for v in verdicts:
            ctl.report_completion(v.job_id, time_s=v.time_s * 2,
                                  mem_bytes=v.mem_bytes)
    assert ctl.cluster_state()["resident_jobs"] == 0
    assert sum(len(obs) for r in fleet.replicas
               for _, obs in r.feedback.items()) == 4


def test_prebuilt_replicas_and_errors(tmp_path):
    reps = [GatewayReplica(f"n{i}", _abacus(),
                           tracer=_counting_tracer([])) for i in range(2)]
    fleet = ClusterFrontend(replicas=reps)
    with fleet:
        est = fleet.predict_one(_fake_cfg(), 2, 32)
    assert est["replica"] in {"n0", "n1"}
    with pytest.raises(ValueError):
        ClusterFrontend()                     # no abacus, no replicas
    with pytest.raises(ValueError):
        fleet.sync_feedback()                 # no central store configured
    with pytest.raises(ValueError):
        fleet.make_refitter()


def test_publisher_counts_failing_replica_without_losing_broadcast():
    class _Broken:
        def publish_generation(self, gen):
            raise RuntimeError("unreachable host")

    good = GatewayReplica("ok", _abacus(), tracer=_counting_tracer([]))
    pub = GenerationPublisher([good, _Broken()])
    gen = ModelGeneration(number=1, abacus=_abacus(seed=3))
    assert not pub.publish_generation(gen)    # not all delivered...
    assert good.service.generation == 1       # ...but the good host swapped
    info = pub.info()
    assert info["failures"] == 1 and info["deliveries"] == 1


# -- tier-2: live fleet under sustained load ----------------------------------


@pytest.mark.slow
def test_live_fleet_load_publishes_and_verdict_parity():
    """Sustained concurrent submits against a 3-replica fleet while
    generations publish mid-load: no mixed-generation tick anywhere, no
    failures, and the generation-0 verdict set matches a single server."""
    queries = _grid()
    with AbacusServer(PredictionService(
            _abacus(), tracer=_counting_tracer([]))) as srv:
        expected = sorted(_verdict(e) for e in srv.predict_many(queries))
    with _fleet(3) as fleet:
        stop = threading.Event()
        collected, errors = [], []
        lock = threading.Lock()

        def client(share):
            while not stop.is_set():
                try:
                    got = [f.result(60)
                           for f in fleet.submit_many(share)]
                    with lock:
                        collected.extend(got)
                except Exception as e:  # pragma: no cover
                    errors.append(e)
                    return

        threads = [threading.Thread(target=client, args=(queries[i::3],))
                   for i in range(3)]
        for t in threads:
            t.start()
        first = [f.result(60) for f in fleet.submit_many(queries)]
        for number in (1, 2, 3):              # publishes under load
            fleet.publish_generation(
                ModelGeneration(number=number, abacus=_abacus(seed=number)))
            time.sleep(0.05)
        time.sleep(0.2)
        stop.set()
        for t in threads:
            t.join(60)
        assert not errors
        final = fleet.predict_many(queries)
    assert sorted(_verdict(e) for e in first) == expected
    by_tick = {}
    for e in collected + first + final:
        by_tick.setdefault((e["replica"], e["tick"]), set()).add(
            e["generation"])
    assert all(len(gens) == 1 for gens in by_tick.values())
    assert all(e["generation"] == 3 for e in final)
    info = fleet.server_info()
    assert info["fleet"]["failed"] == 0
