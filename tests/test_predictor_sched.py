"""DNNAbacus end-to-end on synthetic profile records + data pipeline."""

import numpy as np
import pytest

from repro.core.automl.models import (GradientBoostingRegressor,
                                      RandomForestRegressor, RidgeRegressor)
from repro.core.features import ProfileRecord, mre
from repro.core.predictor import DNNAbacus


def _synthetic_records(n=120, seed=0):
    """Records whose targets follow a known law of the features + NSM."""
    rng = np.random.default_rng(seed)
    recs = []
    for i in range(n):
        batch = int(rng.choice([8, 16, 32, 64]))
        image = int(rng.choice([24, 32, 48]))
        layers = int(rng.integers(4, 40))
        convs = float(rng.integers(4, 60))
        flops = batch * image ** 2 * convs * 1e6
        time_s = flops / 5e10 * (1 + 0.1 * (batch < 16))
        mem = 1e6 * convs + 4.0 * batch * image * image * 64
        edges = {("conv", "add"): convs, ("add", "max"): convs,
                 ("max", "conv"): convs - 1, ("dot", "add"): 2.0}
        recs.append(ProfileRecord(
            model_name=f"m{i}", family="cnn", batch_size=batch,
            input_size=image, channels=3, learning_rate=0.1, epoch=1,
            optimizer="sgd", layers=layers, flops=flops, params=int(convs * 1e5),
            nsm_edges=edges, time_s=time_s, mem_bytes=mem))
    return recs


def _factory(seed):
    return [RandomForestRegressor(n_trees=25, max_depth=16,
                                  min_samples_leaf=1, seed=seed),
            GradientBoostingRegressor(n_stages=120, seed=seed),
            RidgeRegressor()]


def test_abacus_fit_predict_mre():
    recs = _synthetic_records()
    train, test = recs[:90], recs[90:]
    ab = DNNAbacus().fit(train, candidate_factory=_factory)
    ev = ab.evaluate(test)
    assert ev["time_mre"] < 0.35, ev
    assert ev["mem_mre"] < 0.35, ev


def test_abacus_save_load_roundtrip(tmp_path):
    recs = _synthetic_records(60)
    ab = DNNAbacus().fit(recs, candidate_factory=_factory)
    p = str(tmp_path / "ab")
    ab.save(p)
    ab2 = DNNAbacus.load(p)
    t1, m1 = ab.predict(recs[:5])
    t2, m2 = ab2.predict(recs[:5])
    np.testing.assert_allclose(t1, t2)
    np.testing.assert_allclose(m1, m2)


@pytest.mark.slow  # WL embedding refit is the suite's slowest predictor test
def test_graph_embedding_variant_fits():
    recs = _synthetic_records(60)
    ab = DNNAbacus(representation="ge").fit(recs, candidate_factory=_factory)
    ev = ab.evaluate(recs)
    assert ev["time_mre"] < 0.5


def test_predict_config_runs():
    from repro.configs import get_config, reduced_config
    recs = _synthetic_records(60)
    ab = DNNAbacus().fit(recs, candidate_factory=_factory)
    cfg = reduced_config(get_config("qwen2-0.5b"))
    est = ab.predict_config(cfg, batch=2, seq=32)
    assert est["time_s"] > 0 and est["memory_bytes"] > 0
    assert "hbm_budget" in est


# -- data pipeline -----------------------------------------------------------


def test_synthetic_data_deterministic_in_step():
    from repro.data.pipeline import SyntheticLM
    src = SyntheticLM(1000, 4, 16, seed=3)
    a = src.batch_at(7)
    b = src.batch_at(7)
    c = src.batch_at(8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert (a["tokens"] != c["tokens"]).any()
    assert a["tokens"].max() < 1000
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_loader_resumes_at_step():
    from repro.data.pipeline import ShardedLoader, SyntheticLM
    src = SyntheticLM(1000, 2, 8, seed=1)
    l1 = ShardedLoader(src, None, start_step=5, prefetch=1)
    b1 = next(l1)
    l1.close()
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  src.batch_at(5)["tokens"])
