"""Sharding resolution rules, ZeRO rewriting, HLO analyzer invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.analysis.hlo import analyze_text
from repro.distributed import zero as zero_lib
from repro.distributed.sharding import (DEFAULT_RULES, ShardingRules,
                                        resolve_spec)
from repro.launch.mesh import make_host_mesh


class FakeMesh:
    """Duck-typed mesh: axis names + shape only (resolve_spec needs those)."""

    def __init__(self, shape, names):
        import numpy as _np
        self.axis_names = names
        self.devices = _np.empty(shape)


MESH = FakeMesh((16, 16), ("data", "model"))
MESH3 = FakeMesh((2, 16, 16), ("pod", "data", "model"))
STRICT = ShardingRules()


def test_divisible_dims_shard():
    spec = resolve_spec(("embed", "mlp"), (1024, 4096), MESH, STRICT)
    assert tuple(spec) == (None, "model")


def test_non_divisible_falls_back():
    # 14 heads on 16-way model axis: strict -> replicated
    spec = resolve_spec(("batch", "seq", "heads", "head_dim"),
                        (32, 64, 14, 64), MESH, STRICT)
    assert tuple(spec) == ("data",)  # trailing Nones trimmed


def test_pad_tolerance_admits_40_heads():
    rules = ShardingRules(pad_tolerance=4 / 3)
    spec = resolve_spec(("batch", "seq", "heads", "head_dim"),
                        (256, 64, 40, 64), MESH, rules)
    assert tuple(spec) == ("data", None, "model")
    # but rejects 2 kv heads (waste 8x)
    spec = resolve_spec(("batch", "seq", "kv_heads", "head_dim"),
                        (256, 64, 2, 64), MESH, rules)
    assert tuple(spec) == ("data",)


def test_axis_used_once_first_wins():
    # experts and mlp both map to model; experts (leftmost) wins
    spec = resolve_spec(("experts", "embed", None, "mlp"),
                        (64, 1024, 2, 4096), MESH, STRICT)
    assert tuple(spec) == ("model",)


def test_pod_axis_only_on_multipod():
    spec2 = resolve_spec(("batch", "seq"), (256, 64), MESH, STRICT)
    spec3 = resolve_spec(("batch", "seq"), (256, 64), MESH3, STRICT)
    assert tuple(spec2) == ("data",)
    assert tuple(spec3) == (("pod", "data"),)


def test_batch_of_one_replicates():
    spec = resolve_spec(("batch", "seq"), (1, 2048), MESH, STRICT)
    assert tuple(spec) == ()


def test_zero_axes_add_data_shard():
    axes = {"w": (None, "embed", "mlp")}   # stacked layer param
    shapes = {"w": jax.ShapeDtypeStruct((4, 1024, 4096), jnp.float32)}
    out = zero_lib.zero_axes(axes, shapes, MESH, STRICT)
    # first unsharded, divisible dim gets "zero" (1024 % 16 == 0)
    assert out["w"] == (None, "zero", "mlp")
    zr = zero_lib.zero_rules(STRICT)
    spec = resolve_spec(out["w"], (4, 1024, 4096), MESH, zr)
    assert tuple(spec) == (None, "data", "model")


def test_zero_skips_indivisible():
    axes = {"w": (None, None)}
    shapes = {"w": jax.ShapeDtypeStruct((3, 7), jnp.float32)}
    out = zero_lib.zero_axes(axes, shapes, MESH, STRICT)
    assert out["w"] == (None, None)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4096), st.integers(1, 4096))
def test_property_resolve_never_uneven(d0, d1):
    """Strict rules never emit a spec whose dim is not divisible."""
    spec = resolve_spec(("mlp", "vocab"), (d0, d1), MESH, STRICT)
    sizes = {"data": 16, "model": 16}
    for dim, s in zip((d0, d1), tuple(spec) + (None,) * 2):
        if s is not None:
            n = sizes[s] if isinstance(s, str) else int(
                np.prod([sizes[a] for a in s]))
            assert dim % n == 0


# -- HLO analyzer ------------------------------------------------------------


def test_hlo_analyzer_scan_flops_exact():
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=9)
        return y
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    cost = analyze_text(c.as_text())
    expect = 9 * 2 * 64 ** 3
    assert abs(cost.flops - expect) / expect < 0.02


def test_hlo_analyzer_counts_collectives_with_loop_multiplier():
    """A collective inside a while body counts trip-count times."""
    hlo = """
HloModule m, entry_computation_layout={(f32[8,16]{1,0})->f32[8,16]{1,0}}

%body (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg = (s32[], f32[8,16]{1,0}) parameter(0)
  %iv = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%arg), index=1
  %one = s32[] constant(1)
  %iv2 = s32[] add(%iv, %one)
  %ag = f32[8,16]{1,0} all-gather(%x), dimensions={0}
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%iv2, %ag)
}

%cond (arg2: (s32[], f32[8,16])) -> pred[] {
  %arg2 = (s32[], f32[8,16]{1,0}) parameter(0)
  %iv3 = s32[] get-tuple-element(%arg2), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%iv3, %n), direction=LT
}

ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %tup = (s32[], f32[8,16]{1,0}) tuple(%zero, %p0)
  %w = (s32[], f32[8,16]{1,0}) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
"""
    cost = analyze_text(hlo)
    assert cost.coll_counts == {"all-gather": 7.0}, cost.coll_counts
    assert cost.coll_bytes == 7 * 8 * 16 * 4
