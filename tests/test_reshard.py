"""Elastic fleet resharding: ring diffs, slice migration, chaos/crash.

Tier-1: ``HashRing.diff`` ownership-delta properties (via ``_hypo``,
hypothesis-optional; CI re-runs this module under two random
``PYTHONHASHSEED``s like the other cluster suites), the live drain ->
migrate -> cutover protocol for ``add_replica``/``remove_replica``/
``resize`` under concurrent submit load, corrupt-file chaos injection
into a migrating slice, a crash between migrate and cutover rebuilt
from the on-disk stores, and the ``GenerationPublisher`` mid-publish
membership regression.
"""

import json
import threading
import time

import numpy as np
import pytest

from _hypo import given, settings, st
from repro.serve import (AbacusServer, ClusterFrontend, GatewayReplica,
                         GenerationPublisher, HashRing, ModelGeneration,
                         PredictionService, RingDiff, TraceStore,
                         config_fingerprint)

from test_cluster import _fleet, _grid, _verdict
from test_prediction_service import _abacus, _counting_tracer, _fake_cfg
from test_trace_store import _record


def _keys(n=256):
    return [f"{i:032x}" for i in range(n)]


def _owned_keys(fleet):
    """replica name -> stored trace keys (the on-disk slice)."""
    return {r.name: sorted(r.service.store.keys()) for r in fleet.replicas}


def _assert_slices_owned(fleet):
    """Every stored trace/feedback key sits on the replica that owns it."""
    for r in fleet.replicas:
        if r.service.store is not None:
            for k in r.service.store.keys():
                assert fleet.ring.route(k[0]) == r.name, (r.name, k)
        if r.feedback is not None:
            for k, _ in r.feedback.items():
                assert fleet.ring.route(k[0]) == r.name, ("fb", r.name, k)


# -- HashRing.diff: ownership-delta properties --------------------------------


@settings(max_examples=15)
@given(st.integers(min_value=2, max_value=7),
       st.integers(min_value=0, max_value=3),
       st.integers(min_value=0, max_value=10_000))
def test_diff_partitions_keys_and_never_routes_to_departed(n_old, n_add,
                                                           seed):
    """For ANY membership change: (moved | kept) covers every key with
    no overlap, moves agree with per-ring routing, and no key — moved
    or kept — maps to a departed replica under the new ring."""
    rng = np.random.default_rng(seed)
    old_names = [f"n{i}" for i in range(n_old)]
    removed = list(rng.choice(old_names, size=int(rng.integers(0, n_old)),
                              replace=False))
    new_names = ([n for n in old_names if n not in removed]
                 + [f"a{i}" for i in range(n_add)])
    if not new_names:
        new_names = old_names[:1]
        removed = old_names[1:]
    old = HashRing(old_names, vnodes=32)
    new = HashRing(new_names, vnodes=32)
    diff = HashRing.diff(old, new)
    assert isinstance(diff, RingDiff)
    assert sorted(diff.removed) == sorted(removed)
    assert diff.added == [n for n in new_names if n not in old_names]
    keys = _keys(200)
    moves, kept = diff.moves(keys), diff.kept(keys)
    assert set(moves) | set(kept) == set(keys)          # partition...
    assert not set(moves) & set(kept)                   # ...no overlap
    for k, (src, dst) in moves.items():
        assert src == old.route(k) and dst == new.route(k) and src != dst
        assert src in diff.sources and dst in diff.dests
        assert dst not in removed
    for k in kept:
        owner = new.route(k)
        assert owner == old.route(k) and owner not in removed


@settings(max_examples=8)
@given(st.integers(min_value=2, max_value=10))
def test_diff_single_change_stays_near_the_1_over_n_bound(n):
    """Adding one replica to N moves ~1/(N+1) of the keyspace (vnode
    imbalance bounded at ~2.5x), all of it INTO the joiner; removal is
    the exact mirror (same arcs, sources/dests swapped)."""
    old = HashRing([f"r{i}" for i in range(n)], vnodes=64)
    new = HashRing([f"r{i}" for i in range(n + 1)], vnodes=64)
    grow = HashRing.diff(old, new)
    ideal = 1.0 / (n + 1)
    assert 0.0 < grow.moved_fraction <= 2.5 * ideal, grow.moved_fraction
    assert grow.dests == {f"r{n}"} and grow.sources <= set(old.names)
    shrink = HashRing.diff(new, old)
    assert shrink.moved_fraction == pytest.approx(grow.moved_fraction)
    assert shrink.sources == {f"r{n}"} and shrink.dests <= set(old.names)


def test_diff_moved_fraction_matches_sampled_keys():
    """The arc-sweep keyspace measure agrees with brute-force routing
    of a large key sample (the measure is exact; sampling wobbles)."""
    diff = HashRing.diff(HashRing([f"r{i}" for i in range(4)]),
                         HashRing([f"r{i}" for i in range(8)]))
    keys = _keys(4096)
    sampled = len(diff.moves(keys)) / len(keys)
    assert abs(sampled - diff.moved_fraction) < 0.05
    assert diff.moved_fraction < 0.60                  # vs naive 100%


def test_diff_identical_rings_move_nothing():
    ring = HashRing(["a", "b", "c"])
    diff = HashRing.diff(ring, HashRing(["a", "b", "c"]))
    assert diff.moved_fraction == 0.0
    assert not diff.sources and not diff.dests
    assert diff.moves(_keys(64)) == {}


# -- kvstore slice handoff ----------------------------------------------------


def test_split_moves_exact_slice_and_skips_damage(tmp_path):
    """``split`` hands exactly the requested keys to the destination
    through the merge contract; corrupt/foreign/missing files are
    skipped (counted), left in place, and never raise."""
    src = TraceStore(str(tmp_path / "src"))
    dst = TraceStore(str(tmp_path / "dst"))
    keys = [("aa" * 8, 2, 32), ("bb" * 8, 4, 32), ("cc" * 8, 2, 64)]
    for k in keys:
        src.put(k, _record(batch=k[1], seq=k[2]))
    with open(src.path_for(keys[0]), "w") as f:
        f.write("{not json")                       # unparseable
    with open(src.path_for(keys[1])) as f:
        payload = json.load(f)
    payload["version"] = 99                        # foreign schema
    with open(src.path_for(keys[1]), "w") as f:
        json.dump(payload, f)
    res = src.split(keys + [("dd" * 8, 2, 32)], dst)   # + a missing key
    assert res == {"moved": 1, "units": 1, "skipped": 3}
    assert list(dst.keys()) == [keys[2]]
    assert dst.get(keys[2]) is not None
    assert src.stats.corrupt >= 2
    assert src.get(keys[2]) is None                # healthy key moved out
    # extract mirrors the same skip semantics, read-only
    assert list(src.extract(keys)) == []
    assert list(dst.extract(keys)) == [keys[2]]


def test_split_converges_when_destination_raced_ahead(tmp_path):
    """A destination that already traced a moved key (cold query racing
    the migration) converges through ``_merge_raw`` — one deterministic
    winner, no duplicate, no error."""
    src = TraceStore(str(tmp_path / "src"))
    dst = TraceStore(str(tmp_path / "dst"))
    key = ("ee" * 8, 2, 32)
    src.put(key, _record("same", batch=2, seq=32))
    dst.put(key, _record("same", batch=2, seq=32))
    assert src.split([key], dst) == {"moved": 1, "units": 0, "skipped": 0}
    assert dst.get(key) is not None and src.get(key) is None


# -- live resharding: grow ----------------------------------------------------


def test_add_replica_migrates_exactly_the_moved_slice(tmp_path):
    fleet = _fleet(3, tmp_path)
    queries = _grid()
    with fleet:
        pre = fleet.predict_many(queries)
        stored = _owned_keys(fleet)
        old_ring = fleet.ring
        mig = fleet.add_replica("r3")
        expected = {k for ks in stored.values() for k in ks
                    if fleet.ring.route(k[0]) != old_ring.route(k[0])}
        assert mig["trace_keys_moved"] == len(expected)
        assert set(fleet._by_name["r3"].service.store.keys()) == {
            k for k in expected if fleet.ring.route(k[0]) == "r3"}
        _assert_slices_owned(fleet)
        post = fleet.predict_many(queries)
    assert [_verdict(e) for e in pre] == [_verdict(e) for e in post]
    assert [r.name for r in fleet.replicas] == ["r0", "r1", "r2", "r3"]
    assert fleet.ring.names == ["r0", "r1", "r2", "r3"]
    assert fleet.stats()["reshard"]["reshards"] == 1


def test_resize_grow_under_concurrent_load_resolves_every_future(tmp_path):
    """Acceptance: live 4 -> 8 resize under concurrent submit load —
    every in-flight Future resolves, zero failures, and post-reshard
    estimates are identical to a fresh single ``AbacusServer``."""
    queries = _grid()
    with AbacusServer(PredictionService(
            _abacus(), tracer=_counting_tracer([]))) as srv:
        expected = sorted(_verdict(e) for e in srv.predict_many(queries))
    fleet = _fleet(4, tmp_path)
    with fleet:
        fleet.predict_many(queries)
        stop, errors, collected = threading.Event(), [], []
        lock = threading.Lock()

        def client(share):
            while not stop.is_set():
                try:
                    got = [f.result(30) for f in fleet.submit_many(share)]
                    with lock:
                        collected.extend(got)
                except Exception as e:  # pragma: no cover
                    errors.append(e)
                    return

        threads = [threading.Thread(target=client, args=(queries[i::3],))
                   for i in range(3)]
        for t in threads:
            t.start()
        mig = fleet.resize(8)
        time.sleep(0.1)
        stop.set()
        for t in threads:
            t.join(30)
        assert not errors
        post = fleet.predict_many(queries)
    assert len(fleet.replicas) == 8
    assert sorted(_verdict(e) for e in post) == expected
    for est in collected:                     # racing waves also resolved
        assert _verdict(est) in expected
    assert fleet.server_info()["fleet"]["failed"] == 0
    assert mig["moved_fraction_bound"] < 0.60
    _assert_slices_owned(fleet)


# -- live resharding: shrink --------------------------------------------------


def test_remove_replicas_under_load_conserves_slices_and_feedback(tmp_path):
    """Acceptance: live 8 -> 4 via ``remove_replica`` under concurrent
    load — every Future resolves, estimates match a fresh single
    server, and every trace/observation survives on its new owner."""
    # 12 fingerprints: the retiring replicas (r4..r7) own several, so
    # the shrink genuinely migrates slices (SHA-256 routing is fixed)
    queries = _grid(names="abcdefghijkl", seqs=(32,))
    with AbacusServer(PredictionService(
            _abacus(), tracer=_counting_tracer([]))) as srv:
        expected = sorted(_verdict(e) for e in srv.predict_many(queries))
    fleet = _fleet(8, tmp_path)
    with fleet:
        ests = fleet.predict_many(queries)
        for (cfg, b, s), est in zip(queries, ests):
            fleet.observe(cfg, b, s, est["time_s"] * 2.0,
                          est["memory_bytes"] * 1.5,
                          predicted_time_s=est["time_s"],
                          predicted_mem_bytes=est["memory_bytes"])
        stop, errors = threading.Event(), []

        def client(share):
            while not stop.is_set():
                try:
                    for f in fleet.submit_many(share):
                        f.result(30)
                except Exception as e:  # pragma: no cover
                    errors.append(e)
                    return

        threads = [threading.Thread(target=client, args=(queries[i::2],))
                   for i in range(2)]
        for t in threads:
            t.start()
        for name in ("r7", "r6", "r5", "r4"):
            fleet.remove_replica(name)
        stop.set()
        for t in threads:
            t.join(30)
        assert not errors
        post = fleet.predict_many(queries)
    assert [r.name for r in fleet.replicas] == ["r0", "r1", "r2", "r3"]
    assert sorted(_verdict(e) for e in post) == expected
    _assert_slices_owned(fleet)
    # every observation migrated with its slice: none lost, none doubled
    total = sum(len(obs) for r in fleet.replicas
                for _, obs in r.feedback.items())
    assert total == len(queries)
    stats = fleet.stats()["reshard"]
    assert stats["reshards"] == 4 and stats["keys_moved"] > 0


def test_reshard_guards_degenerate_requests(tmp_path):
    fleet = _fleet(2, tmp_path)
    with pytest.raises(ValueError):
        fleet.add_replica("r0")               # duplicate name
    with pytest.raises(ValueError):
        fleet.remove_replica("nope")          # unknown name
    with pytest.raises(ValueError):
        fleet.resize(0)
    fleet.remove_replica("r1")                # offline reshard is fine
    with pytest.raises(ValueError):
        fleet.remove_replica("r0")            # never below one replica


def test_prebuilt_fleet_needs_replica_objects_to_grow():
    reps = [GatewayReplica(f"n{i}", _abacus(), tracer=_counting_tracer([]))
            for i in range(2)]
    fleet = ClusterFrontend(replicas=reps)
    with pytest.raises(ValueError):
        fleet.add_replica("n2")               # no construction recipe
    with fleet:
        fleet.add_replica(GatewayReplica("n2", _abacus(),
                                         tracer=_counting_tracer([])))
        est = fleet.predict_one(_fake_cfg(), 2, 32)
    assert est["replica"] in {"n0", "n1", "n2"}
    assert len(fleet.replicas) == 3


def test_reshard_aborts_cleanly_when_a_drain_times_out(tmp_path):
    """A source replica stuck mid-tick (slow trace) past the reshard
    timeout must ABORT the reshard — membership unchanged, no
    migration under a live writer — and a retry succeeds once the
    worker actually drained. The stuck replica's in-flight Future
    still resolves (the drain serves it)."""
    fleet = _fleet(2, tmp_path)
    fleet.reshard_timeout = 0.3
    gate, entered = threading.Event(), threading.Event()
    base = _counting_tracer([])

    def slow_tracer(cfg, batch, seq):
        entered.set()
        assert gate.wait(30)
        return base(cfg, batch, seq)

    with fleet:
        i, cfg = 0, None
        while cfg is None and i < 200:       # a config r1 owns
            cand = _fake_cfg(f"g{i}")
            if fleet.replica_for(config_fingerprint(cand)).name == "r1":
                cfg = cand
            i += 1
        assert cfg is not None
        stuck = fleet._by_name["r1"]
        stuck.service._tracer = slow_tracer
        fut = fleet.submit(cfg, 2, 32)
        assert entered.wait(10)              # r1 is mid-tick, trace blocked
        with pytest.raises(RuntimeError, match="did not drain"):
            fleet.resize(3)
        assert [r.name for r in fleet.replicas] == ["r0", "r1"]
        assert fleet.stats()["reshard"]["reshards"] == 0
        gate.set()
        assert fut.result(30)["model"] == cfg.name   # drain served it
        for _ in range(200):                 # worker exits after its tick
            if not stuck.draining:
                break
            time.sleep(0.02)
        assert not stuck.draining
        fleet.resize(3)                      # retry now drains instantly
        assert len(fleet.replicas) == 3
        assert fleet.predict_one(cfg, 2, 32)["model"] == cfg.name
    assert fleet.stats()["reshard"]["reshards"] == 1


def test_migrate_failure_restores_service_on_the_old_ring(tmp_path,
                                                          monkeypatch):
    """A migration that fails mid-handoff (e.g. disk full) must restart
    the drained replicas on the OLD ring — their shards keep serving —
    and a retry completes the reshard."""
    from repro.serve.kvstore import KVStoreBase
    fleet = _fleet(3, tmp_path)
    queries = _grid(names="abcdefghijkl", seqs=(32,))
    with fleet:
        pre = fleet.predict_many(queries)

        def boom(self, keys, into):
            raise OSError("disk full")

        # patched on the contract base so it fires on EITHER engine
        monkeypatch.setattr(KVStoreBase, "split", boom)
        with pytest.raises(OSError, match="disk full"):
            fleet.remove_replica("r2")
        assert [r.name for r in fleet.replicas] == ["r0", "r1", "r2"]
        assert all(r.running for r in fleet.replicas)
        mid = fleet.predict_many(queries)      # old ring still serves
        monkeypatch.undo()
        fleet.remove_replica("r2")             # retry completes
        assert [r.name for r in fleet.replicas] == ["r0", "r1"]
        post = fleet.predict_many(queries)
    assert [_verdict(e) for e in pre] == [_verdict(e) for e in mid]
    assert [_verdict(e) for e in pre] == [_verdict(e) for e in post]
    _assert_slices_owned(fleet)


# -- chaos: corrupt files inside a migrating slice ----------------------------


def _corrupt_stored_key(store, key):
    """Engine-agnostic damage: make ``key``'s stored record unloadable
    (unparseable file for the JSON layout, CRC-broken record payload for
    the segment log)."""
    if hasattr(store, "_seg_files"):
        # segment log: zero the first payload bytes of the record
        import os
        store._ensure_fresh()
        name, _no, off, _length, _ts = store._index[key]
        with open(os.path.join(store.root, name), "r+b") as f:
            f.seek(off)
            f.write(b"\x00\x00\x00\x00")
    else:  # file-per-key layout
        with open(store.path_for(key), "w") as f:
            f.write("{torn mid-write")


def _foreign_schema_key(store, key):
    """Engine-agnostic damage: rewrite ``key``'s record under a foreign
    schema version (skipped + counted by either engine)."""
    if hasattr(store, "_seg_files"):
        raw = store.get_raw(key)
        store.schema_version = 99  # instance attr: appends a v99 record
        try:
            store.put_raw(key, raw)
        finally:
            del store.__dict__["schema_version"]
    else:
        path = store.path_for(key)
        with open(path) as f:
            payload = json.load(f)
        payload["version"] = 99
        with open(path, "w") as f:
            json.dump(payload, f)


def test_corrupt_files_in_slice_never_break_migration(tmp_path):
    """Chaos satellite: a slice being handed off contains an
    unparseable file and a foreign-schema file. Migration must
    complete without an exception, every healthy key must arrive at
    its new owner, and only the damaged keys re-trace on demand."""
    calls = []
    fleet = _fleet(3, tmp_path, calls=calls)
    queries = _grid(names="abcdefghij", seqs=(32,))
    with fleet:
        pre = fleet.predict_many(queries)
        victim = max(fleet.replicas,
                     key=lambda r: len(list(r.service.store.keys())))
        vkeys = sorted(victim.service.store.keys())
        assert len(vkeys) >= 2, "grid too small to damage two keys"
        _corrupt_stored_key(victim.service.store, vkeys[0])
        _foreign_schema_key(victim.service.store, vkeys[1])
        healthy = set(vkeys[2:])
        fleet.remove_replica(victim.name)               # must not raise
        assert victim.service.store.stats.corrupt >= 2  # damage was skipped
        # every healthy key arrived at its new owner, loadable
        for key in healthy:
            owner = fleet.replica_for(key[0])
            assert owner.service.store.get(key) is not None, key
        _assert_slices_owned(fleet)
        calls.clear()
        post = fleet.predict_many(queries)
        # only the damaged fingerprints re-trace; nothing healthy does
        damaged_fps = {vkeys[0][0], vkeys[1][0]}
        damaged_names = {q[0].name for q in queries
                         if config_fingerprint(q[0]) in damaged_fps}
        assert {name for name, _, _ in calls} <= damaged_names
    assert [_verdict(e) for e in pre] == [_verdict(e) for e in post]


# -- crash-restart durability -------------------------------------------------


def test_crash_between_migrate_and_cutover_rebuilds_from_disk(tmp_path):
    """Durability satellite: the process dies AFTER slices migrated but
    BEFORE the ring swapped. A fresh frontend over the NEW membership
    must serve identical estimates entirely from the migrated on-disk
    slices — zero re-traces."""
    queries = _grid()
    fleet = _fleet(4, tmp_path)
    with fleet:
        pre = fleet.predict_many(queries)

    def crash(*a, **kw):
        raise RuntimeError("simulated crash before cutover")

    fleet._cutover_swap = crash
    with pytest.raises(RuntimeError, match="simulated crash"):
        fleet.remove_replica("r3")
    del fleet                                  # the process is gone
    calls = []
    rebuilt = _fleet(3, tmp_path, calls=calls)
    with rebuilt:
        post = rebuilt.predict_many(queries)
    assert [_verdict(e) for e in pre] == [_verdict(e) for e in post]
    assert calls == [], "rebuild re-traced: migration was not durable"
    _assert_slices_owned(rebuilt)


# -- publisher / refitter membership ------------------------------------------


def test_publisher_snapshots_membership_per_publish():
    """Regression satellite: a replica added mid-``publish_generation``
    neither corrupts the in-flight broadcast's accounting nor gets a
    retroactive delivery — it catches the next generation."""
    entered, gate = threading.Event(), threading.Event()

    class _Gated:
        def __init__(self):
            self.got = []

        def publish_generation(self, gen):
            entered.set()
            assert gate.wait(10)
            self.got.append(gen.number)

    gated = _Gated()
    pub = GenerationPublisher([gated])
    late = GatewayReplica("late", _abacus(), tracer=_counting_tracer([]))
    result = {}

    def broadcast():
        result["ok"] = pub.publish_generation(
            ModelGeneration(number=1, abacus=_abacus(seed=2)))

    t = threading.Thread(target=broadcast)
    t.start()
    assert entered.wait(10)
    pub.set_replicas([gated, late])            # membership change mid-flight
    gate.set()
    t.join(10)
    assert result["ok"] is True                # complete over its snapshot
    assert gated.got == [1]
    assert late.service.generation == 0        # no retroactive delivery
    info = pub.info()
    assert info["published"] == 1 and info["deliveries"] == 1
    assert info["failures"] == 0 and info["replicas"] == 2
    assert pub.publish_generation(
        ModelGeneration(number=2, abacus=_abacus(seed=3)))
    assert gated.got == [1, 2] and late.service.generation == 2
    assert pub.info()["deliveries"] == 3


def test_reshard_rewires_publisher_refitter_and_seeds_generation(tmp_path):
    """Joiners adopt the fleet's current generation BEFORE serving, and
    the publisher/refitter membership follows the cutover."""
    fleet = _fleet(2, tmp_path)
    refitter = fleet.make_refitter(min_observations=10_000)
    queries = _grid(names="ab", seqs=(32,))
    with fleet:
        fleet.predict_many(queries)
        fleet.publish_generation(
            ModelGeneration(number=3, abacus=_abacus(seed=7)))
        for _ in range(100):                   # swaps land between ticks
            if all(r.service.generation == 3 for r in fleet.replicas):
                break
            time.sleep(0.02)
        assert all(r.service.generation == 3 for r in fleet.replicas)
        fleet.resize(4)
        joiners = [fleet._by_name["r2"], fleet._by_name["r3"]]
        for rep in joiners:
            assert rep.service.generation == 3  # seeded before serving
        assert fleet.publisher.info()["replicas"] == 4
        assert len(refitter.sources) == 4
        ests = fleet.predict_many(queries)
        assert all(e["generation"] == 3 for e in ests)
        fleet.publish_generation(
            ModelGeneration(number=4, abacus=_abacus(seed=8)))
        for _ in range(100):
            if all(r.service.generation == 4 for r in fleet.replicas):
                break
            time.sleep(0.02)
        assert all(r.service.generation == 4 for r in fleet.replicas)
    assert fleet.publisher.info()["deliveries"] >= 2 + 4
