"""Telemetry plane: metrics registry, merge properties, tracing, events.

Tier-1 coverage for ``repro.obs``: histogram bucket/percentile
exactness, order-independent snapshot merging (property), count-weighted
calibration merge == single-window ground truth (property), Prometheus
rendering, registry-backed ``ServerStats``/``ServiceStats`` byte-compat,
server end-to-end histogram counts, an in-process frontend trace, and
the JSONL event log round trip.
"""

import json
import math
import threading

import numpy as np
import pytest

from repro.obs import events
from repro.obs.metrics import (DEFAULT_BUCKETS, Counter, CounterDict, Gauge,
                               Histogram, MetricsRegistry, merge_snapshots,
                               quantile_from_buckets, render_prometheus)
from repro.obs.tracing import SpanSink, make_span, new_context, new_id
from repro.serve import (AbacusServer, ClusterFrontend, PredictionService,
                         config_fingerprint)
from repro.serve.cluster import merge_calibration
from repro.serve.feedback_store import CalibrationWindow
from repro.serve.prediction_service import ServiceStats
from repro.serve.server import ServerStats

from _hypo import given, settings, st
from test_prediction_service import _abacus, _counting_tracer, _fake_cfg


# -- histogram exactness -----------------------------------------------------


def test_histogram_buckets_are_upper_inclusive():
    h = Histogram("h", buckets=(1.0, 2.0, 4.0))
    h.observe_many([0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 9.0])
    snap = h.snapshot()
    # v <= le[i]: 1.0 lands in the first bucket, 2.0 in the second,
    # 4.0 in the third, 9.0 overflows
    assert snap["counts"] == [2, 2, 2, 1]
    assert snap["count"] == 7
    assert snap["sum"] == pytest.approx(21.0)
    assert snap["min"] == 0.5 and snap["max"] == 9.0


def test_histogram_percentiles_are_exact_nearest_rank():
    h = Histogram("h")
    h.observe_many(float(i) for i in range(1, 101))  # 1..100
    assert h.percentile(0.50) == 50.0
    assert h.percentile(0.95) == 95.0
    assert h.percentile(0.99) == 99.0
    snap = h.snapshot()
    assert (snap["p50"], snap["p95"], snap["p99"]) == (50.0, 95.0, 99.0)


def test_histogram_deferred_fold_is_invisible_to_readers():
    """Observations buffer until a reader flushes; every read API sees
    the folded totals regardless of FLUSH_AT."""
    h = Histogram("h", buckets=(1.0,))
    h.observe(0.5)
    # pending, not yet folded
    assert h._pending_n == 1 and h.count == 0
    assert h.snapshot()["count"] == 1  # snapshot() flushed
    assert h._pending_n == 0 and h.count == 1
    h.observe_many([0.25] * (h.FLUSH_AT + 1))  # crosses the cap: auto-fold
    assert h._pending_n == 0
    assert h.count == h.FLUSH_AT + 2


def test_histogram_rejects_unsorted_bounds():
    with pytest.raises(ValueError):
        Histogram("h", buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("h", buckets=(1.0, 1.0))


def test_histogram_observe_many_is_thread_safe():
    h = Histogram("h")
    n, workers = 500, 8

    def feed():
        for i in range(n):
            h.observe_many([1e-4, 1e-2])

    threads = [threading.Thread(target=feed) for _ in range(workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert h.snapshot()["count"] == 2 * n * workers


# -- merge properties --------------------------------------------------------


def _snap_from(values, name="lat"):
    reg = MetricsRegistry()
    reg.counter("reqs_total").inc(len(values))
    reg.gauge("depth").set(max(values) if values else 0)
    if values:
        reg.histogram(name).observe_many(float(v) for v in values)
    return reg.snapshot()


@settings(max_examples=20)
@given(st.integers(min_value=0, max_value=40),
       st.integers(min_value=0, max_value=40),
       st.integers(min_value=0, max_value=40))
def test_merge_snapshots_is_order_independent(na, nb, nc):
    """Counters sum, gauges max, buckets add — any replica arrival
    order produces the identical fleet snapshot."""
    parts = [_snap_from(list(range(1, n + 1))) for n in (na, nb, nc)]
    forward = merge_snapshots(parts)
    backward = merge_snapshots(parts[::-1])
    rotated = merge_snapshots(parts[1:] + parts[:1])
    assert forward == backward == rotated
    assert forward["reqs_total"]["value"] == na + nb + nc
    assert forward["depth"]["value"] == max(na, nb, nc)
    if na + nb + nc:
        assert forward["lat"]["count"] == na + nb + nc


@settings(max_examples=20)
@given(st.integers(min_value=1, max_value=60),
       st.integers(min_value=1, max_value=60),
       st.integers(min_value=1, max_value=4))
def test_merge_calibration_equals_single_window(n1, n2, gens):
    """Count-weighted merge of disjoint per-replica windows must equal
    one CalibrationWindow fed every completion."""
    rng = np.random.default_rng(n1 * 1000 + n2 * 10 + gens)
    rows = [(float(rng.uniform(0.5, 2.0)), float(rng.uniform(0.5, 2.0)),
             float(rng.uniform(1e6, 1e9)), float(rng.uniform(1e6, 1e9)),
             int(rng.integers(0, gens)))
            for _ in range(n1 + n2)]
    whole = CalibrationWindow(window=4096)
    part_a, part_b = (CalibrationWindow(window=4096) for _ in range(2))
    for i, row in enumerate(rows):
        whole.observe(*row)
        (part_a if i < n1 else part_b).observe(*row)
    merged = merge_calibration([part_a.metrics(), part_b.metrics()])
    truth = whole.metrics()
    for field in ("count", "time_mre", "mem_mre", "time_drift", "mem_drift"):
        assert merged[field] == pytest.approx(truth[field], rel=1e-9)
    assert set(merged["by_generation"]) == set(truth["by_generation"])
    for gen, grp in truth["by_generation"].items():
        for field in ("count", "time_mre", "mem_mre"):
            assert merged["by_generation"][gen][field] == pytest.approx(
                grp[field], rel=1e-9)


def test_quantile_from_buckets_interpolates_inside_target_bucket():
    le = (1.0, 2.0, 4.0)
    counts = [10, 0, 10, 0]  # 10 in (0,1], 10 in (2,4]
    assert quantile_from_buckets(le, counts, 0.25) == pytest.approx(0.5)
    assert quantile_from_buckets(le, counts, 0.75) == pytest.approx(3.0)
    assert quantile_from_buckets(le, [0, 0, 0, 0], 0.5) is None
    # overflow bucket clamps to hi when given
    assert quantile_from_buckets(le, [0, 0, 0, 4], 0.99, hi=7.0) <= 7.0


# -- registry + rendering ----------------------------------------------------


def test_registry_is_idempotent_by_name_and_type_checked():
    reg = MetricsRegistry()
    assert reg.counter("a_total") is reg.counter("a_total")
    assert reg.histogram("h") is reg.histogram("h")
    with pytest.raises(TypeError):
        reg.gauge("a_total")


def test_registry_snapshot_includes_callback_gauges():
    reg = MetricsRegistry()
    reg.counter("c_total").inc(3)
    reg.register_callback(lambda: {"queue_depth": 7})
    reg.register_callback(lambda: (_ for _ in ()).throw(RuntimeError()))
    snap = reg.snapshot()
    assert snap["c_total"] == {"type": "counter", "value": 3}
    assert snap["queue_depth"] == {"type": "gauge", "value": 7}


def test_disabled_registry_keeps_counters_live():
    """enabled=False is the baseline arm of the overhead gate: counters
    and gauges still work (server logic depends on them); only
    histogram observes are expected to be skipped by callers."""
    reg = MetricsRegistry(enabled=False)
    reg.counter("c_total").inc()
    assert reg.counter("c_total").value == 1
    assert reg.enabled is False


def test_render_prometheus_emits_cumulative_buckets():
    reg = MetricsRegistry()
    reg.counter("reqs_total").inc(5)
    h = reg.histogram("lat", buckets=(1.0, 2.0))
    h.observe_many([0.5, 1.5, 9.0])
    text = render_prometheus(reg.snapshot())
    assert "# TYPE abacus_reqs_total counter" in text
    assert "abacus_reqs_total 5" in text
    assert 'abacus_lat_bucket{le="1.0"} 1' in text
    assert 'abacus_lat_bucket{le="2.0"} 2' in text
    assert 'abacus_lat_bucket{le="+Inf"} 3' in text
    assert "abacus_lat_count 3" in text
    assert text.endswith("\n")


def test_counterdict_keeps_dict_surface():
    reg = MetricsRegistry()
    d = CounterDict(reg, "reshard_", ("hedges", "retries"))
    d["hedges"] += 2
    assert d["hedges"] == 2 and d["retries"] == 0
    assert dict(d.items()) == {"hedges": 2, "retries": 0}
    assert set(d.keys()) == {"hedges", "retries"}
    assert "hedges" in d and len(d) == 2
    assert d.get("nope", -1) == -1
    # the same ints are visible through the registry under metric names
    assert reg.counter("reshard_hedges_total").value == 2


# -- stats byte-compat -------------------------------------------------------


def test_server_stats_is_byte_compatible_with_dataclass():
    s = ServerStats()
    s.ticks += 3
    s.completed += 6
    s.max_batch = 4
    assert list(s.as_dict()) == list(ServerStats.COUNTERS)
    assert s.as_dict()["ticks"] == 3
    assert s.mean_batch == pytest.approx(2.0)
    kw = ServerStats(ticks=2, submitted=5)  # keyword construction
    assert kw.ticks == 2 and kw.submitted == 5
    # registry shares the same underlying int
    assert s.registry.counter("server_ticks_total").value == 3
    assert s.registry.gauge("server_max_batch").value == 4


def test_service_stats_is_byte_compatible_with_dataclass():
    s = ServiceStats()
    s.hits += 2
    s.misses += 1
    d = s.as_dict()
    assert d["hits"] == 2 and d["misses"] == 1
    assert d["queries"] == 3  # derived key preserved
    assert ServiceStats(hits=7).hits == 7
    assert s.registry.counter("service_hits_total").value == 2


# -- server / frontend end-to-end --------------------------------------------


def _server(**kw):
    svc = PredictionService(_abacus(), tracer=_counting_tracer([]))
    return AbacusServer(svc, **kw).start()


def test_server_histograms_count_every_query():
    srv = _server()
    try:
        keys = [(_fake_cfg(n), 2, 32) for n in "abcd"]
        srv.predict_many(keys, 30)
        snap = srv.metrics_snapshot()
        lat = snap["server_query_latency_seconds"]
        assert lat["count"] == 4
        assert snap["server_queue_wait_seconds"]["count"] == 4
        assert snap["server_tick_seconds"]["count"] >= 1
        assert lat["p50"] is not None and lat["p99"] >= lat["p50"]
        # legacy counters and metric series agree
        assert snap["server_completed_total"]["value"] == srv.stats.completed
        assert "abacus_server_query_latency_seconds_count" \
            in srv.metrics_text()
    finally:
        srv.stop()


def test_frontend_trace_covers_submit_route_tick_reply():
    fe = ClusterFrontend(_abacus(), n_replicas=2,
                         tracer=_counting_tracer([])).start()
    try:
        fut = fe.submit(_fake_cfg("t"), 2, 32, trace=True)
        est = fut.result(30)
        assert np.isfinite(est["time_s"])
        assert "_trace" not in est  # shipped spans are stripped client-side
        spans = fe.trace_spans(fut.trace_id)
        names = {s["name"] for s in spans}
        assert {"submit", "route", "queue_wait", "tick_batch",
                "reply"} <= names
        assert {s["trace"] for s in spans} == {fut.trace_id}
        # every span's parent resolves inside the trace (root or a
        # sibling like tick_batch for its phase children)
        ids = {s["span"] for s in spans}
        assert all(s["parent"] in ids
                   for s in spans if s["name"] != "submit")
        json.dumps(spans)  # spans are JSON-safe by construction
    finally:
        fe.stop()


def test_untraced_queries_record_no_spans():
    fe = ClusterFrontend(_abacus(), n_replicas=2,
                         tracer=_counting_tracer([])).start()
    try:
        fe.predict_one(_fake_cfg("u"), 2, 32)
        assert len(fe.span_sink) == 0
    finally:
        fe.stop()


def test_frontend_metrics_snapshot_merges_replicas():
    fe = ClusterFrontend(_abacus(), n_replicas=2,
                         tracer=_counting_tracer([])).start()
    try:
        fe.predict_many([(_fake_cfg(n), 2, 32) for n in "abcdef"], 30)
        snap = fe.metrics_snapshot()
        assert snap["server_completed_total"]["value"] == 6
        assert snap["fleet_replicas"]["value"] == 2
        legacy = fe.stats()
        assert legacy["fleet"]["completed"] == 6  # stats() keys unchanged
        assert "abacus_server_completed_total 6" in fe.metrics_text()
    finally:
        fe.stop()


# -- spans & sink ------------------------------------------------------------


def test_span_sink_filters_and_orders_by_trace():
    sink = SpanSink()
    tc = new_context()
    sink.record(make_span(tc["trace"], "b", 0.1, ts=2.0, parent=tc["span"]))
    sink.record(make_span(tc["trace"], "a", 0.1, ts=1.0, parent=tc["span"]))
    sink.record(make_span(new_id(), "other", 0.1))
    got = sink.for_trace(tc["trace"])
    assert [s["name"] for s in got] == ["a", "b"]
    assert len(sink) == 3
    sink.clear()
    assert len(sink) == 0


def test_make_span_shape():
    s = make_span("t1", "tick_batch", 0.25, parent="p1", replica="r0")
    assert s["trace"] == "t1" and s["parent"] == "p1"
    assert s["dur_s"] == 0.25 and s["attrs"] == {"replica": "r0"}
    assert isinstance(s["pid"], int) and len(s["span"]) == 16


# -- event log ---------------------------------------------------------------


def test_event_log_file_round_trip(tmp_path):
    path = str(tmp_path / "events.jsonl")
    log = events.EventLog(path=path)
    log.emit("gen_swap", generation=3)
    log.emit("exclusion", replica="r1")
    log.close()
    lines = [json.loads(l) for l in open(path)]
    assert [r["event"] for r in lines] == ["gen_swap", "exclusion"]
    assert lines[0]["generation"] == 3 and "ts" in lines[0]
    assert lines[1]["replica"] == "r1" and "pid" in lines[1]
    # ring buffer mirrors the file
    assert [r["event"] for r in log.tail()] == ["gen_swap", "exclusion"]


def test_event_log_append_interleaves_processes(tmp_path):
    """Two EventLog handles on one path append whole lines."""
    path = str(tmp_path / "shared.jsonl")
    a, b = events.EventLog(path=path), events.EventLog(path=path)
    for i in range(20):
        (a if i % 2 else b).emit("tick", i=i)
    a.close(), b.close()
    recs = [json.loads(l) for l in open(path)]
    assert sorted(r["i"] for r in recs) == list(range(20))


def test_gen_swap_emits_event():
    from repro.serve import ModelGeneration
    events.clear()
    srv = _server()
    try:
        srv.publish_generation(ModelGeneration(number=2, abacus=_abacus()))
        srv.predict_one(_fake_cfg("g"), 2, 32)  # swap adopted on a tick
        swaps = [e for e in events.tail() if e["event"] == "gen_swap"]
        assert swaps and swaps[-1]["generation"] == 2
    finally:
        srv.stop()
