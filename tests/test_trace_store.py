"""TraceStore: persistence, corruption tolerance, cross-process warm start."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.features import ProfileRecord
from repro.serve.prediction_service import PredictionService
from repro.serve.trace_store import SCHEMA_VERSION, TraceStore

from test_prediction_service import (_abacus, _counting_tracer, _fake_cfg,
                                     _random_edges)


def _record(name="m0", batch=2, seq=32):
    rng = np.random.default_rng(batch * 1000 + seq)
    return ProfileRecord(
        model_name=name, family="dense", batch_size=batch, input_size=seq,
        channels=16, learning_rate=1e-3, epoch=1, optimizer="adamw",
        layers=4, flops=batch * seq * 1e6, params=10_000,
        nsm_edges=_random_edges(rng, 5), extra={"note": "x"})


# -- raw store ---------------------------------------------------------------


def test_roundtrip_preserves_record(tmp_path):
    store = TraceStore(str(tmp_path))
    key = ("ab" * 8, 2, 32)
    rec = _record()
    store.put(key, rec)
    got = store.get(key)
    assert got == rec  # dataclass equality covers nsm_edges tuple keys
    assert got.nsm_edges == rec.nsm_edges
    assert len(store) == 1 and list(store.keys()) == [key]
    assert store.stats.writes == 1 and store.stats.hits == 1


def test_miss_returns_none_and_counts(tmp_path):
    store = TraceStore(str(tmp_path))
    assert store.get(("cd" * 8, 4, 64)) is None
    assert store.stats.misses == 1 and store.stats.hits == 0


def test_put_leaves_no_temp_files(tmp_path):
    store = TraceStore(str(tmp_path))
    store.put(("ef" * 8, 2, 32), _record())
    assert [n for n in os.listdir(tmp_path) if n.endswith(".tmp")] == []


def test_corrupted_file_is_skipped_not_fatal(tmp_path):
    store = TraceStore(str(tmp_path))
    key = ("11" * 8, 2, 32)
    store.put(key, _record())
    with open(store.path_for(key), "w") as f:
        f.write("{ not json !!")
    assert store.get(key) is None
    assert store.stats.corrupt == 1
    assert list(store.keys()) == []  # inventory skips it too
    # a fresh put repairs the entry
    store.put(key, _record())
    assert store.get(key) is not None


def test_foreign_schema_version_is_skipped(tmp_path):
    store = TraceStore(str(tmp_path))
    key = ("22" * 8, 2, 32)
    store.put(key, _record())
    with open(store.path_for(key)) as f:
        payload = json.load(f)
    payload["version"] = SCHEMA_VERSION + 1
    with open(store.path_for(key), "w") as f:
        json.dump(payload, f)
    assert store.get(key) is None
    assert store.stats.corrupt == 1


def test_key_mismatch_is_skipped(tmp_path):
    store = TraceStore(str(tmp_path))
    key, other = ("33" * 8, 2, 32), ("44" * 8, 8, 64)
    store.put(key, _record())
    os.rename(store.path_for(key), store.path_for(other))
    assert store.get(other) is None  # file's own key disagrees
    assert store.stats.corrupt == 1


def test_clear_removes_files(tmp_path):
    store = TraceStore(str(tmp_path))
    for batch in (2, 4, 8):
        store.put(("55" * 8, batch, 32), _record(batch=batch))
    assert store.clear() == 3
    assert len(store) == 0


# -- compaction + TTL ---------------------------------------------------------


def test_compact_drops_stale_schema_generations(tmp_path):
    store = TraceStore(str(tmp_path))
    keep = ("66" * 8, 2, 32)
    store.put(keep, _record())
    foreign = ("77" * 8, 4, 32)
    store.put(foreign, _record(batch=4))
    with open(store.path_for(foreign)) as f:
        payload = json.load(f)
    payload["version"] = SCHEMA_VERSION + 1
    with open(store.path_for(foreign), "w") as f:
        json.dump(payload, f)
    with open(store.path_for(("88" * 8, 8, 32)), "w") as f:
        f.write("{ not json !!")
    out = store.compact()
    assert out["stale_schema"] == 2 and out["removed"] == 2
    assert out["kept"] == 1
    assert store.get(keep) is not None           # survivor still serves
    assert not os.path.exists(store.path_for(foreign))


def test_compact_ttl_and_entry_cap_keep_newest(tmp_path):
    store = TraceStore(str(tmp_path))
    keys = [("99" * 8, batch, 32) for batch in (2, 4, 8, 16)]
    now = __import__("time").time()
    for i, key in enumerate(keys):
        store.put(key, _record(batch=key[1]))
        # ages: 100s, 70s, 40s, 10s old (oldest first)
        age = 100 - 30 * i
        os.utime(store.path_for(key), (now - age, now - age))
    out = store.compact(max_age_s=80.0)          # TTL: drops only the oldest
    assert out["expired"] == 1 and out["kept"] == 3
    assert store.get(keys[0]) is None and store.get(keys[1]) is not None
    out = store.compact(max_entries=1)           # cap: newest survives
    assert out["over_cap"] == 2 and out["kept"] == 1
    assert store.get(keys[3]) is not None
    assert [store.get(k) for k in keys[:3]] == [None] * 3


def test_compact_is_safe_under_concurrent_readers(tmp_path):
    import threading

    store = TraceStore(str(tmp_path))
    keys = [("aa" * 8, batch, 32) for batch in range(2, 34, 2)]
    for key in keys:
        store.put(key, _record(batch=key[1]))
    reader = TraceStore(str(tmp_path))           # separate stats/lock
    stop = threading.Event()
    errors = []

    def hammer():
        while not stop.is_set():
            for key in keys:
                try:
                    rec = reader.get(key)        # record or None, never torn
                    assert rec is None or rec.batch_size == key[1]
                except Exception as e:  # pragma: no cover
                    errors.append(e)
                    return

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    for cap in (12, 6, 2, 0):
        store.compact(max_entries=cap)
    stop.set()
    for t in threads:
        t.join(10)
    assert not errors
    assert len(store) == 0


# -- store-backed PredictionService ------------------------------------------


def test_trace_writes_through_and_second_service_warm_starts(tmp_path):
    ab = _abacus()
    cfg = _fake_cfg()
    calls1 = []
    svc1 = PredictionService(ab, tracer=_counting_tracer(calls1),
                             store=TraceStore(str(tmp_path)))
    svc1.predict_one(cfg, 2, 32)
    assert len(calls1) == 1 and len(svc1.store) == 1

    # "second process": fresh service, fresh memory cache, same directory
    calls2 = []
    svc2 = PredictionService(ab, tracer=_counting_tracer(calls2),
                             store=TraceStore(str(tmp_path)))
    est = svc2.predict_one(cfg, 2, 32)
    assert calls2 == []  # ZERO trace calls: answered from the store
    assert np.isfinite(est["time_s"])
    info = svc2.cache_info()
    assert info["store_hits"] == 1 and info["traces"] == 0
    assert info["entries"] == 1 and info["store_entries"] == 1


def test_populated_store_from_real_second_process(tmp_path):
    """Acceptance: a process boots against a store another PROCESS filled."""
    code = f"""
import sys
sys.path.insert(0, {repr(os.path.join(os.path.dirname(__file__), "..", "src"))})
sys.path.insert(0, {repr(os.path.dirname(__file__))})
from repro.serve.prediction_service import PredictionService, config_fingerprint
from repro.serve.trace_store import TraceStore
from test_prediction_service import _abacus, _counting_tracer, _fake_cfg
svc = PredictionService(_abacus(), tracer=_counting_tracer([]),
                        store=TraceStore({repr(str(tmp_path))}))
svc.predict_one(_fake_cfg(), 2, 32)
print(config_fingerprint(_fake_cfg()))
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, check=True)
    fp_child = out.stdout.strip().splitlines()[-1]

    calls = []
    svc = PredictionService(_abacus(), tracer=_counting_tracer(calls),
                            store=TraceStore(str(tmp_path)))
    # same content-address in both processes...
    assert svc.cache_key(_fake_cfg(), 2, 32)[0] == fp_child
    # ...so the previously-seen query is answered with zero trace calls
    est = svc.predict_one(_fake_cfg(), 2, 32)
    assert calls == []
    assert np.isfinite(est["time_s"]) and np.isfinite(est["memory_bytes"])


def test_eviction_falls_back_to_store_without_retrace(tmp_path):
    calls = []
    svc = PredictionService(_abacus(), max_cache_entries=1,
                            tracer=_counting_tracer(calls),
                            store=TraceStore(str(tmp_path)))
    cfg = _fake_cfg()
    svc.predict_one(cfg, 2, 32)
    svc.predict_one(cfg, 4, 32)  # evicts (2, 32) from memory
    assert svc.stats.evictions == 1
    svc.predict_one(cfg, 2, 32)  # memory miss -> store hit, NOT a re-trace
    assert len(calls) == 2
    assert svc.stats.store_hits == 1


def test_corrupted_store_entry_re_traces_via_service(tmp_path):
    store = TraceStore(str(tmp_path))
    cfg = _fake_cfg()
    calls1 = []
    svc1 = PredictionService(_abacus(), tracer=_counting_tracer(calls1),
                             store=store)
    svc1.predict_one(cfg, 2, 32)
    key = svc1.cache_key(cfg, 2, 32)
    with open(store.path_for(key), "w") as f:
        f.write("\x00garbage")
    calls2 = []
    svc2 = PredictionService(_abacus(), tracer=_counting_tracer(calls2),
                             store=TraceStore(str(tmp_path)))
    est = svc2.predict_one(cfg, 2, 32)  # skipped, re-traced, re-persisted
    assert len(calls2) == 1 and np.isfinite(est["time_s"])
    assert svc2.store.stats.corrupt == 1
    svc3 = PredictionService(_abacus(), tracer=_counting_tracer([]),
                             store=TraceStore(str(tmp_path)))
    svc3.predict_one(cfg, 2, 32)
    assert svc3.stats.store_hits == 1  # repaired on disk


# -- clear_cache / cache_info satellites -------------------------------------


def test_clear_cache_resets_inflight_and_optionally_stats():
    import threading
    import time as _time

    calls = []
    base = _counting_tracer(calls)
    release = threading.Event()

    def gated_tracer(cfg, batch, seq):
        release.wait(5)
        return base(cfg, batch, seq)

    svc = PredictionService(_abacus(), tracer=gated_tracer)
    cfg = _fake_cfg()
    t = threading.Thread(target=svc.predict_one, args=(cfg, 2, 32))
    t.start()
    for _ in range(100):  # wait until the trace is registered in-flight
        with svc._lock:
            if svc._inflight:
                break
        _time.sleep(0.01)
    svc.clear_cache()  # must wake waiters and forget in-flight state
    with svc._lock:
        assert svc._inflight == {}
    release.set()
    t.join(5)
    assert not t.is_alive()

    assert svc.stats.queries > 0
    svc.clear_cache(reset_stats=True)
    assert svc.stats.as_dict() == {"hits": 0, "misses": 0, "evictions": 0,
                                   "store_hits": 0, "traces": 0,
                                   "store_errors": 0, "est_hits": 0,
                                   "adopts": 0, "queries": 0}
    assert svc.cache_info()["entries"] == 0


def test_cache_info_reports_memory_and_store_distinctly(tmp_path):
    svc = PredictionService(_abacus(), max_cache_entries=1,
                            tracer=_counting_tracer([]),
                            store=TraceStore(str(tmp_path)))
    cfg = _fake_cfg()
    for batch in (2, 4, 8):
        svc.predict_one(cfg, batch, 32)
    info = svc.cache_info()
    assert info["entries"] == 1        # LRU-bounded memory tier
    assert info["store_entries"] == 3  # durable tier keeps everything
    no_store = PredictionService(_abacus(), tracer=_counting_tracer([]))
    assert no_store.cache_info()["store_entries"] == 0
