"""Online refit loop: FeedbackStore, OnlineRefitter, generation hot-swap.

Tier-1: store round-trip/corruption tolerance, refit thresholds,
generation monotonicity, prediction-cache invalidation on swap, the
end-to-end MRE-improves-after-refit demo on synthetic drift, and the
reservation-release regression. Tier-2 (``slow``): a live ``AbacusServer``
driven through feedback -> refit -> hot-swap under concurrent submits
with the real tracer.
"""

import tempfile
import threading
import time

import numpy as np
import pytest

from _hypo import given, settings, st
from repro.core.scheduler import Machine
from repro.serve import (AbacusServer, AdmissionController, FeedbackStore,
                         ModelGeneration, OnlineRefitter,
                         PredictionService, Query, TraceStore)
from repro.serve.feedback_store import CalibrationWindow, observation_id

from test_prediction_service import _abacus, _counting_tracer, _fake_cfg
from test_server import _CountingAbacus

GIB = 2**30
KEY = ("ab" * 8, 2, 32)


# -- FeedbackStore ------------------------------------------------------------


def test_feedback_roundtrip_and_persistence(tmp_path):
    fb = FeedbackStore(str(tmp_path))
    oid = fb.add(KEY, 0.5, 2e9, generation=0, job_id="j#0", ts=10.0)
    fb.add(KEY, 0.7, 3e9, generation=1, job_id="j#1", ts=20.0)
    obs = fb.get(KEY)
    assert [o.time_s for o in obs] == [0.5, 0.7]  # (ts, id) order
    assert obs[0].generation == 0 and obs[0].job_id == "j#0"
    assert fb.total() == 2 and len(fb) == 1
    # a fresh instance over the same directory sees everything
    again = FeedbackStore(str(tmp_path))
    assert again.total() == 2
    assert again.get(KEY)[0].mem_bytes == 2e9
    assert observation_id(KEY, obs[0]) == oid


def test_feedback_duplicate_report_is_idempotent(tmp_path):
    fb = FeedbackStore(str(tmp_path))
    a = fb.add(KEY, 0.5, 2e9, job_id="j#0", ts=10.0)
    b = fb.add(KEY, 0.5, 2e9, job_id="j#0", ts=10.0)  # retried report
    assert a == b and fb.total() == 1
    assert fb.stats.adds == 1 and fb.stats.duplicates == 1
    # a RETRY carries a fresh wall clock: job identity still dedupes it
    c = fb.add(KEY, 0.5, 2e9, job_id="j#0", ts=99.0)
    assert c == a and fb.total() == 1
    # anonymous observations with identical measurements stay distinct
    fb.add(KEY, 0.5, 2e9, ts=10.0)
    fb.add(KEY, 0.5, 2e9, ts=11.0)
    assert fb.total() == 3


def test_feedback_corrupted_file_skipped_and_repaired(tmp_path):
    fb = FeedbackStore(str(tmp_path))
    fb.add(KEY, 0.5, 2e9, ts=1.0)
    with open(fb.path_for(KEY), "w") as f:
        f.write("{ not json !!")
    assert fb.get(KEY) == []          # skipped, not fatal
    assert fb.total() == 0
    assert fb.stats.corrupt >= 1
    fb.add(KEY, 0.6, 2e9, ts=2.0)    # a fresh add repairs the entry
    assert [o.time_s for o in fb.get(KEY)] == [0.6]


def test_feedback_foreign_schema_version_skipped(tmp_path):
    import json

    fb = FeedbackStore(str(tmp_path))
    fb.add(KEY, 0.5, 2e9, ts=1.0)
    with open(fb.path_for(KEY)) as f:
        payload = json.load(f)
    payload["version"] = 99
    with open(fb.path_for(KEY), "w") as f:
        json.dump(payload, f)
    assert fb.get(KEY) == [] and fb.total() == 0
    assert fb.stats.corrupt >= 1


def test_feedback_merge_unions_by_observation_id(tmp_path):
    a = FeedbackStore(str(tmp_path / "a"))
    b = FeedbackStore(str(tmp_path / "b"))
    a.add(KEY, 0.5, 2e9, ts=1.0)
    b.add(KEY, 0.5, 2e9, ts=1.0)     # same content: same id
    b.add(KEY, 0.9, 4e9, ts=2.0)
    other = ("cd" * 8, 4, 64)
    b.add(other, 1.5, 5e9, ts=3.0)
    assert a.merge(b) == 2           # one dup skipped, two imported
    assert a.total() == 3 and set(a.keys()) == {KEY, other}
    assert a.merge(b) == 0           # idempotent


def test_feedback_compact_ttl_and_per_key_cap(tmp_path):
    fb = FeedbackStore(str(tmp_path))
    now = time.time()
    for i in range(6):  # one key, mixed ages
        fb.add(KEY, 0.1 * (i + 1), 1e9, ts=now - 1000 + 100 * i)
    other = ("cd" * 8, 4, 64)
    fb.add(other, 1.0, 1e9, ts=now - 5000)      # whole key expires
    out = fb.compact(max_age_s=950.0, max_per_key=3)
    assert out["expired"] >= 1 and out["kept"] == 3
    kept = fb.get(KEY)
    assert len(kept) == 3                        # newest 3 survive
    assert [round(o.time_s, 1) for o in kept] == [0.4, 0.5, 0.6]
    assert fb.get(other) == []                   # emptied key file removed
    assert fb.total() == 3


# -- property tests (run with or without hypothesis via tests/_hypo.py) ------


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 8))
def test_fingerprint_stable_across_equivalent_spellings(seed, n):
    """Equivalent payload spellings must share one fingerprint.

    Dict insertion order, set construction order, and numpy integer
    scalars (vs python ints) are presentation details, not content.
    """
    from repro.serve.prediction_service import config_fingerprint

    rng = np.random.default_rng(seed)
    items = [(f"k{i}", int(rng.integers(100))) for i in range(n)]
    tags = [f"t{int(v)}" for _, v in items]

    def cfg(table, tag_list, scalar):
        class _C:
            def __init__(self):
                self.name = "prop"
                self.table = dict(table)
                self.tags = set(tag_list)
                self.w = scalar
        return _C()

    base = config_fingerprint(cfg(items, tags, int(items[0][1])))
    assert base == config_fingerprint(
        cfg(list(reversed(items)), list(reversed(tags)),
            np.int64(items[0][1])))
    # different content: different fingerprint
    bumped = [(k, v + 1) for k, v in items]
    assert base != config_fingerprint(cfg(bumped, tags, int(items[0][1])))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 10))
def test_feedback_merge_is_order_independent(seed, n):
    """Any add/merge order converges to the same store contents."""
    rng = np.random.default_rng(seed)
    obs = [(("ff" * 8, int(rng.integers(1, 4)) * 2, 32),
            float(rng.integers(1, 100)) / 10.0,
            float(rng.integers(1, 100)) * 1e6,
            float(i)) for i in range(n)]
    with tempfile.TemporaryDirectory() as root:
        fwd = FeedbackStore(root + "/fwd")
        rev = FeedbackStore(root + "/rev")
        for key, t, m, ts in obs:
            fwd.add(key, t, m, ts=ts)
        for key, t, m, ts in reversed(obs):
            rev.add(key, t, m, ts=ts)
        assert fwd.snapshot() == rev.snapshot()
        # split + cross-merge in both orders: same fixed point
        a1 = FeedbackStore(root + "/a1")
        a2 = FeedbackStore(root + "/a2")
        half = n // 2
        for key, t, m, ts in obs[:half]:
            a1.add(key, t, m, ts=ts)
        for key, t, m, ts in obs[half:]:
            a2.add(key, t, m, ts=ts)
        m1 = FeedbackStore(root + "/m1")
        m2 = FeedbackStore(root + "/m2")
        m1.merge(a1), m1.merge(a2)
        m2.merge(a2), m2.merge(a1)
        assert m1.snapshot() == m2.snapshot() == fwd.snapshot()


# -- calibration window -------------------------------------------------------


def test_calibration_window_mre_and_drift():
    cal = CalibrationWindow(window=8)
    assert cal.metrics()["count"] == 0
    cal.observe(1.0, 2.0, 4e9, 2e9, generation=0)   # under-time, over-mem
    cal.observe(3.0, 3.0, 1e9, 1e9, generation=1)   # perfect
    m = cal.metrics()
    assert m["count"] == 2
    assert m["time_mre"] == pytest.approx(0.25)      # (0.5 + 0) / 2
    assert m["time_drift"] == pytest.approx(-0.25)   # signed: underestimates
    assert m["mem_mre"] == pytest.approx(0.5)
    assert m["by_generation"][0]["time_mre"] == pytest.approx(0.5)
    assert m["by_generation"][1]["time_mre"] == pytest.approx(0.0)
    cal.reset()
    assert cal.metrics()["count"] == 0


# -- refit thresholds + generation lifecycle ---------------------------------


def _svc_with_traced_keys(tmp_path, n_cfgs=4, seeds=(2, 4), seq=32):
    """Service + the (cfg, batch, seq) grid it has already traced."""
    calls = []
    svc = PredictionService(_abacus(), tracer=_counting_tracer(calls),
                            store=TraceStore(str(tmp_path / "traces")))
    grid = [(_fake_cfg(f"c{i}"), b, seq)
            for i in range(n_cfgs) for b in seeds]
    for cfg, b, s in grid:
        svc.predict_one(cfg, b, s)
    return svc, grid, calls


def test_refit_triggers_on_count_threshold(tmp_path):
    svc, grid, _ = _svc_with_traced_keys(tmp_path)
    fb = FeedbackStore(str(tmp_path / "fb"))
    ref = OnlineRefitter(svc, fb, min_observations=3, min_train_records=2)
    for i, (cfg, b, s) in enumerate(grid[:3]):
        assert not ref.should_refit()
        assert ref.refit_now() is None          # below threshold: no-op
        fb.add(svc.cache_key(cfg, b, s), 0.5 + i, 2e9)
        ref.notify()
    assert ref.should_refit()                   # 3rd observation arms it
    gen = ref.refit_now()
    assert gen is not None and gen.number == 1
    assert gen.n_feedback == 3 and gen.n_unresolved == 0
    assert svc.generation == 1                  # default sink: the service
    # watermark: consumed feedback does not re-arm the trigger
    assert ref.fresh_observations() == 0
    assert not ref.should_refit() and ref.refit_now() is None


def test_refit_triggers_on_staleness(tmp_path):
    svc, grid, _ = _svc_with_traced_keys(tmp_path)
    fb = FeedbackStore(str(tmp_path / "fb"))
    ref = OnlineRefitter(svc, fb, min_observations=100,
                         max_staleness_s=0.05, min_train_records=2)
    cfg, b, s = grid[0]
    fb.add(svc.cache_key(cfg, b, s), 0.5, 2e9)
    cfg2, b2, s2 = grid[1]
    fb.add(svc.cache_key(cfg2, b2, s2), 0.7, 3e9)
    ref.notify()
    assert not ref.should_refit()               # fresh but not stale yet
    time.sleep(0.08)
    assert ref.should_refit()                   # stale feedback forces it
    assert ref.refit_now().number == 1


def test_refit_skips_unresolvable_keys(tmp_path):
    svc, grid, _ = _svc_with_traced_keys(tmp_path)
    fb = FeedbackStore(str(tmp_path / "fb"))
    ref = OnlineRefitter(svc, fb, min_observations=1, min_train_records=2)
    # a key the service never traced cannot be joined with features
    fb.add(("never" + "0" * 11, 2, 32), 1.0, 1e9)
    assert ref.refit_now() is None              # nothing resolvable
    for cfg, b, s in grid[:2]:
        fb.add(svc.cache_key(cfg, b, s), 0.5, 2e9)
    gen = ref.refit_now()
    assert gen is not None
    assert gen.n_feedback == 2 and gen.n_unresolved == 1


def test_worker_does_not_spin_on_unresolvable_feedback(tmp_path):
    """A refit attempt that makes no progress (feedback keys with no
    stored trace) must park the worker until the next notify/poll, not
    busy-loop full-store scans while should_refit() stays true."""
    svc = PredictionService(_abacus(), tracer=_counting_tracer([]))
    fb = FeedbackStore(str(tmp_path))
    ref = OnlineRefitter(svc, fb, min_observations=1, min_train_records=1)
    attempts = []
    orig = ref.training_records
    ref.training_records = lambda: (attempts.append(1), orig())[1]
    with ref:
        fb.add(("dead" + "0" * 12, 2, 32), 1.0, 1e9)  # never traced
        ref.notify()
        time.sleep(0.4)
        # parked after the no-progress attempt: fresh feedback exists but
        # retrying without new information is pointless
        assert ref.fresh_observations() == 1
        assert not ref.should_refit()
    assert len(attempts) <= 2            # one gated attempt per wakeup
    # new feedback re-arms the trigger (and notify clears the parking)
    fb.add(("dead" + "0" * 12, 4, 32), 1.0, 1e9)
    ref.notify()
    assert ref.should_refit()


def test_refit_targets_use_newest_observation_window(tmp_path):
    """A second drift must displace the first: targets average only each
    key's newest obs_window observations, not the whole history."""
    svc, grid, _ = _svc_with_traced_keys(tmp_path, n_cfgs=1, seeds=(2,))
    cfg, b, s = grid[0]
    key = svc.cache_key(cfg, b, s)
    fb = FeedbackStore(str(tmp_path / "fb"))
    for i in range(4):                  # old regime: 3x
        fb.add(key, 3.0, 3e9, ts=float(i))
    for i in range(4):                  # reality returned to 1x
        fb.add(key, 1.0, 1e9, ts=float(10 + i))
    ref = OnlineRefitter(svc, fb, obs_window=4, min_train_records=1)
    records, consumed, unresolved = ref.training_records()
    assert consumed == 8 and unresolved == 0
    assert records[-1].time_s == pytest.approx(1.0)   # not a 2x blend
    assert records[-1].mem_bytes == pytest.approx(1e9)


def test_generation_numbers_are_monotone(tmp_path):
    svc, grid, _ = _svc_with_traced_keys(tmp_path)
    fb = FeedbackStore(str(tmp_path / "fb"))
    ref = OnlineRefitter(svc, fb, min_observations=1, min_train_records=2)
    numbers = []
    for i, (cfg, b, s) in enumerate(grid[:3]):
        fb.add(svc.cache_key(cfg, b, s), 0.5 + i, 2e9)
        fb.add(svc.cache_key(grid[3][0], grid[3][1], grid[3][2]),
               1.0 + i, 3e9, ts=float(i))
        gen = ref.refit_now()
        assert gen is not None
        numbers.append(gen.number)
    assert numbers == [1, 2, 3]
    assert svc.generation == 3


def test_adopt_refuses_stale_generation():
    svc = PredictionService(_abacus(), tracer=_counting_tracer([]))
    ab1, ab2 = _abacus(seed=1), _abacus(seed=2)
    assert svc.adopt(ab1, 1)
    assert not svc.adopt(ab2, 1)     # replay of the same number
    assert not svc.adopt(ab2, 0)     # rollback attempt
    assert svc.generation == 1 and svc.abacus is ab1
    assert svc.adopt(ab2)            # unnumbered: next in sequence
    assert svc.generation == 2
    assert svc.publish_generation(ModelGeneration(number=5, abacus=ab1))
    assert svc.generation == 5


def test_swap_invalidates_prediction_cache_not_traces(tmp_path):
    ab = _CountingAbacus(_abacus())
    calls = []
    svc = PredictionService(ab, tracer=_counting_tracer(calls),
                            store=TraceStore(str(tmp_path)))
    cfg = _fake_cfg()
    e1 = svc.predict_one(cfg, 2, 32)
    e2 = svc.predict_one(cfg, 2, 32)
    assert ab.predict_calls == 1                 # second served from est cache
    assert svc.stats.est_hits == 1
    assert e1["generation"] == e2["generation"] == 0
    ab2 = _CountingAbacus(_abacus(seed=3))
    assert svc.adopt(ab2, 1)
    assert svc.cache_info()["est_entries"] == 0  # prediction cache dropped
    e3 = svc.predict_one(cfg, 2, 32)
    assert e3["generation"] == 1
    assert ab2.predict_calls == 1                # new ensembles actually ran
    assert len(calls) == 1                       # trace cache survived intact
    assert len(svc.store) == 1                   # persisted traces untouched
    assert svc.stats.adopts == 1


# -- admission: completion releases reservations + feeds observations ---------


class _ObservingPredictor:
    """predict_many stub that records observe() calls like AbacusServer."""

    def __init__(self, table):
        self.table = table
        self.observed = []

    def predict_many(self, queries):
        return [{"model": q.cfg.name, "generation": 7, **self.table[q.cfg.name]}
                for q in queries]

    def observe(self, cfg, batch, seq, time_s, mem_bytes, **kw):
        self.observed.append((cfg.name, batch, seq, time_s, mem_bytes, kw))


def _est(t, mem_gib):
    return {"time_s": t, "memory_bytes": mem_gib * GIB}


def test_cluster_returns_to_baseline_after_all_jobs_finish():
    pred = _ObservingPredictor({"a": _est(10.0, 4.0), "b": _est(7.0, 2.0)})
    machines = [Machine("m1", 32 * GIB), Machine("m2", 32 * GIB)]
    ctl = AdmissionController(pred, machines, plan="optimal")
    baseline = ctl.cluster_state()
    verdicts = []
    for wave in range(3):
        verdicts += ctl.admit([Query(_fake_cfg("a"), 2, 32),
                               Query(_fake_cfg("b"), 4, 32)])
    assert all(v.admitted for v in verdicts)
    state = ctl.cluster_state()
    assert state["resident_jobs"] == 6 and state["makespan_s"] > 0
    for v in verdicts:  # mixed API: complete() and report_completion()
        if int(v.job_id.split("#")[1]) % 2:
            ctl.complete(v.job_id)
        else:
            ctl.report_completion(v.job_id, time_s=v.time_s * 2,
                                  mem_bytes=v.mem_bytes)
    end = ctl.cluster_state()
    assert end["resident_jobs"] == 0
    for m in end["machines"]:
        assert m["busy_s"] == pytest.approx(0.0, abs=1e-9)
        assert m["reserved_bytes"] == pytest.approx(0.0, abs=1e-3)
        assert m["jobs"] == []
    assert end["makespan_s"] == pytest.approx(baseline["makespan_s"])


def test_report_completion_feeds_observation_with_prediction_context():
    pred = _ObservingPredictor({"a": _est(10.0, 4.0)})
    ctl = AdmissionController(pred, [Machine("m1", 8 * GIB)], plan="optimal")
    v = ctl.admit([Query(_fake_cfg("a"), 2, 32)])[0]
    summary = ctl.report_completion(v.job_id, time_s=30.0, mem_bytes=6 * GIB)
    assert summary["observed"] and summary["generation"] == 7
    name, batch, seq, t, m, kw = pred.observed[0]
    assert (name, batch, seq) == ("a", 2, 32)
    assert t == 30.0 and m == 6 * GIB
    assert kw["predicted_time_s"] == pytest.approx(10.0)
    assert kw["generation"] == 7 and kw["job_id"] == v.job_id
    # completion without measurements releases but does not observe
    v2 = ctl.admit([Query(_fake_cfg("a"), 4, 32)])[0]
    assert not ctl.report_completion(v2.job_id)["observed"]
    assert len(pred.observed) == 1
    # duplicate report (a retried caller): cached summary, no
    # double-release, no second observation
    dup = ctl.report_completion(v.job_id)
    assert dup["job_id"] == v.job_id and dup["observed"]
    assert len(pred.observed) == 1
    assert ctl.cluster_state()["resident_jobs"] == 0
    with pytest.raises(KeyError):
        ctl.report_completion("never-admitted")  # truly unknown job


def test_report_completion_normalizes_verdict_domain_measurements():
    """Measured costs arrive in the verdict domain (x time_scale, + pad)
    and must be mapped back to the predictor's per-step domain before
    feeding calibration/refit — otherwise a perfectly calibrated
    predictor would read as 100x drifted."""
    pred = _ObservingPredictor({"a": _est(10.0, 4.0)})
    ctl = AdmissionController(pred, [Machine("m1", 32 * GIB)],
                              plan="optimal", time_scale=100.0,
                              mem_pad=GIB)
    v = ctl.admit([Query(_fake_cfg("a"), 2, 32)])[0]
    assert v.time_s == pytest.approx(1000.0)     # verdict domain
    assert v.mem_bytes == pytest.approx(5 * GIB)
    # the job measured exactly what the verdict promised: zero drift
    s = ctl.report_completion(v.job_id, time_s=v.time_s,
                              mem_bytes=v.mem_bytes)
    _, _, _, t, m, kw = pred.observed[0]
    assert t == pytest.approx(10.0)              # back in per-step domain
    assert m == pytest.approx(4 * GIB)
    assert s["measured_time_s"] == pytest.approx(10.0)
    assert kw["predicted_time_s"] == pytest.approx(10.0)


def test_admission_rejects_non_assigning_plan():
    with pytest.raises(ValueError, match="assignment"):
        AdmissionController(_ObservingPredictor({}), [Machine("m", GIB)],
                            plan="random")


# -- end-to-end: drifted workload, refit, MRE drops >= 2x ---------------------

TIME_DRIFT, MEM_DRIFT = 3.0, 1.5


def _measure_wave(ctl, queries, truth=None):
    """One wave: admit, 'run', report measured costs.

    The drifted *reality* is fixed on the first wave (generation-0
    predictions scaled by the drift factors) and replayed verbatim on
    later waves — reality does not move when the predictor does.
    """
    verdicts = ctl.admit(queries)
    assert all(v.admitted for v in verdicts)
    if truth is None:
        truth = [(v.time_s * TIME_DRIFT, v.mem_bytes * MEM_DRIFT)
                 for v in verdicts]
    for v, (t, m) in zip(verdicts, truth):
        ctl.report_completion(v.job_id, time_s=t, mem_bytes=m)
    return truth


def test_windowed_mre_halves_after_one_refit_cycle(tmp_path):
    """The ISSUE acceptance demo, deterministic: wave 1 under generation 0
    sees the full drift error; one feedback/refit cycle later, wave 2 under
    generation 1 predicts the drifted regime, and the per-generation
    windowed time-MRE from ``server.stats()`` drops by >= 2x."""
    svc = PredictionService(_abacus(), tracer=_counting_tracer([]),
                            store=TraceStore(str(tmp_path / "traces")))
    fb = FeedbackStore(str(tmp_path / "fb"))
    ref = OnlineRefitter(svc, fb, min_observations=6, min_train_records=4,
                         seed_records=None)
    machines = [Machine("m1", 1e21), Machine("m2", 1e21)]
    queries = [Query(_fake_cfg(n), b, s)
               for n in ("a", "b", "c") for b in (2, 4) for s in (32, 64)]
    with AbacusServer(svc, feedback=fb, refitter=ref) as srv:
        ctl = AdmissionController(srv, machines, plan="optimal")
        truth = _measure_wave(ctl, queries)
        pre = srv.stats()["calibration"]
        assert pre["by_generation"][0]["time_mre"] == pytest.approx(
            (TIME_DRIFT - 1) / TIME_DRIFT)       # |p - 3p| / 3p
        assert pre["time_drift"] < 0             # drift: we underestimate
        gen = ref.refit_now()                    # threshold was crossed
        assert gen is not None and gen.number == 1
        for _ in range(100):                     # swap lands between ticks
            if svc.generation == 1:
                break
            time.sleep(0.02)
        assert svc.generation == 1
        _measure_wave(ctl, queries, truth)
        post = srv.stats()["calibration"]["by_generation"]
    assert srv.stats.gen_swaps == 1              # worker applied it once
    mre0 = post[0]["time_mre"]
    mre1 = post[1]["time_mre"]
    assert mre1 <= mre0 / 2.0, (mre0, mre1)      # acceptance: >= 2x drop
    assert post[1]["mem_mre"] <= post[0]["mem_mre"] / 2.0
    # the refit actually learned the drifted scale, not a constant
    assert srv.stats()["calibration"]["count"] == 2 * len(queries)


def test_warm_tick_skips_ensemble_pass_entirely():
    ab = _CountingAbacus(_abacus())
    svc = PredictionService(ab, tracer=_counting_tracer([]))
    with AbacusServer(svc) as srv:
        first = srv.predict_many([(_fake_cfg(), b, 32) for b in (2, 4)])
        again = srv.predict_many([(_fake_cfg(), b, 32) for b in (2, 4)])
    assert ab.predict_calls == 1        # repeat tick: prediction cache
    assert srv.stats.ensemble_passes == 1
    assert [e["time_s"] for e in first] == [e["time_s"] for e in again]
    assert svc.stats.est_hits >= 2


def test_hot_swap_never_mixes_generations_within_a_tick():
    calls = []
    base = _counting_tracer(calls)
    started, release = threading.Event(), threading.Event()

    def gated_tracer(cfg, batch, seq):
        started.set()
        release.wait(5)
        return base(cfg, batch, seq)

    svc = PredictionService(_abacus(), tracer=gated_tracer)
    with AbacusServer(svc) as srv:
        first = srv.submit_many([(_fake_cfg("a"), b, 32) for b in (2, 4)])
        assert started.wait(5)                   # tick 1 is in flight
        # publish a new generation MID-TICK, then pile on more queries
        assert srv.publish_generation(
            ModelGeneration(number=1, abacus=_abacus(seed=5)))
        late = srv.submit_many([(_fake_cfg("a"), b, 32) for b in (2, 4, 8)])
        release.set()
        ests = [f.result(10) for f in first + late]
    by_tick = {}
    for e in ests:
        by_tick.setdefault(e["tick"], set()).add(e["generation"])
    # no tick mixes generations; the in-flight tick finished on gen 0
    assert all(len(gens) == 1 for gens in by_tick.values()), by_tick
    assert by_tick[1] == {0}
    assert ests[-1]["generation"] == 1           # later ticks swapped
    assert srv.stats.gen_swaps == 1


# -- TraceStore compaction (satellite) is in test_trace_store.py --------------


# -- tier-2: live server, real tracer, concurrent feedback/refit/swap ---------


@pytest.mark.slow
def test_live_server_feedback_refit_hot_swap_under_concurrency():
    """Drive the whole loop with the real jaxpr tracer and a background
    refit worker while client threads keep submitting."""
    from repro.configs import get_config, reduced_config

    cfg = reduced_config(get_config("qwen2-0.5b"))
    with tempfile.TemporaryDirectory() as root:
        svc = PredictionService(_abacus(), store=TraceStore(root + "/traces"))
        fb = FeedbackStore(root + "/fb")
        ref = OnlineRefitter(svc, fb, min_observations=4,
                             min_train_records=3)
        queries = [(cfg, b, s) for b in (2, 4) for s in (32, 64)]
        with ref, AbacusServer(svc, feedback=fb, refitter=ref) as srv:
            ctl = AdmissionController(srv, [Machine("m1", 1e21)],
                                      plan="optimal")
            stop = threading.Event()
            errors = []

            def client():
                while not stop.is_set():
                    try:
                        for f in srv.submit_many(queries):
                            f.result(60)
                    except Exception as e:  # pragma: no cover
                        errors.append(e)
                        return

            threads = [threading.Thread(target=client) for _ in range(3)]
            for t in threads:
                t.start()
            verdicts = ctl.admit([Query(c, b, s) for c, b, s in queries])
            truth = [(v.time_s * 2.5, v.mem_bytes * 1.2) for v in verdicts]
            for v, (mt, mm) in zip(verdicts, truth):
                ctl.report_completion(v.job_id, time_s=mt, mem_bytes=mm)
            deadline = time.time() + 60
            while svc.generation == 0 and time.time() < deadline:
                time.sleep(0.1)
            # keep clients submitting across the swap, then drain
            time.sleep(0.3)
            stop.set()
            for t in threads:
                t.join(60)
            assert not errors
            assert svc.generation >= 1           # background refit landed
            verdicts2 = ctl.admit([Query(c, b, s) for c, b, s in queries])
            for v, (mt, mm) in zip(verdicts2, truth):  # same fixed reality
                ctl.report_completion(v.job_id, time_s=mt, mem_bytes=mm)
            stats = srv.stats()
        by_gen = stats["calibration"]["by_generation"]
        assert 0 in by_gen and max(by_gen) >= 1
        assert by_gen[max(by_gen)]["time_mre"] < by_gen[0]["time_mre"]
        assert stats["refit"]["refits"] >= 1
        assert ctl.cluster_state()["resident_jobs"] == 0
