"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


@pytest.mark.parametrize("b,s,h,hd", [
    (1, 128, 1, 32), (2, 256, 4, 64), (1, 384, 3, 64), (2, 128, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_allclose(b, s, h, hd, dtype, causal):
    q = _rand(1, (b, s, h, hd), dtype)
    k = _rand(2, (b, s, h, hd), dtype)
    v = _rand(3, (b, s, h, hd), dtype)
    o = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, s, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * h, s, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * h, s, hd)
    want = jnp.moveaxis(
        ref.attention_ref(qf, kf, vf, causal).reshape(b, h, s, hd), 1, 2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("b,l,h,p,n,chunk", [
    (1, 64, 2, 16, 8, 16), (2, 128, 4, 32, 16, 32), (1, 96, 1, 64, 32, 32),
])
def test_ssd_scan_allclose(b, l, h, p, n, chunk):
    from repro.models.ssm import ssd_chunked_ref
    xb = _rand(4, (b, l, h, p), jnp.float32) * 0.5
    dt = jax.nn.softplus(_rand(5, (b, l, h), jnp.float32))
    a_neg = -jnp.exp(_rand(6, (h,), jnp.float32) * 0.3)
    bm = _rand(7, (b, l, n), jnp.float32) * 0.5
    cm = _rand(8, (b, l, n), jnp.float32) * 0.5
    y, s_fin = ops.ssd_scan(xb, dt, a_neg, bm, cm, chunk, interpret=True)
    yw, sw = ssd_chunked_ref(xb, dt, a_neg, bm, cm, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yw),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(sw),
                               atol=2e-4, rtol=2e-4)


def test_ssd_ref_matches_naive_recurrence():
    """Chunked SSD == step-by-step state recurrence."""
    from repro.models.ssm import ssd_chunked_ref
    b, l, h, p, n = 1, 32, 2, 8, 4
    xb = np.asarray(_rand(10, (b, l, h, p), jnp.float32)) * 0.5
    dt = np.asarray(jax.nn.softplus(_rand(11, (b, l, h), jnp.float32)))
    a_neg = np.asarray(-jnp.exp(_rand(12, (h,), jnp.float32) * 0.3))
    bm = np.asarray(_rand(13, (b, l, n), jnp.float32)) * 0.5
    cm = np.asarray(_rand(14, (b, l, n), jnp.float32)) * 0.5
    # naive
    s = np.zeros((b, h, n, p))
    ys = np.zeros((b, l, h, p))
    for t in range(l):
        a = np.exp(dt[:, t] * a_neg[None, :])  # (b,h)
        s = s * a[..., None, None] + np.einsum(
            "bn,bhp->bhnp", bm[:, t], xb[:, t])
        ys[:, t] = np.einsum("bn,bhnp->bhp", cm[:, t], s)
    y, s_fin = ssd_chunked_ref(jnp.asarray(xb), jnp.asarray(dt),
                               jnp.asarray(a_neg), jnp.asarray(bm),
                               jnp.asarray(cm), chunk=8)
    np.testing.assert_allclose(np.asarray(y), ys, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_fin), s, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("r,d", [(64, 128), (256, 64), (32, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_allclose(r, d, dtype):
    x = _rand(20, (r, d), dtype)
    g = _rand(21, (d,), jnp.float32)
    o = ops.rmsnorm(x, g, interpret=True)
    want = ref.rmsnorm_ref(x, g)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_model_level_pallas_path_matches_xla():
    """StackModel forward with pallas-interpret attention == XLA path."""
    from repro.configs import get_config, reduced_config
    from repro.models import build_model
    from repro.models.attention import set_attention_impl

    cfg = reduced_config(get_config("qwen2-0.5b"))
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch = {"tokens": jnp.arange(2 * 64, dtype=jnp.int32).reshape(2, 64) % 100,
             "labels": jnp.ones((2, 64), jnp.int32)}
    logits_xla, _ = m.forward(params, batch)
    try:
        set_attention_impl("pallas_interpret")
        logits_pl, _ = m.forward(params, batch)
    finally:
        set_attention_impl("xla")
    np.testing.assert_allclose(np.asarray(logits_xla), np.asarray(logits_pl),
                               atol=2e-3, rtol=2e-3)
