"""AbacusServer + AdmissionController: concurrency, coalescing, admission."""

import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.core.scheduler import Machine
from repro.serve import (AbacusServer, AdmissionController,
                         PredictionService, Query, TraceStore)
from repro.serve.prediction_service import ServiceStats

from test_prediction_service import _abacus, _counting_tracer, _fake_cfg

GIB = 2**30


class _CountingAbacus:
    """Delegates to a fitted DNNAbacus, counting ensemble passes."""

    def __init__(self, ab):
        self._ab = ab
        self.predict_calls = 0

    def predict(self, records):
        self.predict_calls += 1
        return self._ab.predict(records)


def _served(tracer_calls=None, **svc_kw):
    ab = _CountingAbacus(_abacus())
    svc = PredictionService(
        ab, tracer=_counting_tracer(
            tracer_calls if tracer_calls is not None else []), **svc_kw)
    return ab, svc


# -- lifecycle ---------------------------------------------------------------


def test_submit_requires_running_server():
    _, svc = _served()
    srv = AbacusServer(svc)
    with pytest.raises(RuntimeError):
        srv.submit(_fake_cfg(), 2, 32)
    srv.start()
    try:
        assert srv.running
        assert np.isfinite(srv.predict_one(_fake_cfg(), 2, 32)["time_s"])
    finally:
        srv.stop()
    assert not srv.running
    with pytest.raises(RuntimeError):
        srv.submit(_fake_cfg(), 2, 32)


def test_stop_drains_queued_queries():
    calls = []
    _, svc = _served(calls)
    srv = AbacusServer(svc).start()
    futs = srv.submit_many([(_fake_cfg(), b, 32) for b in (2, 4, 8)])
    srv.stop()
    for f in futs:  # drain-then-stop: all answered, none abandoned
        assert np.isfinite(f.result(1)["time_s"])


# -- burst / dedup / coalescing ----------------------------------------------


def test_burst_of_identical_queries_costs_one_trace_server_path():
    calls = []
    base = _counting_tracer(calls)

    def slow_tracer(cfg, batch, seq):
        time.sleep(0.05)
        return base(cfg, batch, seq)

    ab = _CountingAbacus(_abacus())
    svc = PredictionService(ab, tracer=slow_tracer)
    cfg = _fake_cfg()
    with AbacusServer(svc) as srv:
        futs = [srv.submit(cfg, 2, 32) for _ in range(8)]
        results = [f.result(10) for f in futs]
    assert len(calls) == 1  # one trace for the whole burst
    assert len({r["time_s"] for r in results}) == 1
    assert srv.stats.completed == 8 and srv.stats.failed == 0


def test_burst_costs_one_trace_store_path(tmp_path):
    calls = []
    base = _counting_tracer(calls)

    def slow_tracer(cfg, batch, seq):
        time.sleep(0.05)
        return base(cfg, batch, seq)

    ab = _CountingAbacus(_abacus())
    svc = PredictionService(ab, tracer=slow_tracer,
                            store=TraceStore(str(tmp_path)))
    with AbacusServer(svc) as srv:
        futs = [srv.submit(_fake_cfg(), 2, 32) for _ in range(8)]
        for f in futs:
            f.result(10)
    assert len(calls) == 1
    assert len(svc.store) == 1  # written through exactly once


def test_microbatch_coalesces_to_one_ensemble_pass():
    """Deterministic unit check on the tick path: N queries, 1 pass."""
    ab, svc = _served()
    cfg_a, cfg_b = _fake_cfg("a"), _fake_cfg("b")
    with AbacusServer(svc) as srv:
        batch = [(Query(c, b, 32), Future())
                 for c in (cfg_a, cfg_b) for b in (2, 4)] \
              + [(Query(cfg_a, 2, 32), Future())]  # duplicate key
        srv._serve_batch(batch)
    assert ab.predict_calls == 1  # ONE ensemble pass for the micro-batch
    ests = [fut.result(0) for _, fut in batch]
    assert all(np.isfinite(e["time_s"]) for e in ests)
    assert ests[0]["time_s"] == ests[-1]["time_s"]  # duplicate key agrees
    assert srv.stats.ticks == 1 and srv.stats.max_batch == 5


def test_concurrent_submissions_coalesce_fewer_passes_than_queries():
    calls = []
    base = _counting_tracer(calls)
    started = threading.Event()

    def gating_tracer(cfg, batch, seq):
        started.set()
        time.sleep(0.1)  # hold tick 1 open while clients pile up
        return base(cfg, batch, seq)

    ab = _CountingAbacus(_abacus())
    svc = PredictionService(ab, tracer=gating_tracer)
    cfg = _fake_cfg()
    with AbacusServer(svc) as srv:
        first = srv.submit(cfg, 2, 32)
        assert started.wait(5)
        late = srv.submit_many([(cfg, b, s) for b in (2, 4, 8)
                                for s in (32, 64)])
        first.result(10)
        for f in late:
            f.result(10)
    # the 6 late queries coalesced into (at most) one tick after the first
    assert srv.stats.ticks <= 2
    assert ab.predict_calls <= 2
    assert srv.stats.max_batch >= 6


def test_eviction_under_concurrent_misses_resolves_all_futures():
    ab, svc = _served(max_cache_entries=2)
    cfgs = [_fake_cfg(n) for n in "abcdef"]
    with AbacusServer(svc, trace_workers=4) as srv:
        futs = [srv.submit(c, b, 32) for c in cfgs for b in (2, 4)]
        ests = [f.result(10) for f in futs]
    assert len(ests) == 12 and all(np.isfinite(e["time_s"]) for e in ests)
    info = svc.cache_info()
    assert info["entries"] <= 2          # LRU bound held throughout
    assert svc.stats.evictions >= 10
    assert srv.stats.failed == 0


def test_failing_trace_fails_only_that_query():
    calls = []
    base = _counting_tracer(calls)

    def flaky_tracer(cfg, batch, seq):
        if cfg.name == "bad":
            raise ValueError("untraceable config")
        return base(cfg, batch, seq)

    ab = _CountingAbacus(_abacus())
    svc = PredictionService(ab, tracer=flaky_tracer)
    with AbacusServer(svc) as srv:
        good = srv.submit(_fake_cfg("good"), 2, 32)
        bad = srv.submit(_fake_cfg("bad"), 2, 32)
        assert np.isfinite(good.result(10)["time_s"])
        with pytest.raises(ValueError, match="untraceable"):
            bad.result(10)
    assert srv.stats.completed == 1 and srv.stats.failed == 1


# -- admission controller ----------------------------------------------------


class _FixedPredictor:
    """predict_many stub with controlled estimates (keyed by cfg name)."""

    def __init__(self, table):
        self.table = table

    def predict_many(self, queries):
        return [{"model": q.cfg.name, **self.table[q.cfg.name]}
                for q in queries]


def _est(t, mem_gib):
    return {"time_s": t, "memory_bytes": mem_gib * GIB}


def test_admission_places_waves_incrementally():
    pred = _FixedPredictor({
        "big": _est(10.0, 20.0),   # only fits m2 (24 GiB)
        "small": _est(5.0, 4.0),
    })
    machines = [Machine("m1", 11 * GIB), Machine("m2", 24 * GIB)]
    ctl = AdmissionController(pred, machines, plan="optimal")
    w1 = ctl.admit([Query(_fake_cfg("big"), 2, 32)])
    assert w1[0].admitted and w1[0].machine == "m2"
    # wave 2 sees m2's reserved HBM: another big job no longer fits anywhere
    w2 = ctl.admit([Query(_fake_cfg("big"), 4, 32),
                    Query(_fake_cfg("small"), 2, 32)])
    assert not w2[0].admitted and "residual" in w2[0].reason
    assert w2[1].admitted
    state = ctl.cluster_state()
    assert state["resident_jobs"] == 2
    # completing the resident big job frees m2 for the next wave
    ctl.complete(w1[0].job_id)
    w3 = ctl.admit([Query(_fake_cfg("big"), 8, 32)])
    assert w3[0].admitted and w3[0].machine == "m2"


def test_admission_balances_base_time_across_waves():
    pred = _FixedPredictor({"j": _est(10.0, 1.0)})
    machines = [Machine("m1", 8 * GIB), Machine("m2", 8 * GIB)]
    ctl = AdmissionController(pred, machines, plan="optimal")
    v1 = ctl.admit([Query(_fake_cfg("j"), 2, 32)])
    v2 = ctl.admit([Query(_fake_cfg("j"), 4, 32)])
    # second wave must land on the OTHER machine: base_time makes
    # stacking both 10s jobs on one machine a 20s makespan vs 10s
    assert {v1[0].machine, v2[0].machine} == {"m1", "m2"}
    assert ctl.cluster_state()["makespan_s"] == pytest.approx(10.0)


def test_admission_complete_unknown_job_raises():
    ctl = AdmissionController(_FixedPredictor({}), [Machine("m", GIB)])
    with pytest.raises(KeyError):
        ctl.complete("nope#0")


def test_admission_through_live_server_and_ga():
    ab, svc = _served()
    machines = [Machine("m1", 1e21), Machine("m2", 1e21)]
    with AbacusServer(svc) as srv:
        ctl = AdmissionController(srv, machines, plan="ga",
                                  generations=5, seed=0)
        verdicts = ctl.admit([Query(_fake_cfg(n), b, 32)
                              for n in ("a", "b") for b in (2, 4)])
    assert all(v.admitted for v in verdicts)
    assert {v.machine for v in verdicts} <= {"m1", "m2"}
    assert len({v.job_id for v in verdicts}) == 4  # unique job ids


# -- server introspection ----------------------------------------------------


def test_server_info_merges_service_and_server_counters():
    _, svc = _served()
    with AbacusServer(svc) as srv:
        srv.predict_many([(_fake_cfg(), b, 32) for b in (2, 4)])
        info = srv.server_info()
    assert info["submitted"] == 2 and info["completed"] == 2
    assert info["queued"] == 0
    assert "entries" in info and "store_entries" in info
    assert info["ensemble_passes"] >= 1


def test_service_stats_reset_roundtrip():
    s = ServiceStats(hits=3, misses=2, evictions=1, store_hits=1, traces=1)
    assert s.queries == 5
    s.reset()
    assert s.as_dict()["queries"] == 0


# -- robustness regressions (code review) ------------------------------------


def test_unfingerprintable_config_fails_query_not_worker():
    _, svc = _served()
    with AbacusServer(svc) as srv:
        bad = srv.submit(42, 2, 32)  # int: vars() raises TypeError
        with pytest.raises(TypeError):
            bad.result(10)
        # the worker survived the poison query and keeps serving
        assert np.isfinite(srv.predict_one(_fake_cfg(), 2, 32)["time_s"])
    assert srv.stats.failed == 1 and srv.stats.completed == 1


def test_cancelled_future_is_dropped_not_fatal():
    calls = []
    base = _counting_tracer(calls)
    started, release = threading.Event(), threading.Event()

    def gated_tracer(cfg, batch, seq):
        started.set()
        release.wait(5)
        return base(cfg, batch, seq)

    _, svc = _served()
    svc._tracer = gated_tracer
    with AbacusServer(svc) as srv:
        first = srv.submit(_fake_cfg("a"), 2, 32)
        assert started.wait(5)              # worker is mid-tick
        doomed = srv.submit(_fake_cfg("b"), 2, 32)
        assert doomed.cancel()              # still queued: cancellable
        release.set()
        assert np.isfinite(first.result(10)["time_s"])
        # server keeps serving after skipping the cancelled entry
        assert np.isfinite(srv.predict_one(_fake_cfg("c"), 2, 32)["time_s"])
    assert doomed.cancelled()


def test_store_write_failure_degrades_to_memory_cache(tmp_path):
    class _BrokenStore(TraceStore):
        def put(self, key, rec):
            raise OSError("disk full")

    calls = []
    svc = PredictionService(_abacus(), tracer=_counting_tracer(calls),
                            store=_BrokenStore(str(tmp_path)))
    est = svc.predict_one(_fake_cfg(), 2, 32)  # trace succeeds, put fails
    assert np.isfinite(est["time_s"])
    assert svc.stats.store_errors == 1
    svc.predict_one(_fake_cfg(), 2, 32)  # memory cache still serves it
    assert len(calls) == 1 and svc.stats.hits == 1


# -- stats / lifecycle regressions (serve-layer fixes) ------------------------


def test_mean_batch_counts_failed_queries():
    """An all-failing micro-batch still coalesced queries: mean_batch
    must report (completed + failed) / ticks, not drop to zero."""
    _, svc = _served()

    def broken_tracer(cfg, batch, seq):
        raise ValueError("untraceable")

    svc._tracer = broken_tracer
    with AbacusServer(svc) as srv:
        futs = srv.submit_many([(_fake_cfg(f"bad{i}"), 2, 32)
                                for i in range(3)])
        for f in futs:
            with pytest.raises(ValueError):
                f.result(10)
    st = srv.stats
    assert st.completed == 0 and st.failed == 3 and st.ticks >= 1
    assert st.mean_batch == pytest.approx((st.completed + st.failed)
                                          / st.ticks)
    assert st.mean_batch > 0.0


def test_direct_adopt_counts_gen_swap():
    """publish_generation on a bare (no-worker) server adopts directly;
    that swap must land in stats.gen_swaps like a tick-boundary swap."""
    from repro.serve.refit import ModelGeneration

    _, svc = _served()
    srv = AbacusServer(svc)  # never started: the direct-adopt path
    gen = ModelGeneration(number=svc.generation + 1, abacus=_abacus(seed=1))
    assert srv.publish_generation(gen) is True
    assert srv.stats.gen_swaps == 1
    assert svc.generation == gen.number
    # a stale republish is refused and must NOT count another swap
    assert srv.publish_generation(gen) is False
    assert srv.stats.gen_swaps == 1


def test_observation_count_exact_under_concurrent_observers():
    _, svc = _served()
    srv = AbacusServer(svc)
    n_threads, per = 8, 200
    gate = threading.Barrier(n_threads)

    def hammer():
        gate.wait()
        for _ in range(per):
            srv.observe(_fake_cfg(), 2, 32, time_s=0.01, mem_bytes=1e6)

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30)
    assert srv.stats.observations == n_threads * per


def test_stop_timeout_leaves_worker_draining_then_second_stop_tears_down():
    """stop(timeout) returning with the worker mid-tick must leave the
    server observably draining, refuse a restart, and let a second
    stop() finish the teardown once the worker exits."""
    calls = []
    base = _counting_tracer(calls)
    started, release = threading.Event(), threading.Event()

    def gated_tracer(cfg, batch, seq):
        started.set()
        assert release.wait(20)
        return base(cfg, batch, seq)

    _, svc = _served()
    svc._tracer = gated_tracer
    srv = AbacusServer(svc).start()
    try:
        fut = srv.submit(_fake_cfg("slow"), 2, 32)
        assert started.wait(5)          # worker is blocked mid-tick
        srv.stop(timeout=0.05)          # expires before the trace finishes
        assert not srv.running
        assert srv.draining             # worker alive past the join timeout
        with pytest.raises(RuntimeError, match="draining"):
            srv.start()                 # restart refused while draining
        release.set()
        assert np.isfinite(fut.result(10)["time_s"])  # drain still serves it
        srv.stop(timeout=10)            # second stop completes the teardown
        assert not srv.draining and srv._worker is None and srv._pool is None
        # fully torn down: a fresh start serves again
        srv.start()
        assert np.isfinite(srv.predict_one(_fake_cfg("again"), 2, 32)
                           ["time_s"])
    finally:
        release.set()
        srv.stop()
