"""Scenario zoo: schedule determinism, replay round-trips, oracle teeth.

The determinism property is the foundation everything else stands on:
same seed => byte-identical JSONL, across processes and PYTHONHASHSEEDs
(checked in fresh subprocess interpreters). The oracle tests then prove
the invariant checkers have teeth — a replay passes all six, and an
injected undercount in either telemetry plane is caught.
"""

import copy
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(__file__))
from _hypo import given, settings, st

from repro.scenarios import (FaultSpec, ProfileSwap, ScenarioRunner,
                             ScenarioSpec, Schedule, TenantSpec, TrafficSpec,
                             check_all, config_from_payload, failed,
                             fit_abacus, generate, scenario_trace,
                             schedule_digest, schedule_digest_subprocess)
from repro.scenarios.oracles import (oracle_counters, oracle_legacy_stats,
                                     oracle_metrics_parity)
from repro.serve import AbacusServer, ClusterFrontend, PredictionService
from repro.serve.prediction_service import config_fingerprint


def _small_spec(seed=3, **kw):
    base = dict(
        name="unit", seed=seed, duration_s=2.0,
        tenants=[TenantSpec(name="a", weight=2.0, n_configs=3,
                            time_drift=2.0, mem_drift=1.25,
                            observe_fraction=0.5),
                 TenantSpec(name="b", weight=1.0, n_configs=2,
                            dots=(10.0, 20.0), time_drift=0.8,
                            observe_fraction=0.5)],
        traffic=TrafficSpec(base_rate=10.0, burst_amplitude=0.8,
                            burst_period_s=2.0),
        churn_rate=1.0,
        swaps=[ProfileSwap(t=1.0, tenant="a", time_drift=4.0,
                           mem_drift=1.5)],
        faults=[FaultSpec(t=1.0, kind="publish")])
    base.update(kw)
    return ScenarioSpec(**base)


@pytest.fixture(scope="module")
def abacus():
    return fit_abacus()


# -- determinism --------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_same_seed_same_bytes(seed):
    a = generate(_small_spec(seed=seed)).to_jsonl()
    b = generate(_small_spec(seed=seed)).to_jsonl()
    assert a == b
    # a different seed always produces different bytes (the meta header
    # embeds the seed even if the event stream were to coincide)
    assert a != generate(_small_spec(seed=seed + 1)).to_jsonl()


def test_digest_identical_across_hash_seeds():
    spec = _small_spec(seed=17)
    local = schedule_digest(spec)
    for hash_seed in (0, 4242):
        assert schedule_digest_subprocess(spec, hash_seed) == local


def test_jsonl_round_trip(tmp_path):
    sched = generate(_small_spec(seed=5))
    assert len(sched) > 0
    rt = Schedule.from_jsonl(sched.to_jsonl())
    assert rt == sched
    assert rt.to_jsonl() == sched.to_jsonl()
    path = sched.save(str(tmp_path / "sched.jsonl"))
    assert Schedule.load(path) == sched
    # the embedded spec regenerates the identical schedule
    spec2 = ScenarioSpec.from_dict(sched.meta["spec"])
    assert generate(spec2).to_jsonl() == sched.to_jsonl()


def test_meta_counts_and_drift_bounds():
    sched = generate(_small_spec(seed=9))
    counts = sched.meta["counts"]
    assert counts["submit"] == sum(1 for e in sched if e["op"] == "submit")
    assert counts["publish"] == 1
    lo, hi = sched.meta["drift"]["time"]
    # bounds cover exactly the factors present: drift = 1/factor - 1
    factors = {e["observe"]["time_factor"] for e in sched
               if e["op"] == "submit" and e["observe"]}
    assert factors <= {2.0, 4.0, 0.8}  # base a, swapped a, base b
    assert lo == pytest.approx(1 / max(factors) - 1)
    assert hi == pytest.approx(1 / min(factors) - 1)


def test_churn_configs_are_near_misses():
    sched = generate(_small_spec(seed=21, churn_rate=3.0))
    churned = [e for e in sched
               if e["op"] == "submit" and "nonce" in e["cfg"]]
    assert churned, "churn_rate=3 over 2s should emit churn submits"
    fps = set()
    for ev in churned:
        cfg = config_from_payload(ev["cfg"])
        base = dict(ev["cfg"])
        base.pop("nonce")
        base["name"] = base["name"].split("-churn")[0]
        base_cfg = config_from_payload(base)
        # fresh fingerprint (cache miss) ...
        assert config_fingerprint(cfg) != config_fingerprint(base_cfg)
        fps.add(config_fingerprint(cfg))
        # ... but identical features modulo the name: a true near-miss
        rec = scenario_trace(cfg, 4, 32)
        base_rec = scenario_trace(base_cfg, 4, 32)
        assert rec.flops == base_rec.flops
        assert rec.nsm_edges == base_rec.nsm_edges
    assert len(fps) == len(churned), "every churned config is unique"
    assert all(ev["observe"] is None for ev in churned)


def test_spec_validation():
    with pytest.raises(ValueError):
        generate(_small_spec(tenants=[]))
    with pytest.raises(ValueError):
        generate(_small_spec(
            tenants=[TenantSpec(name="a", weight=0.0)]))
    with pytest.raises(ValueError):
        generate(_small_spec(
            faults=[FaultSpec(t=0.5, kind="explode")]))


# -- replay + oracles ---------------------------------------------------------


def test_server_replay_all_oracles_pass(abacus):
    spec = _small_spec(seed=31)
    with AbacusServer(PredictionService(abacus,
                                        tracer=scenario_trace)) as srv:
        result = ScenarioRunner(srv, generate(spec)).run()
    assert not result.is_cluster
    assert result.ground["expected_gen_swaps"] == 1  # one publish, one server
    bad = failed(check_all(result))
    assert not bad, [(r.name, r.detail) for r in bad]


def test_cluster_replay_with_kill_and_resize(abacus, tmp_path):
    spec = _small_spec(
        seed=37,
        faults=[FaultSpec(t=0.5, kind="publish"),
                FaultSpec(t=1.0, kind="kill", target="r1"),
                FaultSpec(t=1.5, kind="resize", n=4)])
    fleet = ClusterFrontend(abacus, n_replicas=3,
                            trace_root=str(tmp_path / "traces"),
                            feedback_root=str(tmp_path / "fb"),
                            tracer=scenario_trace)
    fleet.start()
    try:
        result = ScenarioRunner(fleet, generate(spec)).run()
    finally:
        fleet.stop()
    g = result.ground
    assert g["kills"] == 1 and g["resizes"] == 1
    assert g["expected_gen_swaps"] == 3
    bad = failed(check_all(result))
    assert not bad, [(r.name, r.detail) for r in bad]
    # the killed replica's counters live on in the retired ledger
    assert result.stats_after["retired"]["submitted"] > 0
    check_all(result, raise_on_fail=True)  # does not raise when green


def test_oracles_catch_injected_undercount(abacus, tmp_path):
    spec = _small_spec(seed=41, faults=[])
    fleet = ClusterFrontend(abacus, n_replicas=2,
                            trace_root=str(tmp_path / "traces"),
                            feedback_root=str(tmp_path / "fb"),
                            tracer=scenario_trace)
    fleet.start()
    try:
        result = ScenarioRunner(fleet, generate(spec)).run()
    finally:
        fleet.stop()
    assert not failed(check_all(result))

    # stats-plane undercount: fleet counter loses a query
    mutated = copy.deepcopy(result)
    mutated.stats_after["fleet"]["submitted"] -= 1
    assert not oracle_counters(mutated).ok

    # metrics-plane undercount: the exposed series drifts from truth
    mutated = copy.deepcopy(result)
    mutated.metrics_after["server_submitted_total"]["value"] += 1
    assert not oracle_metrics_parity(mutated).ok

    # a legacy stats key vanishing is itself a violation
    mutated = copy.deepcopy(result)
    del mutated.stats_after["reshard"]
    assert not oracle_legacy_stats(mutated).ok

    with pytest.raises(AssertionError):
        mutated = copy.deepcopy(result)
        mutated.stats_after["fleet"]["gen_swaps"] += 1
        check_all(mutated, raise_on_fail=True)


@pytest.mark.scenario
@pytest.mark.slow
def test_long_composed_scenario(abacus, tmp_path):
    """Tier-2: a bigger composed scenario — burst + drift + churn +
    publish/kill/resize/publish on a 4 -> 6 fleet, all oracles exact."""
    spec = ScenarioSpec(
        name="composed-long", seed=97, duration_s=10.0,
        tenants=[TenantSpec(name="batch", weight=2.0, n_configs=6,
                            time_drift=3.0, mem_drift=1.5,
                            observe_fraction=0.6),
                 TenantSpec(name="interactive", weight=1.0, n_configs=4,
                            dots=(12.0, 36.0), time_drift=0.8,
                            observe_fraction=0.4)],
        traffic=TrafficSpec(base_rate=80.0, burst_amplitude=0.9,
                            burst_period_s=5.0),
        churn_rate=2.0,
        swaps=[ProfileSwap(t=5.0, tenant="batch", time_drift=2.0,
                           mem_drift=1.2)],
        faults=[FaultSpec(t=2.0, kind="publish"),
                FaultSpec(t=4.0, kind="kill", target="r2"),
                FaultSpec(t=6.0, kind="resize", n=6),
                FaultSpec(t=8.0, kind="publish")])
    fleet = ClusterFrontend(abacus, n_replicas=4,
                            trace_root=str(tmp_path / "traces"),
                            feedback_root=str(tmp_path / "fb"),
                            tracer=scenario_trace)
    fleet.start()
    try:
        result = ScenarioRunner(fleet, generate(spec)).run()
    finally:
        fleet.stop()
    assert result.ground["submitted"] > 400
    assert result.stats_after["replicas"] == 6
    bad = failed(check_all(result))
    assert not bad, [(r.name, r.detail) for r in bad]
