"""Differential + crash-point harness for the store engines.

The segment-log engine (``SegmentLogStore``) must be *observationally
identical* to the historical file-per-key layout (``JsonFileStore``)
behind the shared merge contract. Three layers of proof live here:

1. **Transfer**: the existing ``test_kvstore`` / ``test_trace_store``
   suites run VERBATIM against the segment engine — the ``store_engine``
   fixture (tests/conftest.py) rebinds their module-global store classes
   via :func:`patch_segment`, so every behavioural test those suites
   encode is executed once per backend with zero edits.
2. **Differential**: a seeded random op script (put/delete/merge/split/
   compact/clear, from ``benchmarks.bench_kvstore``) is applied to both
   engines in lockstep; every op result and every periodic content
   digest must match byte-for-byte, including after a cold reopen. The
   nightly CI job runs the same harness for 10^5 ops and uploads the op
   log so any mismatch replays bit-for-bit.
3. **Crash points**: every protocol step boundary the engine declares
   (``_crash_hook`` sites) is killed mid-flight and the directory
   reopened — no acknowledged write may be lost, unacknowledged tails
   must be truncated (not counted as damage), and a retried operation
   must converge.

Layout-specific behaviours that cannot transfer (the JSON suite pokes
individual files; a log has records) get hand-ported segment
equivalents in this module.
"""

import json
import os
import sys
import tempfile

import numpy as np
import pytest

from _hypo import given, settings, st
from repro.serve import kvstore
from repro.serve.feedback_store import SegmentFeedbackStore
from repro.serve.kvstore import SegmentLogStore, SimulatedCrash
from repro.serve.trace_store import SegmentTraceStore

import test_kvstore
import test_trace_store
from test_trace_store import _record

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.bench_kvstore import gen_ops, run_differential  # noqa: E402


class _SegTagStore(SegmentLogStore):
    """``test_kvstore._TagStore``'s value semantics on the segment
    engine — literally the same hook functions, different layout, which
    is the whole claim under test."""

    FILE_PREFIX = "tag_"
    VALUE_FIELD = "tags"
    _check_raw = test_kvstore._TagStore._check_raw
    _merge_raw = test_kvstore._TagStore._merge_raw


def patch_segment(monkeypatch):
    """Rebind the store classes the existing suites use as module
    globals so their tests exercise the segment engine unmodified."""
    monkeypatch.setattr(test_kvstore, "TraceStore", SegmentTraceStore)
    monkeypatch.setattr(test_kvstore, "FeedbackStore", SegmentFeedbackStore)
    monkeypatch.setattr(test_kvstore, "_TagStore", _SegTagStore)
    monkeypatch.setattr(test_trace_store, "TraceStore", SegmentTraceStore)


# -- layer 1: the existing suites, parametrized over both engines -------------

_KVSTORE_PROPERTY_TESTS = (
    "test_schema_version_is_shared_by_every_store",
    "test_trace_roundtrip_property",
    "test_feedback_roundtrip_property",
    "test_trace_merge_is_commutative_and_idempotent",
    "test_feedback_merge_three_way_converges",
    "test_trace_compact_never_drops_newest",
    "test_feedback_compact_never_drops_newest_per_key",
    "test_corrupt_injection_never_raises",
)

_KVSTORE_DIRECTORY_TESTS = (
    "test_base_supports_new_store_kinds",
    "test_clear_removes_only_own_prefix",
    "test_split_serializes_concurrent_writer",
    "test_feedback_compact_is_safe_under_concurrent_readers",
    "test_base_compact_is_safe_under_concurrent_readers",
)

_TRACE_STORE_TESTS = (
    "test_roundtrip_preserves_record",
    "test_miss_returns_none_and_counts",
    "test_put_leaves_no_temp_files",
    "test_clear_removes_files",
    "test_compact_is_safe_under_concurrent_readers",
    "test_trace_writes_through_and_second_service_warm_starts",
    "test_eviction_falls_back_to_store_without_retrace",
    "test_cache_info_reports_memory_and_store_distinctly",
)


@pytest.mark.parametrize("name", _KVSTORE_PROPERTY_TESTS)
def test_kvstore_suite_transfers(store_engine, name):
    getattr(test_kvstore, name)()


@pytest.mark.parametrize("name", _KVSTORE_DIRECTORY_TESTS)
def test_kvstore_directory_suite_transfers(store_engine, name, tmp_path):
    getattr(test_kvstore, name)(tmp_path)


@pytest.mark.parametrize("name", _TRACE_STORE_TESTS)
def test_trace_store_suite_transfers(store_engine, name, tmp_path):
    getattr(test_trace_store, name)(tmp_path)


# -- layer 1b: segment equivalents of the layout-specific JSON tests ----------


def test_segment_mixed_schema_generations(tmp_path):
    """Log analog of ``test_v_mixed_directory_loads_identically``: a
    segment holding records from several schema generations serves the
    current one and skips+counts the rest; compaction reclaims them."""
    ts = SegmentTraceStore(str(tmp_path))
    keys = [("aa" * 8, 2, 32), ("bb" * 8, 4, 32), ("cc" * 8, 8, 64)]
    for key, version in zip(keys, (0, 99, None)):
        if version is not None:
            ts.schema_version = version  # instance attr: foreign record
        try:
            ts.put(key, _record(batch=key[1], seq=key[2]))
        finally:
            ts.__dict__.pop("schema_version", None)
    fresh = SegmentTraceStore(str(tmp_path))
    assert fresh.get(keys[2]) is not None
    assert fresh.get(keys[0]) is None and fresh.get(keys[1]) is None
    assert fresh.stats.corrupt == 2
    assert list(fresh.keys()) == [keys[2]]
    assert fresh.compact()["kept"] == 1
    again = SegmentTraceStore(str(tmp_path))
    assert again.raw_snapshot() == {keys[2]: fresh.get_raw(keys[2])}
    assert again.stats.corrupt == 0  # physically reclaimed, not re-counted


def test_segment_key_disagreement_dead_on_every_path(tmp_path):
    """Log analog of the renamed-file test: an index entry pointing at a
    record whose embedded key disagrees is refused everywhere."""
    ts = SegmentTraceStore(str(tmp_path / "t"))
    key, other = ("11" * 8, 2, 32), ("22" * 8, 4, 64)
    ts.put(key, _record())
    ts._ensure_fresh()
    ts._index[other] = ts._index.pop(key)  # tampered mapping
    assert ts.get(other) is None and ts.stats.corrupt == 1
    assert ts.get(key) is None  # original mapping gone too
    assert list(ts.keys()) == []
    sink = SegmentTraceStore(str(tmp_path / "sink"))
    assert sink.merge(ts) == 0  # never propagates


def test_segment_torn_tail_truncated_not_fatal(tmp_path):
    ts = SegmentTraceStore(str(tmp_path))
    k1, k2 = ("aa" * 8, 2, 32), ("bb" * 8, 4, 32)
    ts.put(k1, _record())
    ts.put(k2, _record(batch=4))
    path = ts._seg_path(ts._active_no)
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) - 7)  # rip the tail mid-payload
    fresh = SegmentTraceStore(str(tmp_path))
    assert fresh.get(k1) is not None
    assert fresh.get(k2) is None
    assert fresh.torn_truncated == 1 and fresh.stats.corrupt == 0
    again = SegmentTraceStore(str(tmp_path))  # the truncation is physical:
    assert again.torn_truncated == 0          # a second open is clean


def test_segment_mid_corruption_skips_one_record(tmp_path):
    ts = SegmentTraceStore(str(tmp_path))
    keys = [(f"{i:02d}" * 8, 2, 32) for i in range(3)]
    for key in keys:
        ts.put(key, _record())
    name, _no, off, _length, _ts = ts._index[keys[1]]
    with open(os.path.join(str(tmp_path), name), "r+b") as f:
        f.seek(off)
        f.write(b"\x00\x00\x00\x00")  # break the MIDDLE record's CRC
    fresh = SegmentTraceStore(str(tmp_path))
    assert fresh.get(keys[0]) is not None  # before the damage
    assert fresh.get(keys[2]) is not None  # resynced past the damage
    assert fresh.get(keys[1]) is None
    assert fresh.stats.corrupt == 1 and fresh.torn_truncated == 0


def test_segment_seal_and_hints_roundtrip(tmp_path):
    """Sealing persists a hint file per immutable segment; a reopened
    instance serves the identical content whether it loads hints,
    rejects a poisoned one, or rejects a stale (wrong-size) one."""
    ts = SegmentTraceStore(str(tmp_path), segment_bytes=2048)
    for i in range(12):
        ts.put((f"{i:02x}" * 8, 2, 32), _record(f"m{i}"))
    assert ts.sealed_segments >= 1 and len(ts._files()) >= 2
    hints = [n for n in os.listdir(tmp_path) if n.endswith(".log.idx")]
    assert len(hints) == ts.sealed_segments
    baseline = ts.raw_snapshot()
    assert SegmentTraceStore(str(tmp_path)).raw_snapshot() == baseline
    # stale hint (valid JSON, wrong size): rejected, falls back to scan
    sealed_no = min(no for no, _ in ts._seg_files())
    kvstore.atomic_write_json(
        str(tmp_path), ts._hint_path(sealed_no),
        {"version": ts.schema_version, "size": 1, "records": []})
    stale = SegmentTraceStore(str(tmp_path))
    assert stale.raw_snapshot() == baseline and stale.stats.corrupt == 0
    # poisoned hints (unparseable): same fallback, still not "corrupt"
    for n in hints:
        with open(os.path.join(str(tmp_path), n), "w") as f:
            f.write("{ not a hint")
    poisoned = SegmentTraceStore(str(tmp_path))
    assert poisoned.raw_snapshot() == baseline
    assert poisoned.stats.corrupt == 0


def test_segment_open_scans_only_the_active_segment(tmp_path, monkeypatch):
    """The hint fast path is load-bearing: opening a directory of sealed
    segments byte-scans ONLY the newest (possibly-torn) segment."""
    ts = SegmentTraceStore(str(tmp_path), segment_bytes=2048)
    for i in range(12):
        ts.put((f"{i:02x}" * 8, 2, 32), _record(f"m{i}"))
    assert len(ts._files()) >= 3
    scans = []
    orig = SegmentLogStore._scan_segment
    monkeypatch.setattr(
        SegmentLogStore, "_scan_segment",
        lambda self, path: (scans.append(path), orig(self, path))[1])
    fresh = SegmentTraceStore(str(tmp_path))
    assert fresh.raw_snapshot() == ts.raw_snapshot()
    assert len(scans) == 1 and scans[0] == ts._seg_path(ts._active_no)


# -- layer 2: differential — one op script, two engines, equal everywhere -----


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10_000), st.integers(20, 80))
def test_engines_agree_on_randomized_op_scripts(seed, n_ops):
    """Seeded put/delete/merge/split/compact/clear scripts applied to
    both engines in lockstep: every op result and every content digest
    (including after a cold reopen) must be byte-equal. The nightly CI
    soak runs this exact harness for 10^5 ops."""
    rng = np.random.default_rng(seed)
    ops = gen_ops(rng, int(n_ops))
    with tempfile.TemporaryDirectory() as root:
        report = run_differential(root, ops, segment_bytes=2 << 10,
                                  check_every=16)
        assert report["ok"], report


# -- layer 3: crash-point injection ------------------------------------------

CRASH_SITES = ("append_mid", "append_durable", "seal",
               "compact_rewrite", "compact_retire")


def _arm(store, site, when=1):
    """Raise :class:`SimulatedCrash` the ``when``-th time ``site`` fires."""
    seen = {"n": 0}

    def hook(s):
        if s == site:
            seen["n"] += 1
            if seen["n"] == when:
                raise SimulatedCrash(site)

    store._crash_hook = hook
    return seen


def test_every_declared_crash_site_fires(tmp_path):
    """Coverage guard: drive ops that traverse all five sites with a
    recording (non-raising) hook — a renamed or dropped site would
    silently hollow out the crash suite otherwise."""
    fired = set()
    ts = SegmentTraceStore(str(tmp_path), segment_bytes=1200)
    ts._crash_hook = fired.add
    for i in range(6):
        ts.put((f"{i:02x}" * 8, 2, 32), _record(f"m{i}"))
    ts._delete_key((f"{0:02x}" * 8, 2, 32))
    ts.compact()
    assert fired == set(CRASH_SITES)


def test_crash_append_mid_loses_only_the_unacked_write(tmp_path):
    ts = SegmentTraceStore(str(tmp_path))
    acked = {}
    for i in range(4):
        key = (f"{i:02x}" * 8, 2, 32)
        acked[key] = _record(f"m{i}")
        ts.put(key, acked[key])
    _arm(ts, "append_mid")
    victim = ("ff" * 8, 4, 32)
    with pytest.raises(SimulatedCrash):
        ts.put(victim, _record("victim", batch=4))
    # the process is dead; a new one opens the same directory
    fresh = SegmentTraceStore(str(tmp_path))
    assert len(fresh) == len(acked)    # (triggers the lazy open scan)
    assert fresh.torn_truncated == 1   # half-written tail ripped out...
    assert fresh.stats.corrupt == 0    # ...as unacked, never as damage
    for key, rec in acked.items():
        assert fresh.get(key) == rec   # no acknowledged write lost
    assert fresh.get(victim) is None
    fresh.put(victim, _record("victim", batch=4))  # the retry just works
    assert fresh.get(victim) is not None


def test_crash_append_durable_put_surfaces_complete_record(tmp_path):
    """Crash AFTER the record is durable, BEFORE the index ack: the
    write was never acknowledged, so surfacing it on reopen is the
    legal outcome for a complete record — what is never legal is
    losing an acked key or counting the record as damage."""
    ts = SegmentTraceStore(str(tmp_path))
    prior = ("aa" * 8, 2, 32)
    ts.put(prior, _record())
    _arm(ts, "append_durable")
    victim = ("bb" * 8, 4, 32)
    rec = _record("durable", batch=4)
    with pytest.raises(SimulatedCrash):
        ts.put(victim, rec)
    fresh = SegmentTraceStore(str(tmp_path))
    assert fresh.get(prior) is not None
    assert fresh.get(victim) == rec
    assert fresh.torn_truncated == 0 and fresh.stats.corrupt == 0


def test_crash_append_durable_delete_tombstone_wins(tmp_path):
    ts = SegmentTraceStore(str(tmp_path))
    doomed, kept = ("aa" * 8, 2, 32), ("bb" * 8, 4, 32)
    ts.put(doomed, _record())
    ts.put(kept, _record(batch=4))
    _arm(ts, "append_durable")
    with pytest.raises(SimulatedCrash):
        ts._delete_key(doomed)
    fresh = SegmentTraceStore(str(tmp_path))
    assert fresh.get(doomed) is None   # durable tombstone took effect
    assert fresh.get(kept) is not None
    assert fresh.torn_truncated == 0 and fresh.stats.corrupt == 0


def test_crash_at_seal_serves_everything_and_keeps_appending(tmp_path):
    ts = SegmentTraceStore(str(tmp_path), segment_bytes=600)
    acked = []
    _arm(ts, "seal")
    with pytest.raises(SimulatedCrash):
        for i in range(200):
            key = (f"{i:02x}" * 8, 2, 32)
            ts.put(key, _record(f"m{i}"))
            acked.append(key)
    assert acked  # guard: the crash fired mid-loop, not before it
    fresh = SegmentTraceStore(str(tmp_path), segment_bytes=600)
    for key in acked:
        assert fresh.get(key) is not None
    # the put that crossed the threshold was durable+indexed pre-seal
    trigger = (f"{len(acked):02x}" * 8, 2, 32)
    assert fresh.get(trigger) is not None
    assert fresh.stats.corrupt == 0 and fresh.torn_truncated == 0
    fresh.put(("ee" * 8, 8, 64), _record("post", batch=8))
    assert fresh.get(("ee" * 8, 8, 64)) is not None


@pytest.mark.parametrize("site", ("compact_rewrite", "compact_retire"))
def test_crash_mid_compact_loses_nothing_and_retry_converges(tmp_path, site):
    ts = SegmentTraceStore(str(tmp_path), segment_bytes=1200)
    for i in range(10):
        ts.put((f"{i:02x}" * 8, 2, 32), _record(f"m{i}"))
    baseline = ts.raw_snapshot()
    _arm(ts, site)
    with pytest.raises(SimulatedCrash):
        ts.compact()
    fresh = SegmentTraceStore(str(tmp_path))
    assert fresh.raw_snapshot() == baseline  # old + new dedupe, zero loss
    assert fresh.stats.corrupt == 0
    out = fresh.compact()                    # the retry converges...
    assert out["kept"] == len(baseline)
    again = SegmentTraceStore(str(tmp_path))
    assert again.raw_snapshot() == baseline  # ...and retires the backlog
    assert len(again._files()) <= 2


# -- single-scan discipline: stat-count regression ----------------------------


def _count_os_stat(monkeypatch):
    calls = {"n": 0}
    real = os.stat

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(os, "stat", counting)
    return calls


def test_json_compact_makes_zero_os_stat_calls(tmp_path, monkeypatch):
    """Regression for the single-scan fix: ``JsonFileStore.compact``
    takes every (mtime, size) from the one directory scan
    (``os.scandir`` DirEntry.stat) — zero python-level ``os.stat``
    calls regardless of record count."""
    store = test_kvstore._TagStore(str(tmp_path))
    for i in range(40):
        store.put_raw((f"{i:02x}" * 8, 2, 32), {"t": i})
    calls = _count_os_stat(monkeypatch)
    out = store.compact(max_entries=10)
    assert out["kept"] == 10  # the compaction actually did the work
    assert calls["n"] == 0


def test_segment_compact_stat_count_independent_of_records(tmp_path,
                                                           monkeypatch):
    """The segment engine's compact stats a constant number of paths
    (freshness probe + directory fingerprint), never per-record."""
    counts = []
    for n, sub in ((8, "a"), (64, "b")):
        store = _SegTagStore(str(tmp_path / sub))
        for i in range(n):
            store.put_raw((f"{i:02x}" * 8, 2, 32), {"t": i})
        calls = _count_os_stat(monkeypatch)
        store.compact()
        monkeypatch.undo()
        counts.append(calls["n"])
    assert counts[0] == counts[1]
