"""Per-architecture smoke tests (assignment deliverable f).

Each assigned arch instantiates its REDUCED config (same family/pattern)
and runs one forward/train step on CPU, asserting output shapes and
finite values; decode cells additionally check prefill->decode
consistency against the full forward pass where exactness is expected.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, reduced_config, shape_applicable
from repro.models import build_model
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib

B, S = 2, 64

# builds + jits every assigned architecture: tier-2 only
pytestmark = pytest.mark.slow


def _batch(cfg):
    tokens = (jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) * 7) % (
        cfg.vocab_size - 1)
    b = {"tokens": tokens, "labels": (tokens + 1) % cfg.vocab_size}
    if cfg.cross_every:
        b["patches"] = jnp.full((B, cfg.vision_seq, cfg.d_model), 0.1,
                                jnp.float32)
    if cfg.is_encoder_decoder:
        b["frames"] = jnp.full((B, cfg.audio_seq, cfg.d_model), 0.1,
                               jnp.float32)
    return b


@pytest.fixture(scope="module", params=list_archs())
def arch_setup(request):
    cfg = reduced_config(get_config(request.param))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return request.param, cfg, model, params


def test_forward_shapes_finite(arch_setup):
    arch, cfg, model, params = arch_setup
    logits, aux = jax.jit(model.forward)(params, _batch(cfg))
    assert logits.shape[:2] == (B, S)
    assert logits.shape[2] >= cfg.vocab_size
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_train_step_no_nans(arch_setup):
    arch, cfg, model, params = arch_setup
    opt_cfg = opt_lib.OptConfig(keep_master=False)
    step = step_lib.make_train_step(model, opt_cfg)
    state = {"params": params,
             "opt": opt_lib.init_opt_state(opt_cfg, params),
             "step": jnp.zeros((), jnp.int32)}
    state, metrics = jax.jit(step)(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state["step"]) == 1
    # params actually changed
    leaves0 = jax.tree.leaves(params)
    leaves1 = jax.tree.leaves(state["params"])
    assert any(not np.allclose(np.asarray(a), np.asarray(b))
               for a, b in zip(leaves0, leaves1))


def test_prefill_decode_consistency(arch_setup):
    """decode_step at position S must match the forward pass logits at S
    (teacher forcing) — exact for every mixer family."""
    arch, cfg, model, params = arch_setup
    if cfg.num_experts:
        # exact consistency requires no capacity drops (grouping differs
        # between full-sequence and single-token routing)
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    ext = dict(batch)
    tok_next = jnp.full((B, 1), 3, jnp.int32)
    ext["tokens"] = jnp.concatenate([batch["tokens"], tok_next], axis=1)
    ext["labels"] = jnp.zeros_like(ext["tokens"])
    full_logits, _ = jax.jit(model.forward)(params, ext)

    logits_s, cache = jax.jit(model.prefill)(params, batch)
    # grow cache to S+1 where attention caches are sized by prefill length
    def grow(a):
        if a.ndim >= 3 and a.shape[2] == S:  # (periods, B, S, ...)
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, 1)
            return jnp.pad(a, pad)
        return a
    cache = jax.tree.map(grow, cache)
    pos = jnp.full((B,), S, jnp.int32)
    logits_t, _ = jax.jit(model.decode_step)(params, cache, tok_next, pos)
    got = np.asarray(logits_t[:, 0], np.float32)
    want = np.asarray(full_logits[:, -1], np.float32)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)


def test_shape_applicability_matrix():
    """40 cells total; long_500k runs only for sub-quadratic archs."""
    total, runnable = 0, 0
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES.values():
            total += 1
            ok, why = shape_applicable(cfg, shape)
            runnable += ok
            if shape.name == "long_500k":
                assert ok == (arch in ("jamba-v0.1-52b", "mamba2-370m")), arch
            else:
                assert ok
    assert total == 40 and runnable == 32


def test_param_counts_match_published():
    expected = {
        "jamba-v0.1-52b": 52e9, "arctic-480b": 480e9, "chatglm3-6b": 6.2e9,
        "phi4-mini-3.8b": 3.8e9, "qwen2.5-32b": 32.5e9, "qwen2-0.5b": 0.5e9,
        "llama-3.2-vision-90b": 88e9, "mamba2-370m": 0.37e9,
    }
    for arch, want in expected.items():
        n = build_model(get_config(arch)).param_count()
        assert abs(n - want) / want < 0.12, (arch, n, want)
