"""GA scheduling (paper §4.3): optimal recovery, memory feasibility."""

import numpy as np
import pytest

from repro.core.scheduler import (Job, Machine, makespan, schedule_ga,
                                  schedule_optimal, schedule_random)

GIB = 2**30


def _jobs(n=12, seed=0):
    rng = np.random.default_rng(seed)
    return [Job(f"j{i}", float(rng.uniform(5, 80)),
                float(rng.uniform(1, 8) * GIB)) for i in range(n)]


MACHINES = [Machine("m1", 11 * GIB), Machine("m2", 24 * GIB)]


def test_ga_matches_optimal_small():
    jobs = _jobs(12)
    opt, _ = schedule_optimal(jobs, MACHINES)
    ga, assign, hist = schedule_ga(jobs, MACHINES, generations=40, seed=1,
                                   return_history=True)
    assert np.isfinite(opt)
    assert ga <= opt * 1.02 + 1e-9
    assert hist == sorted(hist, reverse=True)  # monotone improvement


def test_ga_beats_random():
    jobs = _jobs(16, seed=3)
    rand_mean, _ = schedule_random(jobs, MACHINES, trials=50, seed=0)
    ga, _ = schedule_ga(jobs, MACHINES, generations=30, seed=0)
    assert ga < rand_mean


def test_memory_infeasible_jobs_respected():
    jobs = [Job("big", 10.0, 20 * GIB), Job("small", 5.0, 1 * GIB)]
    # big job only fits machine 2
    opt, assign = schedule_optimal(jobs, MACHINES)
    assert assign[0] == 1
    assert np.isfinite(opt)
    # makespan is inf when forced onto the small machine
    assert makespan([0, 0], jobs, MACHINES) == float("inf")


def test_ga_avoids_oom_assignments():
    jobs = [Job(f"b{i}", 10.0, 20 * GIB) for i in range(3)] + _jobs(6, 5)
    ga, assign = schedule_ga(jobs, MACHINES, generations=30, seed=2)
    assert np.isfinite(ga)
    for i in range(3):
        assert assign[i] == 1
