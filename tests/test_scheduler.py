"""GA scheduling (paper §4.3): optimal recovery, memory feasibility."""

import numpy as np
import pytest

from repro.core.scheduler import (Job, Machine, makespan, schedule_ga,
                                  schedule_optimal, schedule_random)

GIB = 2**30


def _jobs(n=12, seed=0):
    rng = np.random.default_rng(seed)
    return [Job(f"j{i}", float(rng.uniform(5, 80)),
                float(rng.uniform(1, 8) * GIB)) for i in range(n)]


MACHINES = [Machine("m1", 11 * GIB), Machine("m2", 24 * GIB)]


def test_ga_matches_optimal_small():
    jobs = _jobs(12)
    opt, _ = schedule_optimal(jobs, MACHINES)
    ga, assign, hist = schedule_ga(jobs, MACHINES, generations=40, seed=1,
                                   return_history=True)
    assert np.isfinite(opt)
    assert ga <= opt * 1.02 + 1e-9
    assert hist == sorted(hist, reverse=True)  # monotone improvement


def test_ga_beats_random():
    jobs = _jobs(16, seed=3)
    rand_mean, _ = schedule_random(jobs, MACHINES, trials=50, seed=0)
    ga, _ = schedule_ga(jobs, MACHINES, generations=30, seed=0)
    assert ga < rand_mean


def test_memory_infeasible_jobs_respected():
    jobs = [Job("big", 10.0, 20 * GIB), Job("small", 5.0, 1 * GIB)]
    # big job only fits machine 2
    opt, assign = schedule_optimal(jobs, MACHINES)
    assert assign[0] == 1
    assert np.isfinite(opt)
    # makespan is inf when forced onto the small machine
    assert makespan([0, 0], jobs, MACHINES) == float("inf")


def test_ga_avoids_oom_assignments():
    jobs = [Job(f"b{i}", 10.0, 20 * GIB) for i in range(3)] + _jobs(6, 5)
    ga, assign = schedule_ga(jobs, MACHINES, generations=30, seed=2)
    assert np.isfinite(ga)
    for i in range(3):
        assert assign[i] == 1


def test_makespan_with_base_load_and_reserved_mem():
    jobs = [Job("j", 10.0, 5 * GIB)]
    # committed busy time shifts the optimum: m1 already has 30 s queued
    assert makespan([0], jobs, MACHINES, base_time=[30.0, 0.0]) == 40.0
    assert makespan([1], jobs, MACHINES, base_time=[30.0, 0.0]) == 30.0
    # resident jobs' reserved HBM shrinks feasibility
    assert makespan([0], jobs, MACHINES,
                    reserved_mem=[7 * GIB, 0.0]) == float("inf")
    assert np.isfinite(makespan([1], jobs, MACHINES,
                                reserved_mem=[7 * GIB, 0.0]))


def test_plans_respect_base_load():
    jobs = [Job("j", 10.0, GIB)]
    base = [25.0, 0.0]
    opt, assign = schedule_optimal(jobs, MACHINES, base_time=base)
    assert assign == [1] and opt == 25.0  # placing on m1 would be 35 s
    ga, ga_assign = schedule_ga(jobs, MACHINES, generations=10, seed=0,
                                base_time=base)
    assert ga_assign == [1] and ga == 25.0
    mean, spans = schedule_random(jobs, MACHINES, trials=20, seed=0,
                                  reserved_mem=[10.5 * GIB, 0.0])
    assert np.isfinite(mean)  # m1 infeasible at residual HBM: never drawn


def test_ga_single_job_no_crossover_crash():
    # regression: rng.integers(1, 1) raised on single-job waves
    span, assign = schedule_ga([Job("solo", 3.0, GIB)], MACHINES,
                               generations=5, seed=0)
    assert np.isfinite(span) and len(assign) == 1


def test_ga_all_infeasible_population_no_crash():
    # regression: gen-0 entirely infeasible left best_a None -> .copy() crash
    jobs = [Job(f"j{i}", 5.0, 5 * GIB) for i in range(3)]
    tiny = [Machine("t1", 2 * GIB), Machine("t2", 2 * GIB)]
    span, assign = schedule_ga(jobs, tiny, pop_size=4, generations=3, seed=0)
    assert span == float("inf") and len(assign) == 3
